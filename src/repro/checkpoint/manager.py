"""Fault-tolerant checkpointing: per-leaf .npy + JSON manifest.

- atomic: written into <dir>/tmp-<step> then renamed to step-<step>;
- async: saves run on a background thread (training continues);
- elastic: arrays are stored unsharded, so a restart may restore onto a
  different mesh / device count (resharding happens at device_put);
- retention: keep the most recent `keep` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import numpy as np

import jax


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, blocking: bool = False) -> None:
        self.wait()                      # serialize with in-flight saves
        if step in self.all_steps():
            return
        leaves, treedef = _flatten(state)
        # bfloat16 round-trips through .npy as raw void; store as f32
        host_leaves = []
        for l in leaves:
            a = np.asarray(l)
            if a.dtype.name == "bfloat16":
                a = a.astype(np.float32)
            host_leaves.append(a)

        def _write():
            tmp = self.dir / f"tmp-{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir()
            for i, a in enumerate(host_leaves):
                np.save(tmp / f"leaf{i}.npy", a)
            manifest = {
                "step": step,
                "n_leaves": len(host_leaves),
                "treedef": str(treedef),
                "time": time.time(),
            }
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            final = self.dir / f"step-{step}"
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step-{s}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for p in self.dir.glob("step-*"):
            try:
                out.append(int(p.name.split("-")[1]))
            except ValueError:
                pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Restore into the structure of `like`; if `shardings` given,
        device_put each leaf (elastic re-shard onto the current mesh)."""
        d = self.dir / f"step-{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        leaves, treedef = _flatten(like)
        assert manifest["n_leaves"] == len(leaves), "tree structure changed"
        arrays = []
        for i, ref in enumerate(leaves):
            a = np.load(d / f"leaf{i}.npy")
            ref_dtype = getattr(ref, "dtype", None)
            if ref_dtype is not None and a.dtype != ref_dtype:
                a = a.astype(ref_dtype)  # cast back (e.g. f32 -> bf16)
            arrays.append(a)
        restored = jax.tree_util.tree_unflatten(treedef, arrays)
        if shardings is not None:
            restored = jax.tree.map(
                lambda a, s: jax.device_put(a, s), restored, shardings)
        return restored
