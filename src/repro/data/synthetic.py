"""Deterministic synthetic LM data pipeline.

Stateless-by-step: batch(step) is a pure function of (seed, step, shape),
so restarts resume exactly, any DP shard can regenerate its slice without
coordination, and elastic re-sharding (different device counts across
restarts) needs no data-state migration. The token stream is a mixture of
Zipf-distributed unigrams and short Markov motifs so the loss actually
decreases during the example runs (pure uniform noise would not train).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    zipf_a: float = 1.2
    motif_len: int = 8
    n_motifs: int = 64


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # fixed motif bank: repeated sub-sequences give learnable structure
        self.motifs = rng.integers(0, cfg.vocab,
                                   size=(cfg.n_motifs, cfg.motif_len))
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self.p = (p / p.sum()).astype(np.float64)

    def batch(self, step: int, shard: int = 0, n_shards: int = 1
              ) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        assert cfg.global_batch % n_shards == 0
        bsz = cfg.global_batch // n_shards
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + shard)
        toks = rng.choice(cfg.vocab, size=(bsz, cfg.seq_len + 1), p=self.p)
        # plant motifs so there is signal to learn
        n_plant = (cfg.seq_len // cfg.motif_len) // 2
        for b in range(bsz):
            for _ in range(n_plant):
                mi = rng.integers(0, cfg.n_motifs)
                pos = rng.integers(0, cfg.seq_len + 1 - cfg.motif_len)
                toks[b, pos:pos + cfg.motif_len] = self.motifs[mi]
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def jax_batch(self, step: int, extra: Optional[Dict] = None):
        b = {k: jnp.asarray(v) for k, v in self.batch(step).items()}
        if extra:
            b.update(extra)
        return b
