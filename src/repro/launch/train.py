"""Training launcher: --arch <id> [--smoke] [--steps N] ...

On the CPU container this runs reduced configs; on a real pod the same
entrypoint runs the full config under make_production_mesh().
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs.registry import get_config, list_archs
from repro.data.synthetic import DataConfig
from repro.launch.mesh import make_host_mesh
from repro.optim.adamw import OptConfig
from repro.parallel.api import mesh_context
from repro.train.loop import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config for CPU")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", default=None,
                    choices=[None, "int8"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    arch = get_config(args.arch)
    cfg = arch.smoke_model() if args.smoke else arch.model

    import numpy as np
    import jax.numpy as jnp
    extra = None
    if cfg.family == "encdec":
        def extra(step):
            rng = np.random.default_rng(step)
            return {"frames": jnp.asarray(rng.normal(size=(
                args.batch, args.seq, cfg.d_model)).astype(np.float32))}
    elif cfg.n_vision_tokens:
        def extra(step):
            rng = np.random.default_rng(step)
            return {"patches": jnp.asarray(rng.normal(size=(
                args.batch, cfg.n_vision_tokens,
                cfg.d_model)).astype(np.float32))}

    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch)
    tc = TrainConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                     ckpt_every=args.ckpt_every,
                     microbatches=args.microbatches,
                     grad_compression=args.grad_compression)
    with mesh_context(make_host_mesh()):
        trainer = Trainer(cfg, data_cfg,
                          OptConfig(lr=args.lr, total_steps=args.steps,
                                    warmup_steps=max(args.steps // 10, 5)),
                          tc, extra_batch=extra)
        out = trainer.run()
    print(f"[done] steps={out['final_step']} "
          f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f} "
          f"stragglers={out['stragglers']}")


if __name__ == "__main__":
    main()
