"""Step functions the launcher jits: train_step, prefill_step, serve_step."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.optim import adamw


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.OptConfig = None):
    from repro.train.loop import TrainConfig, make_step
    opt_cfg = opt_cfg or adamw.OptConfig()
    mb = 4 if getattr(cfg, "opt_microbatch4", False) else 1
    return make_step(cfg, opt_cfg, TrainConfig(microbatches=mb))


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits, caches = M.prefill_fn(cfg, params, batch)
        return logits, caches

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One new token against a seq_len-deep cache (decode shapes)."""
    def serve_step(params, token, pos, caches):
        logits, caches = M.decode_fn(cfg, params, caches, token, pos)
        return logits, caches

    return serve_step
