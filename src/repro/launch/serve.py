"""Batched serving loop with continuous batching over fixed decode slots.

serve_step is the same function the decode_32k / long_500k dry-run cells
lower; here it runs a real token loop on reduced configs.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, list_archs
from repro.models import model as M


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: Optional[List[int]] = None


class Server:
    """Fixed-slot continuous batching: each slot holds one sequence; free
    slots are refilled from the queue (prefill), all active slots advance
    one token per serve_step."""

    def __init__(self, cfg, params, n_slots: int = 4, max_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.caches = M.empty_cache(cfg, n_slots, max_len,
                                    max_len if cfg.family == "encdec"
                                    else None)
        self.tokens = jnp.zeros((n_slots, 1), jnp.int32)
        self.pos = np.zeros(n_slots, np.int32)
        self.remaining = np.zeros(n_slots, np.int32)
        self.active = np.zeros(n_slots, bool)
        self.rids = np.full(n_slots, -1)
        self.results = {}

        self._decode = jax.jit(
            lambda p, c, t, pos: M.decode_fn(cfg, p, c, t, pos))

    def _prefill_one(self, slot: int, req: Request):
        prompt = jnp.asarray(req.prompt)[None, :]
        batch = {"tokens": prompt}
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (1, prompt.shape[1], self.cfg.d_model), jnp.bfloat16)
        logits, cache = M.prefill_fn(self.cfg, self.params, batch,
                                     cache_len=self.max_len)

        # splice the single-sequence cache into this slot's batch lane
        def splice(full, one):
            for ax in range(full.ndim):
                if one.shape[ax] == 1 and full.shape[ax] == self.n_slots:
                    return jax.lax.dynamic_update_index_in_dim(
                        full, jnp.take(one, 0, axis=ax), slot, axis=ax)
            return full

        self.caches = jax.tree.map(splice, self.caches, cache)
        tok = int(jnp.argmax(logits[0, -1]))
        self.tokens = self.tokens.at[slot, 0].set(tok)
        self.pos[slot] = req.prompt.shape[0]
        self.remaining[slot] = req.max_new
        self.active[slot] = True
        self.rids[slot] = req.rid
        self.results[req.rid] = [tok]

    def run(self, requests: List[Request], greedy: bool = True):
        queue = list(requests)
        served = 0
        steps = 0
        while queue or self.active.any():
            for slot in range(self.n_slots):
                if not self.active[slot] and queue:
                    self._prefill_one(slot, queue.pop(0))
            pos = int(self.pos.max())  # uniform pos approximation
            logits, self.caches = self._decode(self.params, self.caches,
                                               self.tokens, jnp.int32(pos))
            nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            self.tokens = nxt[:, None]
            steps += 1
            for slot in range(self.n_slots):
                if not self.active[slot]:
                    continue
                self.results[self.rids[slot]].append(int(nxt[slot]))
                self.pos[slot] += 1
                self.remaining[slot] -= 1
                if self.remaining[slot] <= 0 or self.pos[slot] >= \
                        self.max_len - 1:
                    self.active[slot] = False
                    served += 1
        return {"served": served, "decode_steps": steps,
                "results": self.results}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke_model()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    server = Server(cfg, params, n_slots=args.slots, max_len=128)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab, args.prompt_len),
                    args.max_new) for i in range(args.requests)]
    t0 = time.time()
    out = server.run(reqs)
    dt = time.time() - t0
    toks = sum(len(v) for v in out["results"].values())
    print(f"[serve] arch={args.arch} served={out['served']} "
          f"decode_steps={out['decode_steps']} tokens={toks} "
          f"({toks / dt:.1f} tok/s) in {dt:.1f}s")


if __name__ == "__main__":
    main()
