import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.
DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two XLA_FLAGS lines above MUST stay the first statements in this module
(jax locks the device count at first init). The dry-run never allocates
arrays: all inputs are ShapeDtypeStructs and compilation is AOT.

HLO cost analysis visits while-loop (lax.scan) bodies once, so for the
roofline numbers each single-pod cell is additionally lowered at two small
UNROLLED depths and flops/bytes/collective-bytes are linearly extrapolated
to the full depth (they are exactly affine in trip count). The full scanned
artifact is still what certifies sharding, memory, and the collective
schedule.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b \
      --shape train_4k --mesh both --outdir benchmarks/results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --arch all
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.configs.registry import get_config, list_archs
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import batch_specs, decode_specs, model_state_specs
from repro.launch.steps import make_prefill_step, make_serve_step, \
    make_train_step
from repro.parallel.api import filter_spec, mesh_context
from repro.parallel.sharding import cache_specs


def build_lowered(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """Lower the cell's step function; returns (lowered, tokens_per_step)."""
    with mesh_context(mesh):
        rep = NamedSharding(mesh, P())
        if shape.kind == "train":
            params, pspec, opt, ospec = model_state_specs(cfg, mesh,
                                                          with_opt=True)
            batch, bspec = batch_specs(cfg, shape, mesh)
            step = make_train_step(cfg)
            stats_spec = {"loss": rep, "lr": rep, "grad_norm": rep}
            jitted = jax.jit(step,
                             in_shardings=(pspec, ospec, bspec),
                             out_shardings=(pspec, ospec, stats_spec),
                             donate_argnums=(0, 1))
            return jitted.lower(params, opt, batch), \
                shape.global_batch * shape.seq_len
        if shape.kind == "prefill":
            params, pspec, _, _ = model_state_specs(cfg, mesh, with_opt=False)
            batch, bspec = batch_specs(cfg, shape, mesh)
            step = make_prefill_step(cfg)
            out_shape = jax.eval_shape(step, params, batch)
            logits_spec = NamedSharding(mesh, filter_spec(
                (("pod", "data"), None, "model"), mesh, out_shape[0].shape))
            cspec = cache_specs(out_shape[1], mesh)
            jitted = jax.jit(step, in_shardings=(pspec, bspec),
                             out_shardings=(logits_spec, cspec))
            return jitted.lower(params, batch), \
                shape.global_batch * shape.seq_len
        # decode
        params, pspec, _, _ = model_state_specs(cfg, mesh, with_opt=False)
        (token, pos, caches), (tspec, posspec, cspec) = \
            decode_specs(cfg, shape, mesh)
        step = make_serve_step(cfg)
        out_shape = jax.eval_shape(step, params, token, pos, caches)
        logits_spec = NamedSharding(mesh, filter_spec(
            (("pod", "data"), None, "model"), mesh, out_shape[0].shape))
        jitted = jax.jit(step,
                         in_shardings=(pspec, tspec, posspec, cspec),
                         out_shardings=(logits_spec, cspec),
                         donate_argnums=(3,))
        return jitted.lower(params, token, pos, caches), shape.global_batch


def analyze(compiled) -> dict:
    ca = compiled.cost_analysis()
    rec = {
        "flops_per_dev": float(ca.get("flops", 0.0)),
        "bytes_per_dev": float(ca.get("bytes accessed", 0.0)),
    }
    txt = compiled.as_text()
    rec["hlo_len"] = len(txt)
    coll = H.collective_stats(txt)
    rec["collectives"] = coll
    rec["wire_bytes_per_dev"] = sum(v["wire_bytes"] for v in coll.values())
    rec["collective_operand_bytes_per_dev"] = \
        sum(v["operand_bytes"] for v in coll.values())
    try:
        ma = compiled.memory_analysis()
        live = (ma.argument_size_in_bytes - ma.alias_size_in_bytes +
                ma.output_size_in_bytes + ma.temp_size_in_bytes)
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_live_bytes": int(live),
            "fits_v5e_16g": bool(live < 16e9),
            "fits_v5p_95g": bool(live < 95e9),
        }
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": str(e)}
    return rec


def depth_variants(cfg: ModelConfig, seq_len: int):
    """Two reduced-depth UNROLLED configs + the full trip count."""
    noscan = dict(unroll=True)
    if cfg.family == "hybrid":
        full = cfg.n_layers // cfg.hybrid_period
        mk = lambda t: dataclasses.replace(
            cfg, n_layers=t * cfg.hybrid_period, **noscan)
        ts = [1, 2]
    elif cfg.family == "encdec":
        full = cfg.enc_layers
        mk = lambda t: dataclasses.replace(
            cfg, enc_layers=t, dec_layers=t, n_layers=2 * t, **noscan)
        ts = [2, 4]
    else:
        full = cfg.n_layers - cfg.first_k_dense
        mk = lambda t: dataclasses.replace(
            cfg, n_layers=cfg.first_k_dense + t, **noscan)
        ts = [2, 4]
    return full, ts, mk


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             roofline: bool = True, opts: str = "") -> dict:
    arch_cfg = get_config(arch)
    cfg = arch_cfg.model
    if opts:
        flags = {f"opt_{o.strip()}": True for o in opts.split(",") if o}
        cfg = dataclasses.replace(cfg, **flags)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "opts": opts,
           "chips": int(mesh.devices.size), "kind": shape.kind}

    t0 = time.time()
    lowered, tokens = build_lowered(cfg, shape, mesh)
    rec["lower_s"] = round(time.time() - t0, 2)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)
    rec.update(analyze(compiled))

    chips = rec["chips"]
    n_active = cfg.active_param_count()
    rec["params"] = cfg.param_count()
    rec["active_params"] = n_active
    rec["model_flops"] = H.model_flops(n_active, tokens, shape.kind)

    if roofline:
        full_t, ts, mk = depth_variants(cfg, shape.seq_len)
        metrics = []
        for t in ts:
            vlow, _ = build_lowered(mk(t), shape, mesh)
            vcomp = vlow.compile()
            va = analyze(vcomp)
            metrics.append((t, va["flops_per_dev"], va["bytes_per_dev"],
                            va["wire_bytes_per_dev"]))
        (t1_, f1, b1, w1), (t2_, f2, b2, w2) = metrics
        ext = {}
        for name, v1, v2 in [("flops_per_dev", f1, f2),
                             ("bytes_per_dev", b1, b2),
                             ("wire_bytes_per_dev", w1, w2)]:
            slope = (v2 - v1) / (t2_ - t1_)
            ext[name] = v1 + slope * (full_t - t1_)
        rec["extrapolated"] = {**ext, "depth_points": metrics,
                               "full_trips": full_t}
        rec["terms"] = H.roofline_terms(ext["flops_per_dev"],
                                        ext["bytes_per_dev"],
                                        ext["wire_bytes_per_dev"], chips)
        hlo_total = ext["flops_per_dev"] * chips
        rec["useful_flop_ratio"] = rec["model_flops"] / hlo_total \
            if hlo_total else 0.0
    else:
        rec["terms"] = H.roofline_terms(rec["flops_per_dev"],
                                        rec["bytes_per_dev"],
                                        rec["wire_bytes_per_dev"], chips)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--outdir", default="benchmarks/results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--opts", default="",
                    help="comma list: moe_local_dispatch,shard_carry")
    args = ap.parse_args()

    outdir = Path(args.outdir)
    outdir.mkdir(parents=True, exist_ok=True)

    archs = list_archs() if args.arch == "all" else [args.arch]
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16",
                       make_production_mesh(multi_pod=False), True))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16",
                       make_production_mesh(multi_pod=True), False))

    for arch in archs:
        arch_cfg = get_config(arch)
        shapes = list(arch_cfg.shapes) if args.shape == "all" \
            else [args.shape]
        for shape_name in shapes:
            if shape_name not in arch_cfg.shapes:
                print(f"SKIP {arch} x {shape_name}: {arch_cfg.notes}")
                continue
            for mesh_name, mesh, roofline in meshes:
                suffix = f"__opt-{args.opts}" if args.opts else ""
                out = outdir / \
                    f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
                if out.exists() and not args.force:
                    print(f"cached {out.name}", flush=True)
                    continue
                print(f"=== {arch} x {shape_name} x {mesh_name}", flush=True)
                try:
                    rec = run_cell(arch, shape_name, mesh, mesh_name,
                                   roofline=roofline, opts=args.opts)
                    print(f"    ok lower={rec['lower_s']}s "
                          f"compile={rec['compile_s']}s "
                          f"dom={rec['terms']['dominant']}", flush=True)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "error": str(e),
                           "traceback": traceback.format_exc()}
                    print(f"    FAIL {e}", flush=True)
                out.write_text(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
