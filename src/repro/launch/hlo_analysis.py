"""Roofline-term extraction from compiled dry-run artifacts.

cost_analysis() gives per-device HLO FLOPs / bytes; collective traffic is
NOT in cost_analysis, so we parse the (SPMD, per-device) HLO text and sum
operand sizes of every collective op. We record both the raw operand bytes
(the metric requested by the assignment) and a modeled bytes-on-wire that
accounts for group size and algorithm (ring) per collective type.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

# TPU v5e-class constants (per assignment)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link
LINKS_PER_CHIP = 6           # 3D torus / TONS radix

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"(?:^|\s)(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_TYPE_RE = re.compile(r"(pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64|f64)"
                      r"\[([0-9,]*)\]")
# iota form: replica_groups=[num_groups,group_size]<=[...]
_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(m) -> int:
    dt, dims = m.group(1), m.group(2)
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per collective kind: count, operand bytes (per-device shard sizes as
    lowered -- the assignment's raw metric) and modeled ring bytes-on-wire
    per device. Result-type based: modern HLO text doesn't annotate operand
    types, so sizes derive from each op's result type + group size."""
    stats = defaultdict(lambda: {"count": 0, "operand_bytes": 0.0,
                                 "wire_bytes": 0.0})
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        lhs = line.split("=", 1)
        if len(lhs) < 2 or "=" not in line[:m.start() + 1]:
            continue
        kind = m.group(1)
        type_region = line[line.index("=") + 1:m.start()]
        types = list(_TYPE_RE.finditer(type_region))
        if not types:
            continue
        b_res = _shape_bytes(types[-1])  # result (last element for tuples)
        g = 1
        gm = _GROUP_IOTA_RE.search(line)
        if gm:
            g = int(gm.group(2))
        else:
            gm = _GROUP_LIST_RE.search(line)
            if gm:
                g = len([x for x in gm.group(1).split(",") if x.strip()])
        if kind == "all-reduce":
            operand, wire = b_res, 2.0 * b_res * (g - 1) / max(g, 1)
        elif kind == "all-gather":
            operand = b_res / max(g, 1)
            wire = b_res * (g - 1) / max(g, 1)
        elif kind == "reduce-scatter":
            operand, wire = b_res * g, b_res * (g - 1)
        elif kind == "all-to-all":
            operand, wire = b_res, b_res * (g - 1) / max(g, 1)
        else:  # collective-permute
            operand, wire = b_res, float(b_res)
        s = stats[kind]
        s["count"] += 1
        s["operand_bytes"] += operand
        s["wire_bytes"] += wire
    return dict(stats)


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   wire_bytes_per_dev: float, chips: int) -> Dict[str, float]:
    """Three roofline terms in seconds (per assignment formulas, with
    HLO totals = per-device x chips)."""
    total_flops = flops_per_dev * chips
    total_bytes = bytes_per_dev * chips
    total_wire = wire_bytes_per_dev * chips
    t_compute = total_flops / (chips * PEAK_FLOPS)
    t_memory = total_bytes / (chips * HBM_BW)
    t_collective = total_wire / (chips * LINK_BW * LINKS_PER_CHIP)
    terms = {"t_compute": t_compute, "t_memory": t_memory,
             "t_collective": t_collective}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    terms["roofline_fraction"] = terms[dom] and max(
        t_compute / max(terms[dom], 1e-30), 0.0)
    return terms


def model_flops(active_params: int, tokens: int, kind: str) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference) with N active params."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * active_params * tokens
