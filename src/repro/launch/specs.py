"""ShapeDtypeStruct input stand-ins + shardings for every dry-run cell.

``input_specs(arch, shape)`` returns (abstract args, shardings) for the
step function the cell lowers: train_step / prefill_step / serve_step.
No device allocation happens anywhere here.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ModelConfig, ShapeConfig
from repro.models import model as M
from repro.optim import adamw
from repro.parallel.api import filter_spec
from repro.parallel.sharding import cache_specs, param_specs

BATCH = ("pod", "data")


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """Training / prefill batch arrays."""
    B, S = shape.global_batch, shape.seq_len
    sh = lambda spec, shp: NamedSharding(mesh, filter_spec(spec, mesh, shp))
    batch: Dict[str, Any] = {}
    shard: Dict[str, Any] = {}
    if cfg.family == "encdec":
        batch["frames"] = _sds((B, S, cfg.d_model), jnp.bfloat16)
        shard["frames"] = sh((BATCH, None, None), batch["frames"].shape)
    batch["tokens"] = _sds((B, S), jnp.int32)
    shard["tokens"] = sh((BATCH, None), (B, S))
    if shape.kind == "train":
        batch["labels"] = _sds((B, S), jnp.int32)
        shard["labels"] = sh((BATCH, None), (B, S))
    if cfg.n_vision_tokens:
        batch["patches"] = _sds((B, cfg.n_vision_tokens, cfg.d_model),
                                jnp.bfloat16)
        shard["patches"] = sh((BATCH, None, None), batch["patches"].shape)
    if B == 1:  # long-context: sequence-parallel over data
        shard["tokens"] = sh((None, "data"), (B, S))
        if "frames" in batch:
            shard["frames"] = sh((None, "data", None), batch["frames"].shape)
    return batch, shard


def model_state_specs(cfg: ModelConfig, mesh, with_opt: bool):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params = jax.eval_shape(partial(M.init_params, cfg), key)
    pspec = param_specs(params, mesh)
    if not with_opt:
        return params, pspec, None, None
    opt = jax.eval_shape(adamw.init, params)
    ospec = {"m": param_specs(opt["m"], mesh),
             "v": param_specs(opt["v"], mesh),
             "step": NamedSharding(mesh, P())}
    return params, pspec, opt, ospec


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """(token, pos, caches) stand-ins + shardings for serve_step."""
    B, S = shape.global_batch, shape.seq_len
    caches = jax.eval_shape(
        partial(M.empty_cache, cfg, B, S, S_enc=S
                if cfg.family == "encdec" else None))
    cspec = cache_specs(caches, mesh)
    token = _sds((B, 1), jnp.int32)
    tok_spec = NamedSharding(mesh, filter_spec((BATCH, None), mesh, (B, 1)))
    pos = _sds((), jnp.int32)
    pos_spec = NamedSharding(mesh, P())
    return (token, pos, caches), (tok_spec, pos_spec, cspec)
