"""Fault-tolerant training loop.

Features needed at pod scale, all exercised by the examples/tests:
- resumable: restores the latest checkpoint (params + optimizer + step)
  and the stateless data pipeline regenerates batch(step) exactly;
- async checkpointing every `ckpt_every` steps, retention-managed;
- preemption handling: SIGTERM/SIGINT triggers a final blocking save;
- straggler watchdog: a step slower than `straggler_factor` x the running
  median is logged (at pod scale this feeds the controller that triggers
  re-sharding away from a slow host -- here we surface the signal);
- optional int8 gradient compression for the DP all-reduce (error feedback
  kept in the optimizer state is unnecessary at int8 for these scales --
  documented in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
import signal
import statistics
import time
from functools import partial
from typing import Any, Callable, Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.models import model as M
from repro.optim import adamw


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    straggler_factor: float = 3.0
    grad_compression: Optional[str] = None     # None | "int8"
    microbatches: int = 1                      # grad accumulation


def quantize_int8(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads):
    """Simulated compressed DP collective: values that cross the wire are
    int8 + one f32 scale per leaf. Under pjit the all-reduce happens on the
    quantized representatives; numerically this applies the same
    quantize->sum->dequantize transfer function."""
    def f(g):
        q, s = quantize_int8(g.astype(jnp.float32))
        return dequantize_int8(q, s).astype(g.dtype)
    return jax.tree.map(f, grads)


def make_step(cfg: ModelConfig, opt_cfg: adamw.OptConfig,
              train_cfg: TrainConfig):
    def loss_of(params, batch):
        return M.loss_fn(cfg, params, batch)

    def train_step(params, opt_state, batch):
        if train_cfg.microbatches > 1:
            mb = train_cfg.microbatches
            B = batch["tokens"].shape[0]
            assert B % mb == 0
            split = {k: v.reshape(mb, B // mb, *v.shape[1:])
                     for k, v in batch.items()}

            def acc_fn(carry, mbatch):
                loss, grads = jax.value_and_grad(loss_of)(params, mbatch)
                return (carry[0] + loss,
                        jax.tree.map(jnp.add, carry[1], grads)), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            if getattr(cfg, "unroll", False):
                # loop-free for the dry-run's cost accounting
                carry = (jnp.zeros(()), zero)
                for i in range(mb):
                    carry, _ = acc_fn(carry, jax.tree.map(
                        lambda v: v[i], split))
                loss, grads = carry
            else:
                (loss, grads), _ = jax.lax.scan(
                    acc_fn, (jnp.zeros(()), zero), split)
            loss = loss / mb
            grads = jax.tree.map(lambda g: g / mb, grads)
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        if train_cfg.grad_compression == "int8":
            grads = compress_grads(grads)
        params, opt_state, stats = adamw.update(opt_cfg, grads, opt_state,
                                                params)
        return params, opt_state, {"loss": loss, **stats}

    return train_step


class Trainer:
    def __init__(self, cfg: ModelConfig, data_cfg: DataConfig,
                 opt_cfg: adamw.OptConfig = None,
                 train_cfg: TrainConfig = None, seed: int = 0,
                 extra_batch: Optional[Callable[[int], Dict]] = None):
        self.cfg = cfg
        self.train_cfg = train_cfg or TrainConfig()
        self.opt_cfg = opt_cfg or adamw.OptConfig(
            total_steps=self.train_cfg.steps)
        self.data = SyntheticLM(data_cfg)
        self.ckpt = CheckpointManager(self.train_cfg.ckpt_dir)
        self.extra_batch = extra_batch

        key = jax.random.PRNGKey(seed)
        self.params = M.init_params(cfg, key)
        self.opt_state = adamw.init(self.params)
        self.start_step = 0
        self._preempted = False

        latest = self.ckpt.latest_step()
        if latest is not None:
            state = self.ckpt.restore(
                latest, {"params": self.params, "opt": self.opt_state})
            self.params, self.opt_state = state["params"], state["opt"]
            self.start_step = latest
            print(f"[trainer] resumed from step {latest}")

        self.step_fn = jax.jit(make_step(cfg, self.opt_cfg, self.train_cfg),
                               donate_argnums=(0, 1))

    def _handle_preempt(self, signum, frame):
        print(f"[trainer] signal {signum}: checkpoint + stop")
        self._preempted = True

    def run(self) -> Dict[str, Any]:
        tc = self.train_cfg
        old1 = signal.signal(signal.SIGTERM, self._handle_preempt)
        old2 = signal.signal(signal.SIGINT, self._handle_preempt)
        losses = []
        step_times = []
        stragglers = 0
        try:
            for step in range(self.start_step, tc.steps):
                t0 = time.time()
                batch = self.data.jax_batch(
                    step, self.extra_batch(step) if self.extra_batch
                    else None)
                self.params, self.opt_state, stats = self.step_fn(
                    self.params, self.opt_state, batch)
                loss = float(stats["loss"])
                dt = time.time() - t0
                step_times.append(dt)
                losses.append(loss)
                if len(step_times) >= 8:
                    med = statistics.median(step_times[-32:])
                    if dt > tc.straggler_factor * med:
                        stragglers += 1
                        print(f"[watchdog] step {step} took {dt:.2f}s "
                              f"(median {med:.2f}s) -- straggler")
                if step % tc.log_every == 0:
                    print(f"[train] step={step} loss={loss:.4f} "
                          f"lr={float(stats['lr']):.2e} "
                          f"gnorm={float(stats['grad_norm']):.3f} "
                          f"dt={dt:.2f}s", flush=True)
                if (step + 1) % tc.ckpt_every == 0 or self._preempted:
                    self.ckpt.save(step + 1, {"params": self.params,
                                              "opt": self.opt_state})
                if self._preempted:
                    break
        finally:
            self.ckpt.save(min(tc.steps, self.start_step + len(losses)),
                           {"params": self.params, "opt": self.opt_state},
                           blocking=True)
            signal.signal(signal.SIGTERM, old1)
            signal.signal(signal.SIGINT, old2)
        return {"losses": losses, "step_times": step_times,
                "stragglers": stragglers,
                "final_step": self.start_step + len(losses)}
