"""(min, +) matrix multiply as a Pallas TPU kernel.

The paper-side compute hotspot: all-pairs shortest paths / metric closure
(diameter, average hops, candidate path sets at pod scale) is repeated
(min,+) squaring of the hop matrix. On TPU this is a matmul-shaped
streaming problem: 128x128 VMEM tiles, K innermost so the accumulator
carries in VMEM; the semiring runs on the VPU (no MXU for min/+, but the
tiling/bandwidth structure is identical to a matmul).

Validated under interpret=True against ref.minplus_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BIG = 1e9


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, bk: int):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, BIG)

    a = a_ref[...]                       # (bm, bk)
    b = b_ref[...]                       # (bk, bn)
    # (min,+) contraction over the bk tile
    s = jnp.min(a[:, :, None] + b[None, :, :], axis=1)
    acc_ref[...] = jnp.minimum(acc_ref[...], s)

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def minplus(a, b, *, bm: int = 128, bn: int = 128, bk: int = 128,
            interpret: bool = True):
    """out[i, j] = min_k a[i, k] + b[k, j]; a: (M, K), b: (K, N) f32."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    grid = (M // bm, N // bn, K // bk)
    kernel = functools.partial(_kernel, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)


def apsp(adj, *, interpret: bool = True, block: int = 128):
    """All-pairs hop distances by log-depth (min,+) squaring."""
    import math
    n = adj.shape[0]
    d = adj
    for _ in range(int(math.ceil(math.log2(max(n - 1, 1))))):
        d = minplus(d, d, bm=block, bn=block, bk=block,
                    interpret=interpret)
    return d
