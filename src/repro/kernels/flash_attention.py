"""Blocked causal GQA flash attention as a Pallas TPU kernel.

TPU adaptation of the training/prefill flop hotspot: q/k/v blocks are
staged HBM->VMEM via BlockSpecs, the (bq, bk) score tile and the online
softmax state (m, l, acc) live in VMEM scratch, and both matmuls hit the
MXU with 128-aligned tiles. The kv-block loop is the innermost grid
dimension so the accumulator carries across it.

Validated under interpret=True on CPU against ref.flash_attention_ref.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            bq: int, bk: int, causal: bool, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    run = (not causal) or (ki * bk <= qi * bq + bq - 1)

    @pl.when(run)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32,
                                                      (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32,
                                                      (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]                                   # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)                   # (bk, hd)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 128, interpret: bool = True):
    """q: (B, Hq, Sq, hd); k, v: (B, Hkv, Skv, hd)."""
    B, Hq, Sq, hd = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0
    grid = (B * Hq, Sq // bq, Skv // bk)
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(_kernel, bq=bq, bk=bk, causal=causal,
                               scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd),
                         lambda bh, qi, ki: (bh // Hq, bh % Hq, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda bh, qi, ki: (bh // Hq, (bh % Hq) // rep,
                                             ki, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda bh, qi, ki: (bh // Hq, (bh % Hq) // rep,
                                             ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda bh, qi, ki: (bh // Hq, bh % Hq,
                                                   qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
