"""Pure-jnp oracles for the Pallas kernels."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, causal: bool = True):
    """q: (B, Hq, Sq, hd); k, v: (B, Hkv, Skv, hd). Materialised softmax
    attention with GQA head grouping -- the correctness oracle."""
    B, Hq, Sq, hd = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    qg = q.reshape(B, Hkv, rep, Sq, hd).astype(jnp.float32)
    s = jnp.einsum("bgrqd,bgkd->bgrqk", qg * (hd ** -0.5),
                   k.astype(jnp.float32))
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Skv), bool), k=Skv - Sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrqk,bgkd->bgrqd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, Sq, hd).astype(q.dtype)


def minplus_ref(a, b):
    """(min, +) matrix product: out[i, j] = min_k a[i, k] + b[k, j]."""
    return jnp.min(a[:, :, None] + b[None, :, :], axis=1)


def apsp_ref(adj, max_iters: int | None = None):
    """All-pairs shortest paths by repeated (min,+) squaring of the hop
    matrix (diagonal 0, edge 1, else +inf)."""
    n = adj.shape[0]
    d = adj
    iters = max_iters or int(math.ceil(math.log2(max(n - 1, 1))))
    for _ in range(iters):
        d = minplus_ref(d, d)
    return d
