"""jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels execute under interpret=True; on real
TPU hardware set REPRO_PALLAS_COMPILE=1 (or pass interpret=False) to lower
them natively. The jnp reference implementations remain available as
oracles and as the XLA fallback the models use for the dry-run.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.minplus import apsp as _apsp, minplus as _minplus

_INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


@partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def flash_attention(q, k, v, causal: bool = True, bq: int = 128,
                    bk: int = 128):
    return _flash(q, k, v, causal=causal, bq=bq, bk=bk,
                  interpret=_INTERPRET)


@partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def minplus(a, b, bm: int = 128, bn: int = 128, bk: int = 128):
    return _minplus(a, b, bm=bm, bn=bn, bk=bk, interpret=_INTERPRET)


def hop_matrix(edges: np.ndarray, n: int) -> jnp.ndarray:
    """Adjacency -> initial (min,+) distance matrix."""
    d = np.full((n, n), 1e9, np.float32)
    np.fill_diagonal(d, 0.0)
    d[edges[:, 0], edges[:, 1]] = 1.0
    d[edges[:, 1], edges[:, 0]] = 1.0
    return jnp.asarray(d)


def topology_metrics(edges: np.ndarray, n: int, block: int = 128):
    """Diameter + average hops via the Pallas APSP path (padded to the
    block size)."""
    pad = (-n) % block
    d0 = hop_matrix(edges, n)
    if pad:
        d0 = jnp.pad(d0, ((0, pad), (0, pad)), constant_values=1e9)
        d0 = d0.at[jnp.arange(n, n + pad), jnp.arange(n, n + pad)].set(0.0)
    d = _apsp(d0, interpret=_INTERPRET, block=block)
    d = d[:n, :n]
    diam = int(jnp.max(jnp.where(d >= 1e8, -1, d)))
    avg = float(jnp.sum(jnp.where(d >= 1e8, 0, d)) / (n * (n - 1)))
    return diam, avg
