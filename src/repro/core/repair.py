"""Online fault-reactive repair: incremental re-route on OCS/link failure.

The cold pipeline treats every fault as a full rebuild -- allowed-turn
admission over every base turn, BFS + selection over every flow, VC
allocation over every hop (~122s at 12^3). No pod serving live traffic
can afford that per fault. This module repairs a live
:class:`ServingState` *incrementally*, exploiting three structural
facts:

1. **Turn pruning is closed** (delta admission). Killing a channel kills
   exactly the turns whose in- or out-channel died; every surviving
   accepted turn was admitted against a *larger* DAG, and a subgraph of
   a DAG is a DAG under the same topological numbering. So the batched
   engine's admission snapshot (:attr:`ATResult._admission`) can be
   patched in place: drop dead rows from the accepted grid, keep the
   level numbering, done -- no turn is replayed. Only when pruning
   disconnects some pair does :func:`_readmit` resume the batched
   admission (prime a fresh :class:`_BatchedDAG` with the kept edges
   under the saved levels, then re-admit the non-accepted candidate
   cells through the normal ``admit_grid`` machinery) -- with a robust
   AT's OCS-disjoint trees this is the rare path.

2. **Untouched flows stay valid** (selective re-selection). A flow whose
   path crosses no dead channel uses only surviving turns (a turn dies
   only with its channels), so its path *and* its VC assignment remain
   exactly valid -- byte-for-byte untouched. Only the flows crossing
   dead channels are pooled: their load is subtracted from the live
   channel-load vector, they are re-walked at full K against the
   distance fields captured at build time (dead states masked out), and
   re-optimised by the sharded engine's own refinement primitive
   (:func:`repro.core.routing._refine_candidates`) against the true
   background load. Stored distances can be *stale* after a fault --
   a completed walk is still a real path (soundness), only completeness
   suffers -- so flows whose walkers all die get an exact per-source
   BFS on the pruned AT (write-back, copy-on-write), a small residual
   in practice.

3. **VC re-repair streams over the pool** (and only the pool). Old
   per-VC hop counts of pooled flows are subtracted and the
   exact-lookahead allocator re-runs over just those flows
   (:func:`repro.core.vcalloc.reallocate_vcs`); deadlock freedom of the
   result is re-verified against the pruned state graph.

`repair_fault(state, dead_channels)` returns a :class:`RepairResult`
carrying per-stage wall-clock, the re-routed flow count and the
post-repair ``l_max``; the repaired state is reachability- and
deadlock-equivalent to a full recompute on the faulted fabric (the
oracle `full_recompute` runs the whole selection + allocation from
scratch in the same channel-id space).

**Degraded mode** (default): when a fault genuinely disconnects some
pairs, the state keeps serving every reachable pair instead of giving
up. Lost pairs keep their flow slot with a zero-length path -- flow ids
stay stable across the whole fault/heal timeline -- and accumulate in
``ServingState.lost``; every invariant (loads, VC counts, deadlock
freedom, untouched-flow bit-identity) holds over the reachable subset.
``repair_fault(..., on_disconnect="recompute")`` restores the legacy
behaviour of falling back to a cold re-selection (which renumbers
flows, since unreachable pairs get no flow entry).

**Restoration** (:func:`restore_channels`) is the inverse walk: revived
channels re-enter turn admission incrementally -- partial heals resume
the batched engine over the saved snapshot (:func:`_readmit`), a full
heal swaps back the pristine cold admission kept on
``ServingState.at0`` for exact pre-fault recovery -- then previously
lost pairs re-route and, with ``rebalance=True``, every flow detoured
during the fault epoch (``ServingState.touched``) re-routes against
fresh exact distances so the healed fabric's ``l_max`` lands within a
few percent of a cold rebuild.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import numpy as np
import scipy.sparse as sp
from scipy.sparse import csgraph

from repro.core.pathtable import CSRPathTable
from repro.core.routing import (ATResult, Channels, RoutingResult,
                                _BatchedDAG, _dead_channel_array,
                                _refine_candidates, _walk_flows,
                                allowed_turns, node_distances,
                                select_paths)
from repro.core.topology import Topology
from repro.core.vcalloc import allocate_vcs, reallocate_vcs, \
    verify_deadlock_free, verify_flows_deadlock_free


class _LazyAllowed:
    """Set-compatible view of the allowed turns, materialised from the
    packed state-edge array only if a python consumer (the reference
    oracles, equivalence tests) actually touches it. The repair hot path
    never does -- everything downstream runs on the compiled
    ``StateGraph`` -- and building millions of tuple pairs would eat the
    time-to-recover budget."""

    def __init__(self, edges: np.ndarray, n_vc: int):
        self._edges = edges
        self._n_vc = n_vc
        self._set: Optional[set] = None

    def _materialise(self) -> set:
        if self._set is None:
            a, b = self._edges[:, 0], self._edges[:, 1]
            v = self._n_vc
            self._set = set(zip(zip((a // v).tolist(), (a % v).tolist()),
                                zip((b // v).tolist(), (b % v).tolist())))
        return self._set

    def __contains__(self, key) -> bool:
        return key in self._materialise()

    def __iter__(self):
        return iter(self._materialise())

    def __len__(self) -> int:
        return len(self._edges)

    def __bool__(self) -> bool:
        return len(self._edges) > 0


@dataclasses.dataclass
class ServingState:
    """A live routed fabric: everything the repair path needs to patch
    instead of rebuild.

    ``loads`` is the integer per-channel load vector with the selection
    engines' sentinel slot appended (``loads[n_ch]`` stays 0); ``dist``
    / ``best`` are the per-source BFS state-distance ``(n, S) int8`` and
    node-distance ``(n, n) int16`` fields captured during the cold
    build -- repairs re-walk pooled flows against them instead of
    re-running the BFS. ``dead`` accumulates every channel killed so
    far (sorted). States share ``dist``/``best`` read-only across a
    repair chain; a repair copies them before writing back refreshed
    rows (copy-on-write).

    ``lost`` holds the flow ids currently unroutable (degraded mode --
    their table slots are zero-length so flow ids never shift);
    ``touched`` accumulates every flow re-routed since the cold build
    (the set :func:`restore_channels` rebalances after a heal, and the
    only flows that may ride turns re-admitted mid-fault). ``at0``
    keeps the pristine cold-build ATResult: repairs never mutate it
    (pruning copies the admission snapshot), so a full heal can restore
    the exact pre-fault allowed set instead of replaying admission.
    """
    topo: Topology
    at: ATResult
    table: CSRPathTable
    loads: np.ndarray          # (n_ch + 1,) int64, sentinel slot last
    vc_counts: np.ndarray      # (n_vc,) hops per VC
    dead: np.ndarray           # (D,) sorted int64 dead channel ids
    dist: np.ndarray           # (n, S) int8 state distances, -1 pad
    best: np.ndarray           # (n, n) int16 node distances, -1 pad
    K: int
    seed: int
    stats: Optional[dict] = None
    lost: Optional[np.ndarray] = None      # sorted int64 lost flow ids
    touched: Optional[np.ndarray] = None   # sorted int64 re-routed flows
    at0: Optional[ATResult] = None         # pristine cold-build AT

    def __post_init__(self) -> None:
        if self.lost is None:
            self.lost = np.zeros(0, np.int64)
        if self.touched is None:
            self.touched = np.zeros(0, np.int64)

    @staticmethod
    def build(topo: Topology, n_vc: int = 4, K: int = 8, seed: int = 0,
              robust: bool = True, priority: str = "apl",
              **select_kw) -> "ServingState":
        """Cold build via :func:`repro.core.pipeline.route_pod`: robust
        allowed turns -> sharded selection (with the distance-field
        capture hooks) -> in-place balanced VC allocation."""
        from repro.core.pipeline import PipelineConfig, route_pod

        cfg = PipelineConfig(n_vc=n_vc, K=K, seed=seed, robust=robust,
                             priority=priority, engine="sharded",
                             local_search_rounds=3, vc="inplace")
        ch = Channels.from_topology(topo)
        n, S = ch.n_nodes, ch.n * n_vc
        dist = np.full((n, S), -1, np.int8)
        best = np.full((n, n), -1, np.int16)
        rp = route_pod(topo, cfg, dist_out=dist, best_out=best,
                       select_kw=select_kw)
        at, routed = rp.at, rp.routed
        loads = np.zeros(ch.n + 1, np.int64)
        loads[:ch.n] = routed.loads.astype(np.int64)
        return ServingState(topo, at, routed.table, loads, rp.vc_counts,
                            np.zeros(0, np.int64), dist, best, K, seed,
                            stats=routed.stats, at0=at)

    @property
    def l_max(self) -> float:
        return float(self.loads[:-1].max()) if len(self.loads) > 1 else 0.0

    @property
    def served_fraction(self) -> float:
        """Fraction of the fabric's flow slots currently routable --
        the availability metric a chaos campaign tracks over time."""
        F = self.table.n_flows
        return 1.0 if F == 0 else 1.0 - len(self.lost) / F


@dataclasses.dataclass
class RepairResult:
    """Outcome of one :func:`repair_fault` / :func:`restore_channels`
    call. ``stats`` carries the per-stage wall-clock (``prune_s``,
    ``walk_s``, ``bfs_s``, ``readmit_s``, ``greedy_s``, ``refine_s``,
    ``vc_s``, ``verify_s``, ``total_s``) plus pool/residual sizes; it
    is JSON-serialised by the benchmark lanes, so everything in it
    stays scalar. The re-routed flow-id pool rides separately on
    ``pool_flows`` (the complement is the untouched set whose paths
    must be bit-identical to the pre-event table)."""
    state: ServingState
    flows_rerouted: int
    l_max: float
    unreachable: int
    deadlock_free: bool
    fallback: bool             # repair gave up -> full re-selection
    readmitted: int            # turns re-admitted by the delta admission
    stats: dict
    lost: int = 0              # flow slots unroutable after this event
    restored: int = 0          # channels revived (restore events only)
    pool_flows: Optional[np.ndarray] = None   # re-routed flow ids


def _pruned_at(at: ATResult, dead_mask: np.ndarray) -> ATResult:
    """Delta allowed-turns admission, the closed (common) case: drop
    every accepted turn touching a dead channel from the admission
    snapshot and rebuild the packed edge array. The saved topological
    levels stay valid -- every kept edge was level-increasing before and
    edge deletion cannot create a cycle -- so nothing is replayed."""
    adm = at._admission
    if adm is None:
        raise ValueError("repair requires an ATResult from the batched "
                         "admission engine (at_engine='batched'); the "
                         "reference engine keeps no admission snapshot")
    n_vc = at.n_vc
    turns, vo = adm["turns"], adm["vo"]
    cin = turns[:, 0].astype(np.int64)
    cout = turns[:, 1].astype(np.int64)
    turn_dead = dead_mask[cin] | dead_mask[cout]
    acc2 = adm["acc"] & ~turn_dead[:, None]
    tr, tv = np.nonzero(acc2)
    edges = np.stack([cin[tr] * n_vc + vo[tv, 0],
                      cout[tr] * n_vc + vo[tv, 1]], axis=1)
    adm2 = {"level": adm["level"].copy(), "acc": acc2, "turns": turns,
            "vo": vo, "perm": adm["perm"], "cap_out": adm["cap_out"],
            "dead_turn": adm["dead_turn"] | turn_dead}
    stats = {"engine": "repair-pruned",
             "pruned_turn_cells": int((adm["acc"] & ~acc2).sum()),
             "allowed": len(edges)}
    return ATResult(at.channels, n_vc, _LazyAllowed(edges, n_vc),
                    trees=at.trees, stats=stats, _edges=edges,
                    _admission=adm2)


def _revived_at(at: ATResult, dead_mask: np.ndarray) -> ATResult:
    """Delta admission for a *partial* heal: recompute the dead-turn
    mask from the (smaller) surviving dead set, keep the accepted grid
    as is. A revived turn is NOT auto-re-accepted -- it was admitted
    against a DAG that has since changed -- it re-enters through
    :func:`_readmit`'s resumed batched admission under the saved level
    numbering, which guarantees the result stays acyclic."""
    adm = at._admission
    if adm is None:
        raise ValueError("restore requires an ATResult from the batched "
                         "admission engine (at_engine='batched'); the "
                         "reference engine keeps no admission snapshot")
    n_vc = at.n_vc
    turns, vo = adm["turns"], adm["vo"]
    cin = turns[:, 0].astype(np.int64)
    cout = turns[:, 1].astype(np.int64)
    turn_dead = dead_mask[cin] | dead_mask[cout]
    acc2 = adm["acc"].copy()    # accepted turns avoid the old dead set,
    tr, tv = np.nonzero(acc2)   # a superset of the healed one
    edges = np.stack([cin[tr] * n_vc + vo[tv, 0],
                      cout[tr] * n_vc + vo[tv, 1]], axis=1)
    adm2 = {"level": adm["level"].copy(), "acc": acc2, "turns": turns,
            "vo": vo, "perm": adm["perm"], "cap_out": adm["cap_out"],
            "dead_turn": turn_dead}
    stats = {"engine": "repair-restored",
             "revived_turn_cells": int((adm["dead_turn"]
                                        & ~turn_dead).sum()),
             "allowed": len(edges)}
    return ATResult(at.channels, n_vc, _LazyAllowed(edges, n_vc),
                    trees=at.trees, stats=stats, _edges=edges,
                    _admission=adm2)


def _readmit(at2: ATResult) -> int:
    """Resume the batched admission over the shrunken DAG: prime a fresh
    engine with the kept edges under the saved level numbering, then
    push every not-yet-accepted candidate cell of every live turn back
    through ``admit_grid`` (full-pass semantics). Exact -- the engine's
    forward/BFS/SCC/tangle ladder guarantees the result is acyclic --
    and only reached when pruning broke reachability. Returns the number
    of newly admitted VC-labeled turns; mutates ``at2`` in place
    (its accepted grid, packed edges and cached state graph)."""
    adm = at2._admission
    n_vc = at2.n_vc
    turns, vo, perm = adm["turns"], adm["vo"], adm["perm"]
    acc, dead_turn = adm["acc"], adm["dead_turn"]
    T, n_vo = acc.shape
    cin = turns[:, 0].astype(np.int64)
    cout = turns[:, 1].astype(np.int64)
    U = cin[:, None] * n_vc + vo[None, :, 0]
    V = cout[:, None] * n_vc + vo[None, :, 1]
    engstats = {"blocks": 0, "fwd_bulk": 0, "contested_bulk": 0,
                "bfs_rows": 0, "scc_checks": 0, "conflict_rounds": 0,
                "tangle_commits": 0, "admitted_per_block": []}
    S = at2.channels.n * n_vc
    eng = _BatchedDAG(S, adm["cap_out"], engstats)
    er, ec = np.nonzero(acc)
    eng.accept(U[er, ec].astype(np.int64), V[er, ec].astype(np.int64))
    eng.level = adm["level"].copy()
    rej = np.repeat(dead_turn[:, None], n_vo, axis=1)
    newly = 0
    block = 1024
    for i in range(0, T, block):
        b = perm[i:i + block]
        res, _ = eng.admit_grid(U[b], V[b], acc[b], rej[b],
                                first_only=False)
        if res.any():
            acc[b] |= res
            newly += int(res.sum())
    if newly:
        tr, tv = np.nonzero(acc)
        edges = np.stack([cin[tr] * n_vc + vo[tv, 0],
                          cout[tr] * n_vc + vo[tv, 1]], axis=1)
        at2._edges = edges
        at2.allowed = _LazyAllowed(edges, n_vc)
        at2._sg = None
        at2._by_in = None
        adm["level"] = eng.level
        if at2.stats is not None:
            at2.stats["allowed"] = len(edges)
    return newly


def _walk_pool_chunked(at2: ATResult, dist_store: np.ndarray,
                       best_store: np.ndarray, dead_state: np.ndarray,
                       psrc: np.ndarray, pdst: np.ndarray, K: int,
                       chunk: int = 64):
    """Re-walk an arbitrary (source-sorted) flow pool against the stored
    distance fields with the dead states masked out. Returns SEN-padded
    ``(cand (P, K, Lp), vc, k_valid, lens)``; flows whose stored node
    distance is gone (``<= 0``) come back all-invalid (residual)."""
    sg = at2.state_graph()
    ch = at2.channels
    n, n_vc = ch.n_nodes, at2.n_vc
    SEN = ch.n
    P = len(psrc)
    lens = best_store[psrc, pdst].astype(np.int64)
    parts = []
    spans = []
    Lp = 1
    usrc = np.unique(psrc)
    bounds = np.searchsorted(psrc, usrc[::chunk])
    bounds = np.append(bounds, P)
    for a, b in zip(bounds[:-1], bounds[1:]):
        if a == b:
            continue
        srcs = np.unique(psrc[a:b])
        sub = np.nonzero(lens[a:b] > 0)[0]
        if not len(sub):
            spans.append((a, b, None))
            continue
        dist = dist_store[srcs].astype(np.int16)
        dist[:, dead_state] = -1
        best = best_store[srcs]
        fb = np.searchsorted(srcs, psrc[a:b][sub])
        fl = lens[a:b][sub]
        cc, vv, kvp = _walk_flows(
            sg, n, n_vc, SEN, dist, best, srcs, fb, pdst[a:b][sub], fl,
            np.full(len(sub), K, np.int64), K, uniq=None)
        parts.append((cc, vv, kvp, sub))
        spans.append((a, b, len(parts) - 1))
        Lp = max(Lp, cc.shape[2])
    cand = np.full((P, K, Lp), SEN, np.int64)
    vcs = np.zeros((P, K, Lp), np.int8)
    kv = np.zeros((P, K), bool)
    for a, b, pi in spans:
        if pi is None:
            continue
        cc, vv, kvp, sub = parts[pi]
        rows = a + sub
        cand[rows, :, :cc.shape[2]] = cc
        vcs[rows, :, :cc.shape[2]] = vv
        kv[rows] = kvp
    return cand, vcs, kv, lens


def _exact_bfs(at2: ATResult, srcs: np.ndarray, dead_all: np.ndarray,
               chunk: int = 1024) -> np.ndarray:
    """Exact multi-source state BFS on the pruned AT: one augmented
    graph (a virtual node per source with unit edges into its live seed
    states) solved by an unweighted csgraph sweep. Matches
    :func:`routing.state_bfs` bit-for-bit -- the pruned edge set has no
    arcs into dead states, so masking the seeds suffices -- and is ~25%
    faster at 12^3 because the level loop runs in compiled code."""
    ch = at2.channels
    n_vc = at2.n_vc
    S = ch.n * n_vc
    B = len(srcs)
    deg = (ch.out_indptr[srcs + 1] - ch.out_indptr[srcs]).astype(np.int64)
    starts = ch.out_indptr[srcs].astype(np.int64)
    idx = np.repeat(starts - (np.cumsum(deg) - deg), deg) \
        + np.arange(int(deg.sum()), dtype=np.int64)
    seed_ch = ch.out_chan[idx].astype(np.int64)
    seed_st = (seed_ch[:, None] * n_vc + np.arange(n_vc)).ravel()
    rows = np.repeat(np.arange(B, dtype=np.int64), deg * n_vc)
    live = np.ones(len(seed_st), bool)
    if len(dead_all):
        dead_state = np.zeros(S, bool)
        dead_state[(dead_all[:, None] * n_vc
                    + np.arange(n_vc)).ravel()] = True
        live = ~dead_state[seed_st]
    e = at2._edges
    es = np.concatenate([e[:, 0], S + rows[live]])
    ed = np.concatenate([e[:, 1], seed_st[live]])
    m = sp.csr_matrix((np.ones(len(es), np.float32), (es, ed)),
                      shape=(S + B, S + B))
    out = np.empty((B, S), np.int16)
    for i in range(0, B, chunk):
        sub = np.arange(i, min(i + chunk, B))
        dmat = csgraph.dijkstra(m, directed=True, indices=S + sub,
                                unweighted=True)[:, :S]
        out[sub] = np.where(np.isinf(dmat), -1, dmat).astype(np.int16)
    return out


def _validated_dead(dead_channels, n_ch: int) -> np.ndarray:
    """Normalise a dead-channel list for the repair entry points:
    deduplicated sorted int64 ids (``_dead_channel_array``), with ids
    outside ``[0, n_ch)`` rejected loudly -- a negative id would
    otherwise wrap through numpy fancy indexing and silently corrupt an
    unrelated channel's state."""
    dc = _dead_channel_array(dead_channels)
    if dc is None:
        return np.zeros(0, np.int64)
    bad = dc[(dc < 0) | (dc >= n_ch)]
    if len(bad):
        raise ValueError(f"unknown channel ids {bad.tolist()} "
                         f"(topology has {n_ch} channels)")
    return dc


def _greedy_assign(loads: np.ndarray, cand: np.ndarray, kv: np.ndarray,
                   routable: np.ndarray, rng, SEN: int, BIG: np.int64,
                   block: int) -> np.ndarray:
    """Blockwise min-max greedy over a random pool order against the
    live background loads: each flow takes the candidate minimising
    (max load along path, sum of loads) lexicographically, committing
    its load before the next block. Returns per-pool-row chosen slot
    ids; mutates ``loads`` in place (sentinel slot kept at 0)."""
    pchosen = np.zeros(len(kv), np.int64)
    order = rng.permutation(routable)
    for i in range(0, len(order), block):
        idx = order[i:i + block]
        bc = cand[idx]
        l = loads[bc]
        cost = l.max(axis=2) * BIG + l.sum(axis=2)
        cost[~kv[idx]] = np.iinfo(np.int64).max
        c = cost.argmin(axis=1)
        pchosen[idx] = c
        np.add.at(loads, bc[np.arange(len(idx)), c].ravel(), 1)
        loads[SEN] = 0
    return pchosen


def _rebuild_table(table: CSRPathTable, pool: np.ndarray,
                   pool_hop_idx: np.ndarray, plens: np.ndarray,
                   kv: np.ndarray, cand: np.ndarray, vcs: np.ndarray,
                   pchosen: np.ndarray, SEN: int) -> CSRPathTable:
    """Rebuild the CSR arrays after a pool re-route: untouched flows
    shift in place via one cumsum/scatter (byte-identical hops),
    pooled flows scatter their winning candidate, unroutable pool
    flows come back as zero-length (lost) slots."""
    F = table.n_flows
    flen_all = table.flow_len.astype(np.int64)
    routable = np.nonzero(kv.any(axis=1))[0]
    flen2 = flen_all.copy()
    flen2[pool] = plens
    flen2[pool[~kv.any(axis=1)]] = 0
    hop_indptr2 = np.zeros(F + 1, np.int64)
    np.cumsum(flen2, out=hop_indptr2[1:])
    chan2 = np.full(int(hop_indptr2[-1]), SEN, np.int32)
    vc2 = np.zeros(int(hop_indptr2[-1]), np.int8)
    keep = np.ones(len(table.chan), bool)
    keep[pool_hop_idx] = False
    shift = hop_indptr2[:-1] - table.hop_indptr[:-1]
    new_pos = (np.arange(len(table.chan), dtype=np.int64)
               + np.repeat(shift, flen_all))[keep]
    chan2[new_pos] = table.chan[keep]
    vc2[new_pos] = table.vc[keep]
    if len(routable):
        rp = pool[routable]
        sel = cand[routable, pchosen[routable]]
        selvc = vcs[routable, pchosen[routable]]
        pos = np.arange(cand.shape[2])[None, :]
        live = pos < plens[routable][:, None]
        flat = (hop_indptr2[rp][:, None] + pos)[live]
        chan2[flat] = sel[live]
        vc2[flat] = selvc[live]
    return CSRPathTable(table.n, table.n_ch, table.n_vc,
                        table.src_indptr.copy(), table.dst.copy(),
                        hop_indptr2, chan2, vc2)


def _pool_hop_ranges(table: CSRPathTable,
                     pool: np.ndarray) -> np.ndarray:
    """Ragged hop index ranges of just the pool flows (~pool * avg hops
    entries, not all hops)."""
    plen = table.flow_len.astype(np.int64)[pool]
    return np.repeat(
        table.hop_indptr[pool] - (np.cumsum(plen) - plen), plen) \
        + np.arange(int(plen.sum()), dtype=np.int64)


def repair_fault(state: ServingState, dead_channels,
                 local_search_rounds: int = 1, refine_block: int = 192,
                 readmit: str = "auto", verify: str = "pool",
                 block: int = 4096, bfs_chunk: int = 1024,
                 on_disconnect: str = "degrade") -> RepairResult:
    """Incrementally repair a live :class:`ServingState` after
    ``dead_channels`` fail. Pure: the input state (its AT, table, loads,
    stores) is never mutated; the repaired state comes back on the
    :class:`RepairResult`.

    ``dead_channels`` is deduplicated; out-of-range or negative ids
    raise ``ValueError``. Channels already dead in the serving state are
    a no-op (their flows were re-routed when they first died) -- the
    repair only walks flows crossing *newly* dead channels, and
    ``stats["already_dead"]`` counts the redundant ids.

    ``readmit="auto"`` resumes turn admission only when pruning breaks
    reachability (``"never"`` disables it, ``"always"`` forces one
    pass). ``verify="pool"`` re-verifies the turns of re-routed flows
    only -- untouched flows keep using surviving turns by construction
    -- while ``"full"`` re-checks the whole table.

    ``on_disconnect`` picks the genuine-disconnection policy:
    ``"degrade"`` (default) serves every reachable pair and parks the
    disconnected ones as zero-length flow slots in
    ``ServingState.lost`` (flow ids stay stable; a later
    :func:`restore_channels` re-routes them); ``"recompute"`` falls
    back to a full re-selection on the pruned AT (legacy behaviour --
    flow ids shift because unreachable pairs get no flow entry, so the
    lost/touched bookkeeping resets).
    """
    if on_disconnect not in ("degrade", "recompute"):
        raise ValueError(f"on_disconnect must be 'degrade' or "
                         f"'recompute', got {on_disconnect!r}")
    t_all = time.time()
    stats: dict = {}
    at = state.at
    ch = at.channels
    n, n_vc = ch.n_nodes, at.n_vc
    SEN = ch.n
    K = state.K
    dc = _validated_dead(dead_channels, SEN)
    new = np.setdiff1d(dc, state.dead)
    stats["already_dead"] = int(len(dc) - len(new))
    dead_all = np.union1d(state.dead, dc)
    dead_mask = np.zeros(SEN, bool)
    dead_mask[dead_all] = True
    new_mask = np.zeros(SEN, bool)
    new_mask[new] = True
    dead_state = (dead_all[:, None] * n_vc
                  + np.arange(n_vc)).ravel() if len(dead_all) else \
        np.zeros(0, np.int64)

    # ---- stage A: delta allowed-turns admission (prune) -------------------
    t0 = time.time()
    at2 = _pruned_at(at, dead_mask)
    stats["prune_s"] = round(time.time() - t0, 3)
    readmitted = 0
    if readmit == "always":
        t0 = time.time()
        readmitted = _readmit(at2)
        stats["readmit_s_upfront"] = round(time.time() - t0, 3)

    # ---- stage B: selective re-selection ----------------------------------
    table = state.table
    F = table.n_flows
    flen_all = table.flow_len.astype(np.int64)
    # flows whose path crosses a newly-dead channel: searchsorted the
    # dead hop positions back to flow ids (cheaper than materialising
    # the tens-of-millions-entry hop->flow map at 12^3+)
    dead_hops = np.nonzero(new_mask[table.chan])[0]
    pool = np.unique(np.searchsorted(table.hop_indptr, dead_hops,
                                     side="right") - 1)
    stats["pool"] = len(pool)
    loads = state.loads.copy()
    counts = state.vc_counts.copy()
    dist_store, best_store = state.dist, state.best
    store_copied = False
    fallback = False
    unreachable = 0
    t_walk = t_bfs = t_readmit = t_greedy = t_refine = t_vc = 0.0
    rng = np.random.default_rng(state.seed)

    if len(pool):
        src_all = table.flow_src.astype(np.int64)
        psrc, pdst = src_all[pool], table.dst[pool].astype(np.int64)
        pool_hop_idx = _pool_hop_ranges(table, pool)
        loads[:SEN] -= np.bincount(table.chan[pool_hop_idx],
                                   minlength=SEN)
        loads[SEN] = 0
        counts = counts - np.bincount(
            table.vc[pool_hop_idx].astype(np.int64), minlength=n_vc)

        # stale-distance walk: completed chains are sound, dead walkers
        # form the residual that gets an exact BFS below
        t0 = time.time()
        cand, vcs, kv, plens = _walk_pool_chunked(
            at2, dist_store, best_store, dead_state, psrc, pdst, K)
        t_walk += time.time() - t0
        residual = np.nonzero(~kv.any(axis=1))[0]
        stats["residual"] = len(residual)
        for attempt in range(2):
            if not len(residual):
                break
            if attempt == 1:
                # the exact BFS still found nothing: only new turns can
                # help -- resume admission, then re-measure
                if readmit == "never" or readmitted:
                    break
                t0 = time.time()
                readmitted = _readmit(at2)
                t_readmit += time.time() - t0
                if not readmitted:
                    break
            t0 = time.time()
            rsrcs = np.unique(psrc[residual])
            if not store_copied:
                dist_store = dist_store.copy()
                best_store = best_store.copy()
                store_copied = True
            d = _exact_bfs(at2, rsrcs, dead_all, chunk=bfs_chunk)
            b = node_distances(at2, rsrcs, dist=d)
            dist_store[rsrcs] = d.astype(np.int8)
            best_store[rsrcs] = b.astype(np.int16)
            t_bfs += time.time() - t0
            t0 = time.time()
            rc, rv, rkv, rlens = _walk_pool_chunked(
                at2, dist_store, best_store, dead_state,
                psrc[residual], pdst[residual], K)
            t_walk += time.time() - t0
            Lp = max(cand.shape[2], rc.shape[2])
            if Lp > cand.shape[2]:
                grown = np.full((len(pool), K, Lp), SEN, np.int64)
                grown[:, :, :cand.shape[2]] = cand
                cand = grown
                gv = np.zeros((len(pool), K, Lp), np.int8)
                gv[:, :, :vcs.shape[2]] = vcs
                vcs = gv
            cand[residual, :, :rc.shape[2]] = rc
            cand[residual, :, rc.shape[2]:] = SEN
            vcs[residual, :, :rv.shape[2]] = rv
            vcs[residual, :, rv.shape[2]:] = 0
            kv[residual] = rkv
            plens[residual] = rlens
            residual = residual[~rkv.any(axis=1)]
        unreachable = int(len(residual))

        if unreachable and readmit != "never" \
                and on_disconnect == "recompute":
            # legacy policy: the pruned AT (even after re-admission)
            # cannot route some pooled flow along stored/exact fields --
            # give up on the incremental path, re-select everything
            fallback = True
        else:
            routable = np.nonzero(kv.any(axis=1))[0]
            # same min-max tie-break base as the selection engines:
            # strictly larger than any sum-of-loads along one path
            BIG = np.int64(F) * max(int(flen_all.max()), 1) + 1
            t0 = time.time()
            pchosen = _greedy_assign(loads, cand, kv, routable, rng,
                                     SEN, BIG, block)
            t_greedy += time.time() - t0
            # the sharded engine's refinement primitive over the pool
            t0 = time.time()
            if local_search_rounds > 0 and len(routable):
                lm_before = int(loads[:SEN].max())
                loads, sub_chosen = _refine_candidates(
                    loads, cand[routable], kv[routable],
                    pchosen[routable].copy(), rng, SEN, BIG,
                    local_search_rounds, refine_block, lm_before)
                pchosen[routable] = sub_chosen
            t_refine += time.time() - t0
            table = _rebuild_table(table, pool, pool_hop_idx, plens,
                                   kv, cand, vcs, pchosen, SEN)
    else:
        stats["residual"] = 0
        table = state.table.copy()

    if fallback:
        # full re-selection + allocation on the pruned AT -- same
        # channel-id space, full recompute semantics
        t0 = time.time()
        routed = select_paths(at2, K=K, seed=state.seed,
                              engine="sharded", dead_channels=dead_all)
        table = routed.table
        loads = np.zeros(SEN + 1, np.int64)
        loads[:SEN] = routed.loads.astype(np.int64)
        counts = allocate_vcs(at2, table)
        unreachable = routed.unreachable
        stats["fallback_s"] = round(time.time() - t0, 3)
    elif len(pool):
        # ---- stage C: streamed VC re-repair over the pool -----------------
        t0 = time.time()
        counts = reallocate_vcs(at2, table, pool, counts)
        t_vc += time.time() - t0

    t0 = time.time()
    if verify == "full" or fallback:
        deadlock_free = verify_deadlock_free(at2, table)
    elif len(pool):
        deadlock_free = verify_flows_deadlock_free(at2, table, pool)
    else:
        deadlock_free = True
    stats["verify_s"] = round(time.time() - t0, 3)

    stats.update({"walk_s": round(t_walk, 3), "bfs_s": round(t_bfs, 3),
                  "readmit_s": round(t_readmit, 3),
                  "greedy_s": round(t_greedy, 3),
                  "refine_s": round(t_refine, 3),
                  "vc_s": round(t_vc, 3)})
    if not store_copied and not fallback:
        dist_store, best_store = state.dist, state.best
    if fallback:
        # the fallback re-selection renumbers flows (unreachable pairs
        # get no entry), so the flow-id bookkeeping resets
        lost2 = np.zeros(0, np.int64)
        touched2 = np.zeros(0, np.int64)
    elif len(pool):
        routable_m = kv.any(axis=1)
        lost2 = np.union1d(state.lost, pool[~routable_m])
        touched2 = np.union1d(state.touched, pool[routable_m])
    else:
        lost2, touched2 = state.lost, state.touched
    stats["lost"] = int(len(lost2))
    new_state = ServingState(state.topo, at2, table, loads, counts,
                             dead_all, dist_store, best_store, K,
                             state.seed, stats=state.stats, lost=lost2,
                             touched=touched2, at0=state.at0)
    stats["total_s"] = round(time.time() - t_all, 3)
    return RepairResult(new_state, flows_rerouted=len(pool),
                        l_max=float(loads[:SEN].max()),
                        unreachable=unreachable,
                        deadlock_free=bool(deadlock_free),
                        fallback=fallback, readmitted=readmitted,
                        stats=stats, lost=int(len(lost2)),
                        pool_flows=pool)


def restore_channels(state: ServingState, channels, rebalance: bool = True,
                     local_search_rounds: int = 1, refine_block: int = 192,
                     verify: str = "pool", block: int = 4096,
                     bfs_chunk: int = 1024) -> RepairResult:
    """Heal a live :class:`ServingState` after ``channels`` come back --
    the inverse of :func:`repair_fault`. Pure like the repair: the
    input state is never mutated.

    Revived turns re-enter admission incrementally: a *partial* heal
    rebuilds the dead-turn mask from the surviving dead set and resumes
    the batched engine over the saved snapshot (:func:`_readmit`, saved
    level numbering, acyclic by construction); a *full* heal (nothing
    left dead) swaps back the pristine cold admission kept on
    ``ServingState.at0`` -- the exact pre-fault allowed set, so
    reachability recovery is exact by construction, with no replay.

    The re-route pool is ``state.lost`` (pairs parked by degraded-mode
    repairs -- they re-route against fresh exact distances) plus, with
    ``rebalance=True``, ``state.touched``: every flow detoured during
    the fault epoch re-routes so load concentrated on detours relaxes
    back toward a cold rebuild's balance. On a full heal the touched
    set is pooled regardless -- those are the only flows that can ride
    turns re-admitted mid-fault, which the pristine admission does not
    contain. Untouched flows stay byte-identical.

    Channels not currently dead are counted in ``stats["not_dead"]``
    and ignored; out-of-range ids raise ``ValueError``.
    """
    t_all = time.time()
    stats: dict = {}
    at = state.at
    ch = at.channels
    n, n_vc = ch.n_nodes, at.n_vc
    SEN = ch.n
    K = state.K
    dc = _validated_dead(channels, SEN)
    revived = np.intersect1d(dc, state.dead)
    stats["not_dead"] = int(len(dc) - len(revived))
    dead_all = np.setdiff1d(state.dead, revived)
    dead_mask = np.zeros(SEN, bool)
    dead_mask[dead_all] = True
    dead_state = (dead_all[:, None] * n_vc
                  + np.arange(n_vc)).ravel() if len(dead_all) else \
        np.zeros(0, np.int64)
    full_heal = len(dead_all) == 0 and state.at0 is not None

    # ---- stage A: delta re-admission over the healed fabric ---------------
    t0 = time.time()
    readmitted = 0
    if not len(revived):
        at2 = at
    elif full_heal:
        at2 = state.at0
        stats["exact_heal"] = True
    else:
        at2 = _revived_at(at, dead_mask)
        readmitted = _readmit(at2)
    stats["readmit_s"] = round(time.time() - t0, 3)

    table = state.table
    F = table.n_flows
    flen_all = table.flow_len.astype(np.int64)
    pool = state.lost
    if rebalance or full_heal:
        pool = np.union1d(pool, state.touched)
    pool = pool.astype(np.int64)
    stats["pool"] = len(pool)
    stats["lost_before"] = int(len(state.lost))
    loads = state.loads.copy()
    counts = state.vc_counts.copy()
    dist_store, best_store = state.dist, state.best
    unreachable = 0
    t_walk = t_bfs = t_greedy = t_refine = t_vc = 0.0
    rng = np.random.default_rng(state.seed)
    lost2, touched2 = state.lost, state.touched

    if len(pool):
        src_all = table.flow_src.astype(np.int64)
        psrc, pdst = src_all[pool], table.dst[pool].astype(np.int64)
        pool_hop_idx = _pool_hop_ranges(table, pool)
        loads[:SEN] -= np.bincount(table.chan[pool_hop_idx],
                                   minlength=SEN)
        loads[SEN] = 0
        counts = counts - np.bincount(
            table.vc[pool_hop_idx].astype(np.int64), minlength=n_vc)

        # exact distance refresh for every pooled source: the stored
        # fields reflect the faulted fabric, and stale distances are
        # only sound on a *subgraph* -- healing grows the graph, so the
        # lost/touched walks need fresh exact BFS rows (copy-on-write)
        t0 = time.time()
        rsrcs = np.unique(psrc)
        dist_store = dist_store.copy()
        best_store = best_store.copy()
        d = _exact_bfs(at2, rsrcs, dead_all, chunk=bfs_chunk)
        b = node_distances(at2, rsrcs, dist=d)
        dist_store[rsrcs] = d.astype(np.int8)
        best_store[rsrcs] = b.astype(np.int16)
        t_bfs += time.time() - t0

        t0 = time.time()
        cand, vcs, kv, plens = _walk_pool_chunked(
            at2, dist_store, best_store, dead_state, psrc, pdst, K)
        t_walk += time.time() - t0
        routable_m = kv.any(axis=1)
        unreachable = int((~routable_m).sum())
        routable = np.nonzero(routable_m)[0]
        BIG = np.int64(F) * max(int(flen_all.max()),
                                int(plens.max(initial=1)), 1) + 1
        t0 = time.time()
        pchosen = _greedy_assign(loads, cand, kv, routable, rng, SEN,
                                 BIG, block)
        t_greedy += time.time() - t0
        t0 = time.time()
        if local_search_rounds > 0 and len(routable):
            lm_before = int(loads[:SEN].max())
            loads, sub_chosen = _refine_candidates(
                loads, cand[routable], kv[routable],
                pchosen[routable].copy(), rng, SEN, BIG,
                local_search_rounds, refine_block, lm_before)
            pchosen[routable] = sub_chosen
        t_refine += time.time() - t0
        table = _rebuild_table(table, pool, pool_hop_idx, plens, kv,
                               cand, vcs, pchosen, SEN)
        # ---- stage C: streamed VC re-allocation over the pool -------------
        t0 = time.time()
        counts = reallocate_vcs(at2, table, pool, counts)
        t_vc += time.time() - t0
        lost2 = pool[~routable_m]
        touched2 = np.union1d(state.touched, pool[routable_m])
    else:
        table = state.table.copy()

    t0 = time.time()
    if verify == "full":
        deadlock_free = verify_deadlock_free(at2, table)
    elif len(pool):
        deadlock_free = verify_flows_deadlock_free(at2, table, pool)
    else:
        deadlock_free = True
    stats["verify_s"] = round(time.time() - t0, 3)

    stats.update({"walk_s": round(t_walk, 3), "bfs_s": round(t_bfs, 3),
                  "greedy_s": round(t_greedy, 3),
                  "refine_s": round(t_refine, 3),
                  "vc_s": round(t_vc, 3), "lost": int(len(lost2))})
    new_state = ServingState(state.topo, at2, table, loads, counts,
                             dead_all, dist_store, best_store, K,
                             state.seed, stats=state.stats, lost=lost2,
                             touched=touched2, at0=state.at0)
    stats["total_s"] = round(time.time() - t_all, 3)
    return RepairResult(new_state, flows_rerouted=len(pool),
                        l_max=float(loads[:SEN].max()),
                        unreachable=unreachable,
                        deadlock_free=bool(deadlock_free),
                        fallback=False, readmitted=readmitted,
                        stats=stats, lost=int(len(lost2)),
                        restored=int(len(revived)), pool_flows=pool)


def full_recompute(state: ServingState, dead_channels=None
                   ) -> Tuple[RoutingResult, np.ndarray, ATResult]:
    """The repair oracle: prune the AT exactly like :func:`repair_fault`
    then re-select and re-allocate *every* flow from scratch in the same
    channel-id space. Returns ``(routed, vc_counts, at2)``; repair
    quality (post-repair ``l_max``) and recovery wall-clock are measured
    against this. Input ids are validated like :func:`repair_fault`."""
    dc = _validated_dead(dead_channels, state.at.channels.n)
    dead_all = np.union1d(state.dead, dc)
    dead_mask = np.zeros(state.at.channels.n, bool)
    dead_mask[dead_all] = True
    at2 = _pruned_at(state.at, dead_mask)
    routed = select_paths(at2, K=state.K, seed=state.seed,
                          engine="sharded", dead_channels=dead_all)
    counts = allocate_vcs(at2, routed.table)
    return routed, counts, at2
