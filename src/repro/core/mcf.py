"""Exact uniform-demand maximum concurrent flow via the LR metric LP.

For uniform all-pairs demand the LP dual of maximum concurrent flow is the
metric LP (Leighton-Rao):   lambda* = min  sum_{e in E} d_e
                            s.t.  sum_{i<j} d_ij >= 1,  d a semi-metric.
This is EXACT (the O(log n) gap applies to sparsest cut, not to MCF).
Conventions (calibrated against the paper's Appendix C): undirected edges of
capacity 1 shared by both directions, one demand per unordered pair; e.g.
PT 4x4x8 -> 1/128 = 0.00781.

One-leg reduction (paper 4.3.1 / Appendix A): triangle inequalities only for
(i,k) in E. Symmetry reduction (4.3.2): with an abelian automorphism group
(cube translations; full/twisted torus translations), variables collapse to
canonical pair classes and constraints to canonical sources.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core.lp import COOMatrix, LPResult, solve, solve_highs, solve_pdhg


class PairCanon:
    """Deterministic pair -> canonical-class mapping under an abelian
    permutation group (rows of ``perms`` = node permutations, incl. id)."""

    def __init__(self, perms: np.ndarray, n: int, directed: bool = False):
        if perms is None:
            perms = np.arange(n, dtype=np.int32)[None, :]
        self.perms = np.asarray(perms, np.int64)
        self.n = n
        self.directed = directed
        # canonical rep + canonicalising group element for every node
        self.node_canon = self.perms.min(axis=0)            # (n,)
        self.node_g = self.perms.argmin(axis=0)             # (n,)
        self.sources = np.unique(self.node_canon)

    def key(self, a, b):
        """Canonical class key for pair arrays (a, b)."""
        a = np.asarray(a, np.int64)
        b = np.asarray(b, np.int64)
        n = self.n
        k1 = self.node_canon[a] * n + self.perms[self.node_g[a], b]
        if self.directed:
            return k1
        k2 = self.node_canon[b] * n + self.perms[self.node_g[b], a]
        return np.minimum(k1, k2)


def _adjacency(edges: np.ndarray, n: int, directed: bool):
    out = [[] for _ in range(n)]
    for u, v in edges:
        out[int(u)].append(int(v))
        if not directed:
            out[int(v)].append(int(u))
    return out


def build_metric_lp(edges: np.ndarray, n: int,
                    perms: Optional[np.ndarray] = None,
                    directed: bool = False, pair_weight=None):
    """Returns (c, A, b, lo, hi, var_keys, canon).

    ``pair_weight(a_arr, b_arr) -> w`` generalises the normalisation to a
    weighted traffic matrix (beyond-paper: workload-shaped demand); weights
    must be invariant under ``perms`` when symmetry reduction is used."""
    pc = PairCanon(perms, n, directed)

    # all pair keys (chunked over sources to bound memory)
    all_nodes = np.arange(n, dtype=np.int64)
    uniq = set()
    edge_keys = pc.key(edges[:, 0], edges[:, 1])
    uniq.update(edge_keys.tolist())
    # normalisation weights need every pair's key count
    key_count: dict = {}
    for a0 in range(0, n, max(1, 4096 * 4096 // n)):
        a1 = min(n, a0 + max(1, 4096 * 4096 // n))
        aa = np.repeat(all_nodes[a0:a1], n)
        bb = np.tile(all_nodes, a1 - a0)
        mask = aa != bb
        if not directed:
            mask &= aa < bb
        kk = pc.key(aa[mask], bb[mask])
        if pair_weight is None:
            ks, cnt = np.unique(kk, return_counts=True)
        else:
            w = pair_weight(aa[mask], bb[mask])
            ks = np.unique(kk)
            cnt = np.zeros(len(ks))
            pos = np.searchsorted(ks, kk)
            np.add.at(cnt, pos, w)
        for k, c_ in zip(ks.tolist(), cnt.tolist()):
            key_count[k] = key_count.get(k, 0) + c_
    uniq.update(key_count.keys())

    var_keys = np.array(sorted(uniq), np.int64)
    vidx = {k: i for i, k in enumerate(var_keys.tolist())}
    nv = len(var_keys)

    # objective: edge-count per class (each undirected edge counted once)
    c = np.zeros(nv)
    ks, cnt = np.unique(edge_keys, return_counts=True)
    for k, c_ in zip(ks.tolist(), cnt.tolist()):
        c[vidx[k]] += c_

    rows, cols, vals = [], [], []
    b = []
    # normalisation: -sum w_N d <= -1
    for k, c_ in key_count.items():
        rows.append(0)
        cols.append(vidx[k])
        vals.append(-float(c_))
    b.append(-1.0)

    # triangle rows: canonical sources s, all j, k in N(s) -- vectorised
    adj = _adjacency(edges, n, directed)
    vmap = np.full(int(var_keys.max()) + 1, -1, np.int64)
    vmap[var_keys] = np.arange(nv)
    rows = [np.asarray(rows, np.int64)]
    cols = [np.asarray(cols, np.int64)]
    vals = [np.asarray(vals, np.float64)]
    r = 1
    for s in pc.sources.tolist():
        for k in adj[s]:
            js = np.arange(n, dtype=np.int64)
            js = js[(js != s) & (js != k)]
            m = len(js)
            kij = vmap[pc.key(np.full(m, s), js)]
            kik = vmap[pc.key(np.array([s]), np.array([k]))[0]]
            kkj = vmap[pc.key(np.full(m, k), js)]
            rr = np.arange(r, r + m, dtype=np.int64)
            rows.append(np.repeat(rr, 3))
            cols.append(np.stack([kij, np.full(m, kik), kkj], 1).ravel())
            vals.append(np.tile([1.0, -1.0, -1.0], m))
            r += m
    b = np.concatenate([np.asarray(b), np.zeros(r - 1)])
    A = COOMatrix.from_triplets(np.concatenate(rows), np.concatenate(cols),
                                np.concatenate(vals), (r, nv))
    lo = np.zeros(nv)
    hi = np.ones(nv)
    return c, A, np.array(b), lo, hi, var_keys, pc


def mcf_uniform(edges: np.ndarray, n: int,
                perms: Optional[np.ndarray] = None,
                directed: bool = False, prefer: str = "auto",
                pair_weight=None, **kw) -> Tuple[float, LPResult]:
    """Exact MCF of a fixed graph (uniform or weighted demand)."""
    c, A, b, lo, hi, _, _ = build_metric_lp(edges, n, perms, directed,
                                            pair_weight=pair_weight)
    res = solve(c, A, b, lo, hi, prefer=prefer, **kw)
    return float(res.obj), res


def mcf_topology(topo, perms: Optional[np.ndarray] = None,
                 prefer: str = "auto", **kw) -> float:
    from repro.core.topology import cube_translations
    if perms is None:
        perms = cube_translations(topo.pod)
    lam, _ = mcf_uniform(topo.edges(), topo.n, perms=perms, prefer=prefer,
                         **kw)
    return lam


def mcf_upper_bound_basu(n: int, r: int = 6) -> float:
    """Basu et al. theoretical bound: lambda <= r / (n log_r n) (Fig. 3)."""
    return r / (n * (np.log(n) / np.log(r)))
