"""Cycle-level network simulator, vectorised and jitted in JAX.

Replaces CNSim (paper Section 6.1) for this container: synchronous
packet-granularity wormhole approximation with per-(channel, VC) FIFOs,
round-robin VC arbitration, one packet serviced per channel per cycle and
one packet accepted per queue per cycle (crossbar constraint; losers
stall and retry), static single-path routing tables and per-hop VC
assignments from the AT pipeline.

The default kernel is *CSR-native* (``kernel="csr"``): packet words carry
a routed-flow id, and next-channel/next-VC lookups gather from the
``CSRPathTable``'s concatenated hop array via ``hop_indptr[flow] + hop``
indexing. Peak simulator memory therefore scales with total routed hops
(O(H), ~73 MB at 12^3) instead of the dense ``(n, n, MAXHOP)`` gather
tables (O(n^2 * MAXHOP), ~480 MB at 12^3 and ~3.4 GB at 16^3, which also
exceeds the dense packet word's 12-bit node fields). The legacy dense
kernel survives as ``kernel="dense"``: it consumes the same flow-slot
traffic tables and the same RNG stream, so its per-rate counters are
bit-identical to the CSR kernel's -- the equivalence oracle exercised by
``tests/test_netsim_csr.py``. Keep the two cycle bodies in lockstep.

Traffic is pluggable (:class:`repro.core.traffic.TrafficPattern`): demand
matrices compile onto the table's flow slots
(:class:`repro.core.traffic.CompiledFlowTraffic`, O(F) alias tables), so
uniform-random, permutation, hotspot and demand-driven patterns all share
one compiled simulator. Demand on unrouted pairs is dropped at compile
time (rows renormalise over routed flows). Injection-rate sweeps run all
rates in one batched device execution (lane-flattened rather than
``jax.vmap``-ed -- see :func:`_sweep_csr`) instead of a Python loop of
per-rate jit calls.

Accounting: ``delivered`` is the measurement-window consumption rate (the
steady-state throughput estimator -- arrivals of warmup-injected packets
cancel the still-in-flight tail). Packets injected during the window are
additionally tagged, and ``delivered_tagged`` counts only those arrivals,
so ``delivered_tagged <= accepted <= offered`` holds exactly;
``injected_total`` / ``consumed_total`` / ``in_flight`` (whole run)
satisfy packet conservation ``injected == consumed + in_flight``.
Saturation = largest rate whose delivered throughput tracks the offered
rate (CNSim's first-timeout criterion, in deficit form).

Defaults follow Table 2 where representable at packet granularity
(radix 6, 2 escape VCs of the 4 total, buffering in packet slots).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.pathtable import MAXHOP, CSRPathTable, PathTable
from repro.core.routing import (ATResult, Channels, RoutingResult,
                                _dead_channel_array)
from repro.core.topology import Topology
from repro.core.traffic import (CompiledFlowTraffic, CompiledTraffic,
                                PhasedTraffic, TrafficPattern,
                                compile_flow_traffic)


@dataclasses.dataclass
class SimTables:
    """Static routing tables for the simulator.

    Accepts either path-table layout and keeps it as-is in ``table``;
    the CSR form is what the default simulator kernel consumes directly,
    so a 12^3/16^3 route-and-simulate pipeline never materialises the
    ``n^2 * MAXHOP`` arrays. Conversions are cached on the side:
    :meth:`csr` packs a dense table once, :meth:`dense` (and the
    ``path``/``vcs``/``hops`` views, kept for the dense kernel and
    API-edge consumers) densifies a CSR table once.
    """
    n: int
    n_ch: int
    n_vc: int
    ch_dst: np.ndarray                  # (C,)
    table: Union[PathTable, CSRPathTable]
    _dense_cache: Optional[PathTable] = \
        dataclasses.field(default=None, repr=False)
    _csr_cache: Optional[CSRPathTable] = \
        dataclasses.field(default=None, repr=False)

    def dense(self) -> PathTable:
        if isinstance(self.table, PathTable):
            return self.table
        if self._dense_cache is None:
            self._dense_cache = self.table.to_dense()
        return self._dense_cache

    def csr(self) -> CSRPathTable:
        if isinstance(self.table, CSRPathTable):
            return self.table
        if self._csr_cache is None:
            self._csr_cache = CSRPathTable.from_dense(self.table)
        return self._csr_cache

    @property
    def path(self) -> np.ndarray:
        return self.dense().path

    @property
    def vcs(self) -> np.ndarray:
        return self.dense().vcs

    @property
    def hops(self) -> np.ndarray:
        return self.dense().hops


def build_tables(topo: Topology,
                 table: Union[PathTable, CSRPathTable, RoutingResult]
                 ) -> SimTables:
    """Packed path table (or a RoutingResult carrying one) -> SimTables.

    No per-pair python loops: the table arrives already packed from path
    selection / DOR construction / VC allocation, in either the dense or
    the CSR layout.
    """
    if isinstance(table, RoutingResult):
        table = table.table
    ch = Channels.from_topology(topo)
    if table.n_ch != ch.n:
        raise ValueError(f"table built for {table.n_ch} channels, "
                         f"topology has {ch.n}")
    return SimTables(table.n, ch.n, table.n_vc, ch.dst.astype(np.int32),
                     table)


# ---------------------------------------------------------------------------
# Jitted kernels: all injection rates batched as lane-flattened simulations
# ---------------------------------------------------------------------------


# Packet word layouts (one int32 per packet; packing all attributes into
# one word turns the four per-attribute scatter updates of the seed
# kernel into a single scatter -- scatters serialise on CPU and dominated
# the vmapped sweep's wall-clock):
#
#   dense kernel:  src[0:12] | dst[12:24] | hop[24:30] | tag[30]
#                  (n <= 4095 -- the dense kernel cannot pack 16^3)
#   csr kernel:    flow[0:24] | hop[24:30] | tag[30]
#                  (F <= 2^24 - 1; all-pairs 16^3 = 4096*4095 still fits)
#
# MAXHOP <= 63 for both; checked in `sweep`.
_SRC_BITS = 12
_DST_SHIFT = 12
_HOP_SHIFT = 24
_TAG_SHIFT = 30
_FIELD_MASK = (1 << 12) - 1
_HOP_MASK = (1 << 6) - 1
_FLOW_MASK = (1 << 24) - 1


def _pack(src, dst, hop, tag):
    return (src | (dst << _DST_SHIFT) | (hop << _HOP_SHIFT)
            | (tag.astype(jnp.int32) << _TAG_SHIFT))


def _pack_flow(flow, hop, tag):
    return (flow | (hop << _HOP_SHIFT)
            | (tag.astype(jnp.int32) << _TAG_SHIFT))


@partial(jax.jit, static_argnames=("R", "n", "n_ch", "n_vc", "slots",
                                   "cycles", "warmup", "flits", "adaptive",
                                   "faulted", "bursty", "patience",
                                   "watchdog", "D", "period", "on_cycles",
                                   "T", "phased", "p_period"))
def _sweep_csr(ch_dst, pvf, hptr, lenm1, dstN, src_ptr, deg, fprob, falias,
               src_rate, rates, key, outch, minmask, esc, alive, t_fault,
               g_on, g_off, phase, tof, tmap, phase_of, *, R, n, n_ch, n_vc,
               slots, cycles, warmup, flits, adaptive=False, faulted=False,
               bursty=False, patience=64, watchdog=512, D=1, period=0,
               on_cycles=0, T=0, phased=False, p_period=1):
    """R independent simulations (one per injection rate) in one compiled
    execution, gathering routes from the CSR hop arrays.

    The batch is *lane-flattened* rather than ``jax.vmap``-ed: lane ``l``'s
    queue (c, v) lives at flat row ``l*NQ + c*n_vc + v``, so every update
    in the cycle body stays an ordinary rank-1 gather/scatter. (A vmapped
    version was measured first: XLA CPU lowers batched scatter/sort so
    poorly that it ran slower than the sequential python loop. Because the
    flat queue id factors as ``fc * n_vc + v`` with ``fc = l*C + c``, the
    single-lane arbitration/rank formulas carry over verbatim.)

    Route lookups are flow-native: a head word's next (channel, VC) is
    ``pvf[hptr[flow] + hop + 1]`` and it consumes when ``hop`` reaches
    ``lenm1[flow]`` -- no (n, n, MAXHOP) arrays anywhere. ``pvf`` packs
    ``channel * n_vc + vc`` per hop (one gather serves both fields).

    Extension flags (all python-static, so the default trace -- and its
    counters -- is bit-identical to the plain static kernel):

    - ``adaptive``: heads on VCs >= 1 pick among the minimal alternates
      of ``outch``/``minmask`` by downstream adaptive-VC free space and
      divert to the escape lane (VC 0, routed by ``esc``) after
      ``patience`` stalled cycles or when no live alternate exists;
      VC 0 heads always follow the escape tree. ``dstN`` maps flow ->
      destination node (consumption becomes node-arrival, not
      hop-count).
    - ``faulted``: channels with ``alive[1, c] == 0`` stop accepting
      forwards/injections from cycle ``t_fault`` on (their queues still
      drain -- the buffer sits at the receiving node); tables indexed
      ``[ph]`` switch from the pre- to the post-fault plane.
    - ``bursty``: injection thresholds are modulated by the
      mean-preserving on/off gains (``g_on``/``g_off``) on a
      ``period``-cycle schedule offset per source by ``phase``.
    - watchdog (always on): a lane with packets in flight that neither
      pops nor injects for ``watchdog`` consecutive cycles is marked
      stalled (``stalled_at`` = cycle of detection); when *every* lane
      is stalled the sweep aborts early instead of spinning out the
      budget.
    - ``phased`` (trace replay): ``fprob``/``falias``/``src_rate`` carry
      a leading phase axis and ``phase_of[i % p_period]`` selects the
      active demand phase each cycle -- same RNG draw count as the
      stationary path, so a single-phase schedule is bit-identical.
    - ``T > 0`` (multi-tenant): ``tof`` maps flow -> tenant id (-1 =
      none) and the kernel keeps per-(lane, tenant) injected / consumed
      / consumed-in-window counters plus end-of-run queued words, giving
      exact per-tenant conservation (injected == consumed + in-flight).
      No extra RNG draws, so the default trace is unchanged when off.
    """
    C = R * n_ch                    # flat channels across lanes
    NQ = C * n_vc                   # flat queues across lanes
    N = R * n                       # flat sources across lanes
    H = pvf.shape[0]

    # queue state: per-(lane, channel, vc) ring buffers of packed words
    q = jnp.zeros((NQ, slots), jnp.int32)
    head = jnp.zeros((NQ,), jnp.int32)
    size = jnp.zeros((NQ,), jnp.int32)
    rr = jnp.zeros((C,), jnp.int32)
    busy = jnp.zeros((C,), jnp.int32)   # flit-serialisation countdown

    srcs = jnp.tile(jnp.arange(n), R)            # local node ids per lane
    lane_q = (jnp.arange(N) // n) * (n_ch * n_vc)
    if T:
        word_tenant = lambda w: tof[w & _FLOW_MASK]   # noqa: E731
    if not phased:
        thresh = (rates[:, None] * src_rate[None, :]).reshape(N)
    if bursty:
        phs = jnp.tile(phase, R)                 # (N,) per-source offsets
    if adaptive:
        node_q = jnp.tile(ch_dst, R)[jnp.arange(NQ) // n_vc]
        vc_q = jnp.arange(NQ) % n_vc
        qrows = jnp.arange(NQ)

    def cycle(carry):
        i, q, head, size, rr, busy, key, stall, wstall, stalled_at, \
            stats = carry
        (offered, accepted, tagged, consumed_meas, consumed, injected,
         escaped, inj_t, cons_t, consm_t) = stats
        ph = (i >= t_fault).astype(jnp.int32) if faulted else 0
        phz = phase_of[i % p_period] if phased else 0

        # ---- head packet per (lane, channel, vc) --------------------------
        hw = q[jnp.arange(NQ), head]
        hf = hw & _FLOW_MASK
        hh = (hw >> _HOP_SHIFT) & _HOP_MASK
        nonempty = size > 0

        lane_base = (jnp.arange(NQ) // (n_ch * n_vc)) * (n_ch * n_vc)
        if adaptive:
            # consume on destination arrival; next hop chosen live among
            # the minimal alternates by downstream adaptive free space,
            # escape lane (VC0 over the tree) as the safe fallback
            dq = dstN[hf]
            consume_q = nonempty & (node_q == dq)
            cand_ch = jnp.clip(outch[node_q], 0, n_ch - 1)     # (NQ, D)
            mm = minmask[ph, node_q, dq]
            ok_cand = ((mm[:, None] >> jnp.arange(D)[None, :]) & 1) > 0
            if faulted:
                ok_cand = ok_cand & (alive[ph, cand_ch] > 0)
            # free space of the queue the packet would actually join:
            # its destination-bound adaptive VC on each candidate channel
            vq = (1 + dq % (n_vc - 1))[:, None]
            occ = size[lane_base[:, None] + cand_ch * n_vc + vq]
            score = jnp.where(ok_cand, slots - occ, -1)
            # rotate tie-breaks per (queue, cycle): equal scores would
            # otherwise herd every packet at a node onto one alternate
            rot = (jnp.arange(D)[None, :] + qrows[:, None] + i) % D
            j = jnp.argmax(score * D + rot, axis=1)
            best_ch = cand_ch[qrows, j]
            has_cand = score[qrows, j] >= 0
            # destination-bound adaptive VC: confines any one endpoint's
            # backlog to a single VC per channel, so victim flows keep
            # the other adaptive VCs (least-occupied selection was
            # measured to level-fill every VC with hotspot backlog and
            # collapse total throughput well below the static tables)
            bv = 1 + dq % (n_vc - 1)
            # planned-path-first: a packet still on its static path keeps
            # it while the destination-bound queue ahead has room -- the
            # LP-balanced tables confine backlog to the same narrow cones
            # static routing does -- and only overflows onto the freest
            # minimal alternate (off-path and post-fault packets route
            # fully adaptively)
            my_ch = (qrows // n_vc) % n_ch
            on_path = (hh <= lenm1[hf]) \
                & (pvf[jnp.minimum(hptr[hf] + hh, H - 1)] // n_vc
                   == my_ch)
            chan_s = pvf[jnp.minimum(hptr[hf] + hh + 1, H - 1)] // n_vc
            prim_occ = size[lane_base + chan_s * n_vc + bv]
            best_occ = slots - score[qrows, j]    # slots + 1 when no cand
            prim_take = on_path & ~consume_q & (prim_occ < slots) \
                & (prim_occ <= best_occ + 4)
            if faulted:
                prim_take = prim_take & (alive[ph, chan_s] > 0)
            use_esc = (vc_q == 0) | (stall >= patience) \
                | (~has_cand & ~prim_take)
            e_ch = esc[ph, node_q, dq]
            nxt_ch = jnp.where(use_esc, e_ch,
                               jnp.where(prim_take, chan_s, best_ch))
            nxt_vc = jnp.where(use_esc, 0, bv)
            valid = nxt_ch >= 0
            if faulted:
                valid = valid & (alive[ph, jnp.clip(nxt_ch, 0,
                                                    n_ch - 1)] > 0)
            tq = jnp.where(consume_q | ~valid, -1,
                           lane_base
                           + jnp.clip(nxt_ch, 0, n_ch - 1) * n_vc
                           + nxt_vc)
            fwd_ok = nonempty & ~consume_q & (tq >= 0) \
                & (size[jnp.clip(tq, 0, NQ - 1)] < slots)
        else:
            consume_q = nonempty & (hh == lenm1[hf])
            nxt = pvf[jnp.minimum(hptr[hf] + hh + 1, H - 1)]
            tq = jnp.where(consume_q, -1, lane_base + nxt)
            if faulted:
                # dead next hop: the packet waits in place (and the
                # watchdog eventually reports the wedged lane)
                tq = jnp.where(alive[ph, nxt // n_vc] > 0, tq, -1)
                fwd_ok = nonempty & ~consume_q & (tq >= 0) \
                    & (size[jnp.clip(tq, 0, NQ - 1)] < slots)
            else:
                fwd_ok = nonempty & ~consume_q \
                    & (size[jnp.clip(tq, 0, NQ - 1)] < slots)
        eligible = consume_q | fwd_ok                   # per (c, v)

        # ---- round-robin arbitration: one vc per channel ------------------
        # multi-flit packets occupy the link for `flits` cycles
        eligible = eligible & jnp.repeat(busy == 0, n_vc)
        elig_cv = eligible.reshape(C, n_vc)
        offs = (rr[:, None] + jnp.arange(n_vc)[None, :]) % n_vc
        pri = jnp.take_along_axis(elig_cv, offs, axis=1)
        first = jnp.argmax(pri, axis=1)
        any_e = pri.any(axis=1)
        win_v = (rr + first) % n_vc
        win_q = jnp.arange(C) * n_vc + win_v             # (C,)
        win_valid = any_e
        rr = jnp.where(win_valid, (win_v + 1) % n_vc, rr)

        w_word = hw[win_q]
        w_tag = (w_word >> _TAG_SHIFT) & 1
        w_consume = consume_q[win_q] & win_valid
        w_target = jnp.where(win_valid & ~w_consume, tq[win_q], -1)

        # ---- crossbar constraint: one push per target queue per cycle ----
        # (a router output accepts one packet from the crossbar per cycle;
        # the lowest-id input wins, losers stall and retry next cycle).
        # Targets never collide across lanes: flat queue ids are disjoint.
        cand = win_valid & ~w_consume & (w_target >= 0)
        tgt = jnp.clip(w_target, 0, NQ - 1)
        first = jnp.full((NQ + 1,), C, jnp.int32) \
            .at[jnp.where(cand, tgt, NQ)].min(jnp.arange(C, dtype=jnp.int32))
        w_push = cand & (first[tgt] == jnp.arange(C))
        w_pop = w_consume | w_push
        busy = jnp.where(w_pop, flits - 1, jnp.maximum(busy - 1, 0))

        # ---- push slots ----------------------------------------------------
        # post-pop (head + size) equals pre-pop (head + size): a pop moves
        # head forward and shrinks size by one, so the tail slot is stable
        p_slot = (head[tgt] + size[tgt]) % slots
        if adaptive:
            # adaptive paths are not length-bounded by the route table, so
            # saturate the 6-bit hop field instead of wrapping into the tag
            w_hh = (w_word >> _HOP_SHIFT) & _HOP_MASK
            push_word = jnp.where(w_hh >= _HOP_MASK, w_word,
                                  w_word + (1 << _HOP_SHIFT))
        else:
            push_word = w_word + (1 << _HOP_SHIFT)  # hop += 1, rest intact

        # ---- injection: alias-sampled routed flow per source --------------
        measure = i >= warmup
        key, k1, k2, k3 = jax.random.split(key, 4)
        if phased:
            thr = (rates[:, None] * src_rate[phz][None, :]).reshape(N)
            fp, fa = fprob[phz], falias[phz]
        else:
            thr, fp, fa = thresh, fprob, falias
        if bursty:
            on = ((i + phs) % period) < on_cycles
            want = jax.random.uniform(k1, (N,)) \
                < thr * jnp.where(on, g_on, g_off)
        else:
            want = jax.random.uniform(k1, (N,)) < thr
        u1 = jax.random.uniform(k2, (N,))
        dg = deg[srcs]
        j = jnp.minimum((u1 * dg.astype(jnp.float32)).astype(jnp.int32),
                        dg - 1)
        f0 = src_ptr[srcs] + jnp.maximum(j, 0)
        u2 = jax.random.uniform(k3, (N,))
        fid = jnp.where(u2 < fp[f0], f0, fa[f0])
        cv0 = pvf[hptr[fid]]
        if adaptive or faulted:
            ch0 = cv0 // n_vc
            ok0 = (alive[ph, ch0] > 0) if faulted \
                else jnp.ones((N,), bool)
            if adaptive:
                # the stored VC is a static-mode artifact: inject onto
                # the planned channel's destination-bound adaptive VC
                # (sources can always wait, so injection never needs the
                # escape guarantee). Planned first hop dead: inject
                # straight onto the escape tree; no escape route -> hold.
                dstf = dstN[fid]
                iv = 1 + dstf % (n_vc - 1)
                e0 = esc[ph, srcs, dstf]
                cv0 = jnp.where(ok0, ch0 * n_vc + iv,
                                jnp.maximum(e0, 0) * n_vc)
                ok0 = ok0 | (e0 >= 0)
            iq = lane_q + cv0
        else:
            iq = lane_q + cv0
        # queue iq was popped this cycle iff its channel's winner is iq
        i_pop = (w_pop[iq // n_vc]
                 & (win_q[iq // n_vc] == iq)).astype(jnp.int32)
        # at most one push lands in iq this cycle (crossbar constraint)
        i_push = (first[iq] < C).astype(jnp.int32)
        has_space = size[iq] - i_pop + i_push < slots
        inj = want & has_space & (dg > 0)
        if adaptive or faulted:
            inj = inj & ok0
        i_slot = (head[iq] + size[iq] + i_push) % slots
        inj_word = _pack_flow(fid, jnp.zeros((N,), jnp.int32),
                              measure & inj)

        # ---- one fused scatter for pushes + injections --------------------
        all_rows = jnp.concatenate([jnp.where(w_push, tgt, NQ),
                                    jnp.where(inj, iq, NQ)])
        all_slots = jnp.concatenate([p_slot, i_slot])
        all_words = jnp.concatenate([push_word, inj_word])
        q = q.at[all_rows, all_slots].set(all_words, mode="drop")

        # ---- one fused scatter-add for every size delta, one for heads ----
        popq = jnp.where(w_pop, win_q, NQ)
        d_rows = jnp.concatenate([popq, all_rows])
        d_vals = jnp.concatenate([jnp.full((C,), -1, jnp.int32),
                                  jnp.ones((C + N,), jnp.int32)])
        size = size.at[d_rows].add(d_vals, mode="drop")
        head = head.at[popq].add(1, mode="drop") % slots

        meas = jnp.where(measure, 1, 0)
        cons_lane = w_consume.reshape(R, n_ch).sum(axis=1)
        inj_lane = inj.reshape(R, n).sum(axis=1)
        offered = offered + meas * want.reshape(R, n).sum(axis=1)
        accepted = accepted + meas * inj_lane
        tagged = tagged + (w_consume & (w_tag == 1)).reshape(
            R, n_ch).sum(axis=1)
        consumed_meas = consumed_meas + meas * cons_lane
        consumed = consumed + cons_lane
        injected = injected + inj_lane

        if T:
            # per-(lane, tenant) accounting; flow -> tenant is static
            # (`tof`), so attribution costs two gathers and two
            # scatter-adds, no extra RNG
            t_w = tof[hf[win_q]]
            ok_w = w_consume & (t_w >= 0)
            rowc = (jnp.arange(C) // n_ch) * T + jnp.clip(t_w, 0, T - 1)
            cons_t = cons_t.at[rowc].add(ok_w.astype(jnp.int32))
            consm_t = consm_t.at[rowc].add(
                (ok_w & measure).astype(jnp.int32))
            t_i = tof[fid]
            rowi = (jnp.arange(N) // n) * T + jnp.clip(t_i, 0, T - 1)
            inj_t = inj_t.at[rowi].add(
                (inj & (t_i >= 0)).astype(jnp.int32))

        if adaptive:
            # per-queue persistent-stall counter (drives escape diversion)
            popped = w_pop[qrows // n_vc] & (win_q[qrows // n_vc] == qrows)
            stall = jnp.where(nonempty & ~popped, stall + 1, 0)
            # escape diversions: pushes that land on VC0 from a VC >= 1
            escaped = escaped + (w_push & (tgt % n_vc == 0)
                                 & (win_q % n_vc != 0)).reshape(
                R, n_ch).sum(axis=1)

        # ---- watchdog: lanes with traffic but zero forward progress -------
        pop_lane = w_pop.reshape(R, n_ch).sum(axis=1)
        progress = (pop_lane > 0) | (inj_lane > 0)
        wstall = jnp.where((injected - consumed > 0) & ~progress,
                           wstall + 1, 0)
        stalled_at = jnp.where((wstall >= watchdog) & (stalled_at < 0),
                               i, stalled_at)
        return (i + 1, q, head, size, rr, busy, key, stall, wstall,
                stalled_at,
                (offered, accepted, tagged, consumed_meas, consumed,
                 injected, escaped, inj_t, cons_t, consm_t))

    stats0 = (jnp.zeros((R,), jnp.int32),) * 7 \
        + (jnp.zeros((R * T,), jnp.int32),) * 3
    stall0 = jnp.zeros((NQ if adaptive else 1,), jnp.int32)
    carry = (jnp.int32(0), q, head, size, rr, busy, key, stall0,
             jnp.zeros((R,), jnp.int32), jnp.full((R,), -1, jnp.int32),
             stats0)

    def cond(carry):
        return (carry[0] < cycles) & ~jnp.all(carry[8] >= watchdog)

    carry = jax.lax.while_loop(cond, cycle, carry)
    q, head, size = carry[1], carry[2], carry[3]
    stalled_at = carry[9]
    (offered, accepted, tagged, consumed_meas, consumed, injected,
     escaped, inj_t, cons_t, consm_t) = carry[-1]
    if T:
        # per-tenant end-of-run occupancy from the final ring buffers:
        # slot j of queue r holds a live word iff (j - head) % slots
        # < size -- exact, so injected == consumed + in_flight per tenant
        occ = ((jnp.arange(slots)[None, :] - head[:, None]) % slots) \
            < size[:, None]
        tw = word_tenant(q)                             # (NQ, slots)
        rows = (jnp.arange(NQ) // (n_ch * n_vc))[:, None] * T \
            + jnp.clip(tw, 0, T - 1)
        infl_t = jnp.zeros((R * T,), jnp.int32) \
            .at[rows].add((occ & (tw >= 0)).astype(jnp.int32))
    else:
        infl_t = jnp.zeros((0,), jnp.int32)
    return (offered, accepted, tagged, consumed_meas, consumed, injected,
            escaped, size.reshape(R, -1).sum(axis=1), stalled_at,
            inj_t, cons_t, consm_t, infl_t, carry[0])


@partial(jax.jit, static_argnames=("R", "n", "n_ch", "n_vc", "slots",
                                   "cycles", "warmup", "flits", "adaptive",
                                   "faulted", "bursty", "patience",
                                   "watchdog", "D", "period", "on_cycles",
                                   "T", "phased", "p_period"))
def _sweep_dense(ch_dst, pv, fdst, src_ptr, deg, fprob, falias,
                 src_rate, rates, key, outch, minmask, esc, alive, t_fault,
                 g_on, g_off, phase, tof, tmap, phase_of, *, R, n, n_ch,
                 n_vc, slots, cycles, warmup, flits, adaptive=False,
                 faulted=False, bursty=False, patience=64, watchdog=512,
                 D=1, period=0, on_cycles=0, T=0, phased=False,
                 p_period=1):
    """Legacy dense-gather kernel: identical cycle body to
    :func:`_sweep_csr` (same RNG stream, same flow-slot sampling, same
    arbitration) except route lookups gather from the dense
    ``(n, n, MAXHOP)`` composite table and packet words carry (src, dst)
    node ids. Kept as the bit-identity oracle for the CSR kernel -- edit
    the two cycle bodies in lockstep. The adaptive/faulted/bursty flags
    and the always-on watchdog mirror :func:`_sweep_csr` exactly (the
    dense word already carries the destination, so no ``dstN`` gather is
    needed).
    """
    C = R * n_ch
    NQ = C * n_vc
    N = R * n

    q = jnp.zeros((NQ, slots), jnp.int32)
    head = jnp.zeros((NQ,), jnp.int32)
    size = jnp.zeros((NQ,), jnp.int32)
    rr = jnp.zeros((C,), jnp.int32)
    busy = jnp.zeros((C,), jnp.int32)

    arrive_node = jnp.tile(ch_dst, R)[jnp.arange(NQ) // n_vc]
    srcs = jnp.tile(jnp.arange(n), R)
    lane_q = (jnp.arange(N) // n) * (n_ch * n_vc)
    if T:
        word_tenant = lambda w: tmap[w & _FIELD_MASK,   # noqa: E731
                                     (w >> _DST_SHIFT) & _FIELD_MASK]
    if not phased:
        thresh = (rates[:, None] * src_rate[None, :]).reshape(N)
    if bursty:
        phs = jnp.tile(phase, R)
    if adaptive:
        vc_q = jnp.arange(NQ) % n_vc
        qrows = jnp.arange(NQ)

    def cycle(carry):
        i, q, head, size, rr, busy, key, stall, wstall, stalled_at, \
            stats = carry
        (offered, accepted, tagged, consumed_meas, consumed, injected,
         escaped, inj_t, cons_t, consm_t) = stats
        ph = (i >= t_fault).astype(jnp.int32) if faulted else 0
        phz = phase_of[i % p_period] if phased else 0

        hw = q[jnp.arange(NQ), head]
        hs = hw & _FIELD_MASK
        hd = (hw >> _DST_SHIFT) & _FIELD_MASK
        hh = (hw >> _HOP_SHIFT) & _HOP_MASK
        nonempty = size > 0

        consume_q = nonempty & (arrive_node == hd)
        lane_base = (jnp.arange(NQ) // (n_ch * n_vc)) * (n_ch * n_vc)
        if adaptive:
            dq = hd
            cand_ch = jnp.clip(outch[arrive_node], 0, n_ch - 1)
            mm = minmask[ph, arrive_node, dq]
            ok_cand = ((mm[:, None] >> jnp.arange(D)[None, :]) & 1) > 0
            if faulted:
                ok_cand = ok_cand & (alive[ph, cand_ch] > 0)
            # free space of the queue the packet would actually join:
            # its destination-bound adaptive VC on each candidate channel
            vq = (1 + dq % (n_vc - 1))[:, None]
            occ = size[lane_base[:, None] + cand_ch * n_vc + vq]
            score = jnp.where(ok_cand, slots - occ, -1)
            rot = (jnp.arange(D)[None, :] + qrows[:, None] + i) % D
            j = jnp.argmax(score * D + rot, axis=1)    # rotating tie-break
            best_ch = cand_ch[qrows, j]
            has_cand = score[qrows, j] >= 0
            bv = 1 + dq % (n_vc - 1)    # destination-bound VC (see CSR)
            # planned-path-first, mirroring the CSR kernel
            my_ch = (qrows // n_vc) % n_ch
            pcur = pv[hs, hd, hh]
            on_path = (pcur >= 0) & (pcur // n_vc == my_ch)
            pnxt = pv[hs, hd, hh + 1]
            chan_s = jnp.clip(pnxt, 0, n_ch * n_vc - 1) // n_vc
            prim_occ = size[lane_base + chan_s * n_vc + bv]
            best_occ = slots - score[qrows, j]    # slots + 1 when no cand
            prim_take = on_path & (pnxt >= 0) & ~consume_q & (prim_occ < slots) \
                & (prim_occ <= best_occ + 4)
            if faulted:
                prim_take = prim_take & (alive[ph, chan_s] > 0)
            use_esc = (vc_q == 0) | (stall >= patience) \
                | (~has_cand & ~prim_take)
            e_ch = esc[ph, arrive_node, dq]
            nxt_ch = jnp.where(use_esc, e_ch,
                               jnp.where(prim_take, chan_s, best_ch))
            nxt_vc = jnp.where(use_esc, 0, bv)
            valid = nxt_ch >= 0
            if faulted:
                valid = valid & (alive[ph, jnp.clip(nxt_ch, 0,
                                                    n_ch - 1)] > 0)
            tq = jnp.where(consume_q | ~valid, -1,
                           lane_base
                           + jnp.clip(nxt_ch, 0, n_ch - 1) * n_vc
                           + nxt_vc)
            fwd_ok = nonempty & ~consume_q & (tq >= 0) \
                & (size[jnp.clip(tq, 0, NQ - 1)] < slots)
        else:
            # pv packs channel * n_vc + vc per hop: one gather for both
            nxt = pv[hs, hd, hh + 1]
            tq = jnp.where(consume_q, -1, lane_base + nxt)
            if faulted:
                tq = jnp.where(alive[ph, nxt // n_vc] > 0, tq, -1)
                fwd_ok = nonempty & ~consume_q & (tq >= 0) \
                    & (size[jnp.clip(tq, 0, NQ - 1)] < slots)
            else:
                fwd_ok = nonempty & ~consume_q \
                    & (size[jnp.clip(tq, 0, NQ - 1)] < slots)
        eligible = consume_q | fwd_ok

        eligible = eligible & jnp.repeat(busy == 0, n_vc)
        elig_cv = eligible.reshape(C, n_vc)
        offs = (rr[:, None] + jnp.arange(n_vc)[None, :]) % n_vc
        pri = jnp.take_along_axis(elig_cv, offs, axis=1)
        first = jnp.argmax(pri, axis=1)
        any_e = pri.any(axis=1)
        win_v = (rr + first) % n_vc
        win_q = jnp.arange(C) * n_vc + win_v
        win_valid = any_e
        rr = jnp.where(win_valid, (win_v + 1) % n_vc, rr)

        w_word = hw[win_q]
        w_tag = (w_word >> _TAG_SHIFT) & 1
        w_consume = consume_q[win_q] & win_valid
        w_target = jnp.where(win_valid & ~w_consume, tq[win_q], -1)

        cand = win_valid & ~w_consume & (w_target >= 0)
        tgt = jnp.clip(w_target, 0, NQ - 1)
        first = jnp.full((NQ + 1,), C, jnp.int32) \
            .at[jnp.where(cand, tgt, NQ)].min(jnp.arange(C, dtype=jnp.int32))
        w_push = cand & (first[tgt] == jnp.arange(C))
        w_pop = w_consume | w_push
        busy = jnp.where(w_pop, flits - 1, jnp.maximum(busy - 1, 0))

        p_slot = (head[tgt] + size[tgt]) % slots
        if adaptive:
            w_hh = (w_word >> _HOP_SHIFT) & _HOP_MASK
            push_word = jnp.where(w_hh >= _HOP_MASK, w_word,
                                  w_word + (1 << _HOP_SHIFT))
        else:
            push_word = w_word + (1 << _HOP_SHIFT)

        measure = i >= warmup
        key, k1, k2, k3 = jax.random.split(key, 4)
        if phased:
            thr = (rates[:, None] * src_rate[phz][None, :]).reshape(N)
            fp, fa = fprob[phz], falias[phz]
        else:
            thr, fp, fa = thresh, fprob, falias
        if bursty:
            on = ((i + phs) % period) < on_cycles
            want = jax.random.uniform(k1, (N,)) \
                < thr * jnp.where(on, g_on, g_off)
        else:
            want = jax.random.uniform(k1, (N,)) < thr
        u1 = jax.random.uniform(k2, (N,))
        dg = deg[srcs]
        j = jnp.minimum((u1 * dg.astype(jnp.float32)).astype(jnp.int32),
                        dg - 1)
        f0 = src_ptr[srcs] + jnp.maximum(j, 0)
        u2 = jax.random.uniform(k3, (N,))
        fid = jnp.where(u2 < fp[f0], f0, fa[f0])
        dsts = fdst[fid]
        cv0 = pv[srcs, dsts, 0]
        if adaptive or faulted:
            ch0 = jnp.clip(cv0, 0, n_ch * n_vc - 1) // n_vc
            ok0 = (alive[ph, ch0] > 0) if faulted \
                else jnp.ones((N,), bool)
            if adaptive:
                iv = 1 + dsts % (n_vc - 1)
                e0 = esc[ph, srcs, dsts]
                cv0 = jnp.where(ok0, ch0 * n_vc + iv,
                                jnp.maximum(e0, 0) * n_vc)
                ok0 = ok0 | (e0 >= 0)
            iq = lane_q + jnp.clip(cv0, 0, n_ch * n_vc - 1)
        else:
            iq = lane_q + jnp.clip(cv0, 0, n_ch * n_vc - 1)
        i_pop = (w_pop[iq // n_vc]
                 & (win_q[iq // n_vc] == iq)).astype(jnp.int32)
        i_push = (first[iq] < C).astype(jnp.int32)
        has_space = size[iq] - i_pop + i_push < slots
        inj = want & has_space & (dg > 0)
        if adaptive or faulted:
            inj = inj & ok0
        i_slot = (head[iq] + size[iq] + i_push) % slots
        inj_word = _pack(srcs, dsts, jnp.zeros((N,), jnp.int32),
                         measure & inj)

        all_rows = jnp.concatenate([jnp.where(w_push, tgt, NQ),
                                    jnp.where(inj, iq, NQ)])
        all_slots = jnp.concatenate([p_slot, i_slot])
        all_words = jnp.concatenate([push_word, inj_word])
        q = q.at[all_rows, all_slots].set(all_words, mode="drop")

        popq = jnp.where(w_pop, win_q, NQ)
        d_rows = jnp.concatenate([popq, all_rows])
        d_vals = jnp.concatenate([jnp.full((C,), -1, jnp.int32),
                                  jnp.ones((C + N,), jnp.int32)])
        size = size.at[d_rows].add(d_vals, mode="drop")
        head = head.at[popq].add(1, mode="drop") % slots

        meas = jnp.where(measure, 1, 0)
        cons_lane = w_consume.reshape(R, n_ch).sum(axis=1)
        inj_lane = inj.reshape(R, n).sum(axis=1)
        offered = offered + meas * want.reshape(R, n).sum(axis=1)
        accepted = accepted + meas * inj_lane
        tagged = tagged + (w_consume & (w_tag == 1)).reshape(
            R, n_ch).sum(axis=1)
        consumed_meas = consumed_meas + meas * cons_lane
        consumed = consumed + cons_lane
        injected = injected + inj_lane

        if T:
            # dense words carry (src, dst): attribute via the pair map
            # (tof[fid] == tmap[srcs, dsts] by construction, so the CSR
            # kernel's counters stay bit-identical)
            ws = w_word & _FIELD_MASK
            wd = (w_word >> _DST_SHIFT) & _FIELD_MASK
            t_w = tmap[ws, wd]
            ok_w = w_consume & (t_w >= 0)
            rowc = (jnp.arange(C) // n_ch) * T + jnp.clip(t_w, 0, T - 1)
            cons_t = cons_t.at[rowc].add(ok_w.astype(jnp.int32))
            consm_t = consm_t.at[rowc].add(
                (ok_w & measure).astype(jnp.int32))
            t_i = tof[fid]
            rowi = (jnp.arange(N) // n) * T + jnp.clip(t_i, 0, T - 1)
            inj_t = inj_t.at[rowi].add(
                (inj & (t_i >= 0)).astype(jnp.int32))

        if adaptive:
            popped = w_pop[qrows // n_vc] & (win_q[qrows // n_vc] == qrows)
            stall = jnp.where(nonempty & ~popped, stall + 1, 0)
            escaped = escaped + (w_push & (tgt % n_vc == 0)
                                 & (win_q % n_vc != 0)).reshape(
                R, n_ch).sum(axis=1)

        pop_lane = w_pop.reshape(R, n_ch).sum(axis=1)
        progress = (pop_lane > 0) | (inj_lane > 0)
        wstall = jnp.where((injected - consumed > 0) & ~progress,
                           wstall + 1, 0)
        stalled_at = jnp.where((wstall >= watchdog) & (stalled_at < 0),
                               i, stalled_at)
        return (i + 1, q, head, size, rr, busy, key, stall, wstall,
                stalled_at,
                (offered, accepted, tagged, consumed_meas, consumed,
                 injected, escaped, inj_t, cons_t, consm_t))

    stats0 = (jnp.zeros((R,), jnp.int32),) * 7 \
        + (jnp.zeros((R * T,), jnp.int32),) * 3
    stall0 = jnp.zeros((NQ if adaptive else 1,), jnp.int32)
    carry = (jnp.int32(0), q, head, size, rr, busy, key, stall0,
             jnp.zeros((R,), jnp.int32), jnp.full((R,), -1, jnp.int32),
             stats0)

    def cond(carry):
        return (carry[0] < cycles) & ~jnp.all(carry[8] >= watchdog)

    carry = jax.lax.while_loop(cond, cycle, carry)
    q, head, size = carry[1], carry[2], carry[3]
    stalled_at = carry[9]
    (offered, accepted, tagged, consumed_meas, consumed, injected,
     escaped, inj_t, cons_t, consm_t) = carry[-1]
    if T:
        # per-tenant end-of-run occupancy from the final ring buffers:
        # slot j of queue r holds a live word iff (j - head) % slots
        # < size -- exact, so injected == consumed + in_flight per tenant
        occ = ((jnp.arange(slots)[None, :] - head[:, None]) % slots) \
            < size[:, None]
        tw = word_tenant(q)                             # (NQ, slots)
        rows = (jnp.arange(NQ) // (n_ch * n_vc))[:, None] * T \
            + jnp.clip(tw, 0, T - 1)
        infl_t = jnp.zeros((R * T,), jnp.int32) \
            .at[rows].add((occ & (tw >= 0)).astype(jnp.int32))
    else:
        infl_t = jnp.zeros((0,), jnp.int32)
    return (offered, accepted, tagged, consumed_meas, consumed, injected,
            escaped, size.reshape(R, -1).sum(axis=1), stalled_at,
            inj_t, cons_t, consm_t, infl_t, carry[0])


def _compiled_flows(traffic, tables: SimTables) -> CompiledFlowTraffic:
    """Compile any accepted traffic input onto the table's flow slots."""
    if isinstance(traffic, CompiledFlowTraffic):
        return traffic
    t = tables.csr()
    ct = compile_flow_traffic(traffic, t.src_indptr, t.dst)
    if ct.prob.shape[-1] != t.n_flows:
        raise ValueError("flow traffic does not match the path table")
    return ct


@dataclasses.dataclass
class AdaptiveSpec:
    """Precomputed adaptive-routing tables for the sweep kernels.

    ``esc``/``minmask`` are stacked (2, n, n): plane 0 is the pre-fault
    network, plane 1 the post-fault survivors (identical when no fault is
    injected). ``outch`` is the fixed per-node out-channel slot layout --
    CSR out-adjacency order, fault-independent, so ``minmask`` bit ``j``
    always refers to the same physical channel.
    """
    esc: np.ndarray       # (2, n, n) int32: escape next-channel, -1 none
    outch: np.ndarray     # (n, D) int32: out-channels per node, -1 pad
    minmask: np.ndarray   # (2, n, n) uint8: bit j <=> outch[u, j] minimal

    @property
    def D(self) -> int:
        return self.outch.shape[1]


def adaptive_spec(topo: Topology,
                  dead_channels=None) -> AdaptiveSpec:
    """Build the escape + minimal-alternate tables for adaptive sweeps.

    When ``dead_channels`` is given, plane 1 of the stacked tables is
    recomputed over the survivors (escape tree re-rooted around the
    fault, minimal masks re-derived from surviving distances) -- the
    kernel switches planes at the fault cycle.
    """
    from repro.core.routing import adaptive_route
    from repro.core.vcalloc import escape_routes
    e0 = escape_routes(topo)
    a0 = adaptive_route(topo)
    if not e0.connected:
        raise ValueError("pre-fault escape tree does not span the "
                         "network")
    dc = _dead_channel_array(dead_channels)
    if dc is None:
        e1, a1 = e0, a0
    else:
        e1 = escape_routes(topo, dc)
        a1 = adaptive_route(topo, dc)
    return AdaptiveSpec(
        np.stack([e0.esc_next, e1.esc_next]).astype(np.int32),
        a0.outch.astype(np.int32),
        np.stack([a0.minmask, a1.minmask]).astype(np.uint8))


def sweep(tables: SimTables, rates: Sequence[float],
          traffic: Optional[Union[TrafficPattern, CompiledTraffic,
                                  CompiledFlowTraffic,
                                  PhasedTraffic]] = None,
          cycles: int = 6000, warmup: int = 2000, slots: int = 128,
          seed: int = 0, flits: int = 4, kernel: str = "csr",
          stats: Optional[dict] = None,
          adaptive: Optional[AdaptiveSpec] = None,
          fault: Optional[Tuple[int, Sequence[int]]] = None,
          patience: int = 64, watchdog: int = 512) -> List[Dict]:
    """Simulate every rate in one batched (lane-flattened) kernel
    execution; one dict per rate.

    ``kernel="csr"`` (default) gathers routes from the CSR hop arrays
    and never touches the dense ``(n, n, MAXHOP)`` tables;
    ``kernel="dense"`` runs the legacy dense-gather kernel on the same
    flow-slot traffic tables and RNG stream -- the counters of the two
    kernels are bit-identical (the CSR parity tests rely on it). A
    ``stats`` dict, when given, records the kernel used and the peak
    device-array bytes staged per call under ``"array_bytes"``.

    ``adaptive`` (an :func:`adaptive_spec` result) switches both kernels
    to occupancy-driven minimal adaptive routing with the VC0 escape
    lane; requires ``n_vc >= 2`` tables (VC0 reserved -- allocate with
    ``reserve_escape=True``). ``fault=(t, dead_channels)`` kills the
    given channels at cycle ``t`` mid-sweep: dead channels stop
    accepting forwards/injections (their receive queues still drain),
    and with ``adaptive`` set, in-flight packets re-resolve onto
    surviving alternates or the re-rooted escape tree. ``patience`` is
    the per-queue stalled-cycles threshold before an adaptive head
    diverts to the escape VC; ``watchdog`` is the zero-progress window
    after which a lane is declared stalled (``stalled_at`` per rate,
    ``stats["cycles_run"]`` < ``cycles`` when every lane wedged and the
    sweep aborted early).

    A :class:`PhasedTraffic` input switches both kernels to trace
    replay: the spatial demand phase follows the compiled schedule
    cycle by cycle. A pattern carrying a
    :class:`~repro.core.traffic.TenantMap` (from
    :func:`~repro.core.traffic.compose_tenants`) adds a ``"tenants"``
    entry to every rate dict -- per-tenant injected / consumed /
    in-flight packet counts (exact conservation: injected == consumed +
    in-flight) and delivered throughput per tenant node.
    """
    if MAXHOP > _HOP_MASK:
        raise ValueError(f"packed packet words support MAXHOP <= "
                         f"{_HOP_MASK}")
    if patience < 1:
        raise ValueError("patience must be >= 1")
    if watchdog < 1:
        raise ValueError("watchdog must be >= 1")
    adaptive_on = adaptive is not None
    if adaptive_on and tables.n_vc < 2:
        raise ValueError("adaptive routing reserves VC 0 as the escape "
                         "lane and needs n_vc >= 2")
    faulted = fault is not None
    t_fault = 0
    dead = None
    if faulted:
        t_fault, dead_in = fault
        t_fault = int(t_fault)
        if not 0 <= t_fault <= cycles:
            raise ValueError(f"fault cycle {t_fault} outside "
                             f"[0, {cycles}]")
        dead = _dead_channel_array(dead_in)
        if dead is not None and ((dead < 0).any()
                                 or (dead >= tables.n_ch).any()):
            bad = dead[(dead < 0) | (dead >= tables.n_ch)]
            raise ValueError(f"unknown channel ids {bad.tolist()} "
                             f"(topology has {tables.n_ch} channels)")
    alive_np = np.ones((2, tables.n_ch), np.int32)
    if faulted and dead is not None:
        alive_np[1, dead] = 0
    if adaptive_on:
        esc_np = np.ascontiguousarray(adaptive.esc, np.int32)
        outch_np = np.ascontiguousarray(adaptive.outch, np.int32)
        minmask_np = np.ascontiguousarray(adaptive.minmask, np.uint8)
        D = adaptive.D
        if esc_np.shape != (2, tables.n, tables.n):
            raise ValueError("adaptive spec built for a different "
                             "topology")
    else:
        esc_np = np.zeros((2, 1, 1), np.int32)
        outch_np = np.zeros((1, 1), np.int32)
        minmask_np = np.zeros((2, 1, 1), np.uint8)
        D = 1
    ct = _compiled_flows(traffic, tables)
    burst = ct.burst
    bursty = burst is not None
    if bursty:
        on_cycles, g_on, g_off, phase_np = burst.realize(tables.n)
        period = int(burst.period)
    else:
        period, on_cycles, g_on, g_off = 0, 0, 1.0, 1.0
        phase_np = np.zeros(tables.n, np.int32)
    phased = ct.phases > 0
    if phased:
        phase_of_np = np.asarray(ct.phase_of, np.int32)
        p_period = int(len(phase_of_np))
    else:
        phase_of_np = np.zeros(1, np.int32)
        p_period = 1
    tenants = ct.tenants
    T = tenants.n_tenants if tenants is not None else 0
    if T:
        tmap_np = np.asarray(tenants.pair_tenant, np.int32)
        t_csr = tables.csr()
        fsrc = np.repeat(np.arange(tables.n),
                         np.diff(t_csr.src_indptr).astype(np.int64))
        tof_np = tmap_np[fsrc, np.asarray(t_csr.dst, np.int64)]
    else:
        tmap_np = np.zeros((1, 1), np.int32)
        tof_np = np.zeros(1, np.int32)
    rates = np.asarray(list(rates), np.float32)
    R = len(rates)
    NQ = R * tables.n_ch * tables.n_vc
    F = int(ct.prob.shape[-1])
    state_bytes = NQ * slots * 4 + NQ * 8 + R * tables.n_ch * 8
    if adaptive_on:
        state_bytes += NQ * 4     # per-queue stall counters
    traffic_bytes = (ct.src_indptr.nbytes + ct.deg.nbytes + ct.prob.nbytes
                     + ct.alias.nbytes + ct.src_rate.nbytes)
    aux_bytes = (esc_np.nbytes + outch_np.nbytes + minmask_np.nbytes
                 + alive_np.nbytes + phase_np.nbytes + tof_np.nbytes
                 + tmap_np.nbytes + phase_of_np.nbytes)
    if F == 0:
        if stats is not None:
            stats["kernel"] = kernel
            stats["cycles_run"] = cycles
            stats["array_bytes"] = max(stats.get("array_bytes", 0),
                                       state_bytes + traffic_bytes)
        return [{"rate": float(r), "offered": 0.0, "accepted": 0.0,
                 "delivered": 0.0, "delivered_tagged": 0.0,
                 "consumed_total": 0, "injected_total": 0, "in_flight": 0,
                 "escaped": 0, "stalled_at": -1}
                for r in rates]
    if kernel == "csr":
        t = tables.csr()
        if t.n_flows > _FLOW_MASK:
            raise ValueError(f"packed packet words support F <= "
                             f"{_FLOW_MASK} flows")
        pvf = (t.chan.astype(np.int64) * tables.n_vc
               + t.vc.astype(np.int64)).astype(np.int32)
        hptr = t.hop_indptr[:-1].astype(np.int32)
        lenm1 = (np.diff(t.hop_indptr) - 1).astype(np.int32)
        if len(lenm1) and (lenm1 < 0).any():
            raise ValueError(
                "path table contains zero-length (lost) flow slots -- "
                "the kernel samples traffic over flow slots and cannot "
                "inject a packet with no route; compact a degraded "
                "serving table first (CSRPathTable.compact() drops "
                "lost pairs and remaps flow ids)")
        dstN = np.asarray(t.dst, np.int32)   # flow -> destination node
        route_bytes = pvf.nbytes + hptr.nbytes + lenm1.nbytes + dstN.nbytes
        args = (jnp.asarray(tables.ch_dst), jnp.asarray(pvf),
                jnp.asarray(hptr), jnp.asarray(lenm1), jnp.asarray(dstN))
        fn = _sweep_csr
    elif kernel == "dense":
        if tables.n > _FIELD_MASK:
            raise ValueError(f"the dense kernel's packed packet words "
                             f"support n <= {_FIELD_MASK}")
        # composite per-hop (channel * n_vc + vc) table: one kernel gather
        pv = np.where(tables.path < 0, -1,
                      tables.path * tables.n_vc
                      + tables.vcs.astype(np.int32)).astype(np.int32)
        fdst = np.asarray(tables.csr().dst, np.int32)
        route_bytes = pv.nbytes + fdst.nbytes
        args = (jnp.asarray(tables.ch_dst), jnp.asarray(pv),
                jnp.asarray(fdst))
        fn = _sweep_dense
    else:
        raise ValueError(f"unknown kernel {kernel!r}")
    if stats is not None:
        stats["kernel"] = kernel
        stats["array_bytes"] = max(stats.get("array_bytes", 0),
                                   state_bytes + traffic_bytes
                                   + route_bytes + aux_bytes)
    # the simulator's integer carries are written for 32-bit mode; shield
    # it from processes that enabled x64 (e.g. the LP solver)
    with jax.experimental.disable_x64():
        out = fn(*args, jnp.asarray(ct.src_indptr[:-1]),
                 jnp.asarray(ct.deg), jnp.asarray(ct.prob),
                 jnp.asarray(ct.alias), jnp.asarray(ct.src_rate),
                 jnp.asarray(rates), jax.random.PRNGKey(seed),
                 jnp.asarray(outch_np), jnp.asarray(minmask_np),
                 jnp.asarray(esc_np), jnp.asarray(alive_np),
                 jnp.int32(t_fault), jnp.float32(g_on), jnp.float32(g_off),
                 jnp.asarray(np.asarray(phase_np, np.int32)),
                 jnp.asarray(tof_np), jnp.asarray(tmap_np),
                 jnp.asarray(phase_of_np), R=R,
                 n=tables.n, n_ch=tables.n_ch, n_vc=tables.n_vc,
                 slots=slots, cycles=cycles, warmup=warmup, flits=flits,
                 adaptive=adaptive_on, faulted=faulted, bursty=bursty,
                 patience=patience, watchdog=watchdog, D=D, period=period,
                 on_cycles=on_cycles, T=T, phased=phased,
                 p_period=p_period)
    (off, acc, tagd, consm, cons, injd, escd, infl, stalled,
     inj_t, cons_t, consm_t, infl_t) = (np.asarray(a) for a in out[:-1])
    cycles_run = int(out[-1])
    if stats is not None:
        stats["cycles_run"] = cycles_run
    meas = cycles - warmup
    trace = []
    for i, rate in enumerate(rates):
        trace.append({
            "rate": float(rate),
            "offered": float(off[i]) / meas / tables.n,
            "accepted": float(acc[i]) / meas / tables.n,
            # steady-state throughput: window consumption rate
            "delivered": float(consm[i]) / meas / tables.n,
            # conservation-safe: only packets injected inside the window
            "delivered_tagged": float(tagd[i]) / meas / tables.n,
            "consumed_total": int(cons[i]),
            "injected_total": int(injd[i]),
            "in_flight": int(infl[i]),
            # adaptive diagnostics: escape-lane diversions and the cycle
            # the lane's watchdog fired (-1 = never stalled)
            "escaped": int(escd[i]),
            "stalled_at": int(stalled[i]),
        })
        if T:
            # per-tenant accounting (exact conservation:
            # injected == consumed + in_flight for every tenant)
            tens = {}
            for t_id, name in enumerate(tenants.names):
                k = i * T + t_id
                tens[name] = {
                    "injected": int(inj_t[k]),
                    "consumed": int(cons_t[k]),
                    "in_flight": int(infl_t[k]),
                    "delivered": float(consm_t[k]) / meas
                    / max(int(tenants.n_nodes[t_id]), 1),
                }
            trace[-1]["tenants"] = tens
    return trace


def run(tables: SimTables, rate: float,
        traffic: Optional[Union[TrafficPattern, CompiledTraffic,
                                CompiledFlowTraffic,
                                PhasedTraffic]] = None,
        cycles: int = 6000, warmup: int = 2000, slots: int = 128,
        seed: int = 0, flits: int = 4, kernel: str = "csr",
        stats: Optional[dict] = None,
        adaptive: Optional[AdaptiveSpec] = None,
        fault: Optional[Tuple[int, Sequence[int]]] = None,
        patience: int = 64, watchdog: int = 512) -> Dict:
    """Single-rate convenience wrapper over :func:`sweep`."""
    return sweep(tables, [rate], traffic, cycles=cycles, warmup=warmup,
                 slots=slots, seed=seed, flits=flits, kernel=kernel,
                 stats=stats, adaptive=adaptive, fault=fault,
                 patience=patience, watchdog=watchdog)[0]


def saturation_point(tables: SimTables, step: float = 0.01,
                     max_rate: float = 1.0, deficit: float = 0.05,
                     cycles: int = 6000, warmup: int = 2000,
                     slots: int = 128, flits: int = 4,
                     traffic: Optional[Union[TrafficPattern,
                                             CompiledTraffic,
                                             CompiledFlowTraffic,
                                             PhasedTraffic]] = None,
                     seed: int = 0, kernel: str = "csr",
                     stats: Optional[dict] = None,
                     adaptive: Optional[AdaptiveSpec] = None,
                     patience: int = 64,
                     watchdog: int = 512) -> Tuple[float, List[Dict]]:
    """Saturation = last rate whose delivered throughput covers
    (1 - deficit) of offered, before the first shortfall.

    Two batched stages instead of a python loop of per-rate jit calls: a
    coarse sub-grid at half the cycle budget brackets the saturation rate,
    then the grid rates inside the bracketing cell run at full fidelity in
    a second batched execution. Each stage is one compile (cached per
    rate-count) + one device execution; only full-fidelity rates enter the
    returned trace. A bracketing error costs at most one grid step of
    saturation accuracy -- within the deficit criterion's own noise.

    The traffic pattern is compiled onto the table's flow slots once and
    shared by every stage; ``kernel``/``stats``/``adaptive`` forward to
    :func:`sweep` (mid-sweep faults do not -- a fault cycle is only
    meaningful against one fixed cycle budget, so fault studies call
    :func:`sweep` directly).
    """
    ct = _compiled_flows(traffic, tables)
    rates = np.arange(step, max_rate + 1e-9, step)
    stride = max(1, int(round(np.sqrt(len(rates)))))
    coarse_idx = list(range(stride - 1, len(rates), stride))
    if coarse_idx[-1] != len(rates) - 1:
        coarse_idx.append(len(rates) - 1)
    coarse = sweep(tables, rates[coarse_idx], ct,
                   cycles=max(cycles // 2, warmup // 2 + 1),
                   warmup=warmup // 2, slots=slots, seed=seed, flits=flits,
                   kernel=kernel, stats=stats, adaptive=adaptive,
                   patience=patience, watchdog=watchdog)

    def ok(r):
        return r["delivered"] >= (1 - deficit) * r["offered"]

    first_bad = next((i for i, r in enumerate(coarse) if not ok(r)),
                     None)
    if first_bad is None:
        lo, hi = max(len(rates) - stride, 0), len(rates)
    else:
        lo = coarse_idx[first_bad - 1] + 1 if first_bad >= 1 else 0
        hi = coarse_idx[first_bad] + 1
    # full-fidelity refinement; if the half-budget bracket overshot (its
    # lower edge already saturated at full fidelity), slide down a cell
    # until the window's first rate passes or the grid floor is reached
    trace: List[Dict] = []
    while True:
        fine = sweep(tables, rates[lo:hi], ct, cycles=cycles,
                     warmup=warmup, slots=slots, seed=seed, flits=flits,
                     kernel=kernel, stats=stats, adaptive=adaptive,
                     patience=patience, watchdog=watchdog)
        trace = fine + trace
        if lo == 0 or (fine and ok(fine[0])):
            break
        hi = lo
        lo = max(lo - stride, 0)
    sat = 0.0
    for r in trace:
        if ok(r):
            sat = r["delivered"]
        else:
            break
    return sat, trace


# ---------------------------------------------------------------------------
# DOR baseline on prismatic tori (XYZ order, dateline VC switching),
# vectorised over all (src, dst) pairs at once.
# ---------------------------------------------------------------------------


def dor_paths(topo: Topology) -> PathTable:
    """Dimension-ordered minimal routing on a torus with dateline VC rule:
    start on VC0, switch to VC1 after crossing a wrap link in any dim.

    Fully vectorised: the outer loop runs 3 axes x (dim // 2) steps; each
    step advances every still-moving pair simultaneously via a dense
    (u, v) -> channel lookup. No per-pair python loops, no dicts.
    """
    ch = Channels.from_topology(topo)
    pod = topo.pod
    n = topo.n
    X, Y, Z = pod.dims
    chan_of = np.full((n, n), -1, np.int64)
    chan_of[ch.src, ch.dst] = np.arange(ch.n)

    coords = pod.all_coords().astype(np.int64)
    cur = np.broadcast_to(coords[:, None, :], (n, n, 3)).copy()
    tgt = np.broadcast_to(coords[None, :, :], (n, n, 3))

    table = PathTable.empty(n, ch.n, 2)
    hops = table.hops
    vc = np.zeros((n, n), np.int8)
    for axis in range(3):
        dim = pod.dims[axis]
        delta = (tgt[..., axis] - cur[..., axis]) % dim
        step = np.where(2 * delta <= dim, 1, -1)
        count = np.where(step == 1, delta, dim - delta)
        for k in range(dim // 2):
            act = count > k
            if not act.any():
                break
            c_ax = cur[..., axis]
            nxt_ax = (c_ax + step) % dim
            nxt = cur.copy()
            nxt[..., axis] = nxt_ax
            u = cur[..., 0] + X * (cur[..., 1] + Y * cur[..., 2])
            v = nxt[..., 0] + X * (nxt[..., 1] + Y * nxt[..., 2])
            si, di = np.nonzero(act)
            cidx = chan_of[u[si, di], v[si, di]]
            if (cidx < 0).any():
                raise KeyError("DOR needs torus links along every axis")
            crossed = ((step == 1) & (nxt_ax == 0)) | \
                ((step == -1) & (c_ax == 0))
            vc = np.where(act & crossed, np.int8(1), vc)
            h = hops[si, di]
            table.path[si, di, h] = cidx.astype(np.int32)
            table.vcs[si, di, h] = vc[si, di]
            hops[si, di] = h + 1
            cur = np.where(act[..., None], nxt, cur)
    return table


def dor_tables(topo: Topology, n_vc: int = 2) -> SimTables:
    table = dor_paths(topo)
    table.n_vc = n_vc
    return build_tables(topo, table)


def at_tables(topo: Topology, at: ATResult, routed: RoutingResult,
              balance: Optional[bool] = True,
              stats: Optional[dict] = None,
              reserve_escape: bool = False) -> SimTables:
    """VC-allocate the routed paths and build simulator tables.

    Works on a copy of ``routed.table`` so the caller's RoutingResult is
    not mutated and the returned SimTables cannot be rewritten by later
    allocations on the same result. Both table layouts pass through
    unchanged (a CSR table stays CSR -- and feeds the CSR-native kernel
    without ever densifying).

    ``balance=None`` skips re-allocation and keeps the VC assignment
    already in the table -- the array and sharded path-selection engines
    emit each winning candidate's BFS state-path VCs, which are valid by
    construction (fast path for large pods / fault sweeps where the
    balanced re-allocation is not needed). ``stats`` is forwarded to
    :func:`~repro.core.vcalloc.allocate_vcs` (greedy dead-end
    counters). ``reserve_escape=True`` keeps VC 0 free for the adaptive
    escape lane (forwarded to the allocator; requires re-allocation,
    i.e. ``balance`` not None)."""
    from repro.core.vcalloc import allocate_vcs
    if reserve_escape and balance is None:
        raise ValueError("reserve_escape needs VC re-allocation "
                         "(balance=True or False)")
    table = routed.table.copy()
    if balance is not None:
        allocate_vcs(at, table, balance=balance, stats=stats,
                     reserve_escape=reserve_escape)
    table.n_vc = at.n_vc
    return build_tables(topo, table)
