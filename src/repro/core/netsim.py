"""Cycle-level network simulator, vectorised and jitted in JAX.

Replaces CNSim (paper Section 6.1) for this container: synchronous
packet-granularity wormhole approximation with per-(channel, VC) FIFOs,
round-robin VC arbitration, one packet serviced per channel per cycle,
static single-path routing tables and per-hop VC assignments from the AT
pipeline. Uniform-random traffic swept over injection rates; saturation =
largest rate whose delivered throughput tracks the offered rate (CNSim's
first-timeout criterion, in deficit form).

Defaults follow Table 2 where representable at packet granularity
(radix 6, 2 escape VCs of the 4 total, buffering in packet slots).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.routing import ATResult, Channels, RoutingResult
from repro.core.topology import Topology

MAXHOP = 40


@dataclasses.dataclass
class SimTables:
    """Dense static routing tables for the simulator."""
    n: int
    n_ch: int
    n_vc: int
    ch_dst: np.ndarray                  # (C,)
    path: np.ndarray                    # (n, n, MAXHOP) channel ids, -1 pad
    vcs: np.ndarray                     # (n, n, MAXHOP) vc ids
    hops: np.ndarray                    # (n, n)


def build_tables(topo: Topology, routed: RoutingResult,
                 vc_seqs: Dict[Tuple[int, int], List[int]],
                 n_vc: int = 2) -> SimTables:
    ch = Channels.from_topology(topo)
    n = topo.n
    path = np.full((n, n, MAXHOP), -1, np.int32)
    vcs = np.zeros((n, n, MAXHOP), np.int8)
    hops = np.zeros((n, n), np.int32)
    for (s, d), p in routed.paths.items():
        L = min(len(p), MAXHOP)
        path[s, d, :L] = p[:L]
        vcs[s, d, :L] = vc_seqs[(s, d)][:L]
        hops[s, d] = L
    return SimTables(n, ch.n, n_vc, ch.dst.astype(np.int32), path, vcs,
                     hops)


@partial(jax.jit, static_argnames=("n", "n_ch", "n_vc", "slots", "cycles",
                                   "flits"))
def _simulate(ch_dst, path, vcs, rate, key, *, n, n_ch, n_vc, slots,
              cycles, warmup, flits=1):
    NQ = n_ch * n_vc

    # queue state: per-(channel,vc) ring buffers of packet attributes
    q_src = jnp.zeros((NQ, slots), jnp.int32)
    q_dst = jnp.zeros((NQ, slots), jnp.int32)
    q_hop = jnp.zeros((NQ, slots), jnp.int32)
    head = jnp.zeros((NQ,), jnp.int32)
    size = jnp.zeros((NQ,), jnp.int32)
    rr = jnp.zeros((n_ch,), jnp.int32)
    busy = jnp.zeros((n_ch,), jnp.int32)   # flit-serialisation countdown

    def qid(c, v):
        return c * n_vc + v

    def cycle(i, carry):
        (q_src, q_dst, q_hop, head, size, rr, busy, key, stats) = carry
        offered, accepted, delivered = stats

        # ---- head packet per (channel, vc) --------------------------------
        hs = q_src[jnp.arange(NQ), head]
        hd = q_dst[jnp.arange(NQ), head]
        hh = q_hop[jnp.arange(NQ), head]
        nonempty = size > 0

        arrive_node = ch_dst[jnp.arange(NQ) // n_vc]
        consume = nonempty & (arrive_node == hd)
        nxt_c = path[hs, hd, hh + 1]
        nxt_v = vcs[hs, hd, hh + 1].astype(jnp.int32)
        tq = jnp.where(consume, -1, qid(nxt_c, nxt_v))
        fwd_ok = nonempty & ~consume & (size[jnp.clip(tq, 0, NQ - 1)]
                                        < slots)
        eligible = consume | fwd_ok                     # per (c, v)

        # ---- round-robin arbitration: one vc per channel ------------------
        # multi-flit packets occupy the link for `flits` cycles
        eligible = eligible & jnp.repeat(busy == 0, n_vc)
        elig_cv = eligible.reshape(n_ch, n_vc)
        offs = (rr[:, None] + jnp.arange(n_vc)[None, :]) % n_vc
        pri = jnp.take_along_axis(elig_cv, offs, axis=1)
        first = jnp.argmax(pri, axis=1)
        any_e = pri.any(axis=1)
        win_v = (rr + first) % n_vc
        win_q = jnp.arange(n_ch) * n_vc + win_v          # (C,)
        win_valid = any_e
        rr = jnp.where(win_valid, (win_v + 1) % n_vc, rr)

        w_src = hs[win_q]
        w_dst = hd[win_q]
        w_hop = hh[win_q]
        w_consume = consume[win_q] & win_valid
        w_target = jnp.where(win_valid & ~w_consume, tq[win_q], -1)

        # ---- rank winners per target queue, check space -------------------
        sort_i = jnp.argsort(jnp.where(w_target < 0, NQ + 1, w_target))
        st = jnp.where(w_target < 0, NQ + 1, w_target)[sort_i]
        newgrp = jnp.concatenate([jnp.ones(1, bool), st[1:] != st[:-1]])
        gid = jnp.cumsum(newgrp) - 1
        grp_start = jnp.where(newgrp, jnp.arange(n_ch), 0)
        grp_start = jax.lax.associative_scan(jnp.maximum, grp_start)
        rank_sorted = jnp.arange(n_ch) - grp_start
        rank = jnp.zeros(n_ch, jnp.int32).at[sort_i].set(
            rank_sorted.astype(jnp.int32))
        space_ok = (size[jnp.clip(w_target, 0, NQ - 1)] + rank) < slots
        w_push = win_valid & ~w_consume & (w_target >= 0) & space_ok
        w_pop = w_consume | w_push
        busy = jnp.where(w_pop, flits - 1, jnp.maximum(busy - 1, 0))

        # ---- apply pops ----------------------------------------------------
        popq = jnp.where(w_pop, win_q, NQ)  # NQ = dummy
        head = head.at[jnp.clip(popq, 0, NQ - 1)].add(
            jnp.where(w_pop, 1, 0)) % slots
        size = size.at[jnp.clip(popq, 0, NQ - 1)].add(
            jnp.where(w_pop, -1, 0))

        # ---- apply pushes --------------------------------------------------
        tgt = jnp.clip(w_target, 0, NQ - 1)
        slot = (head[tgt] + size[tgt] + rank) % slots
        q_src = q_src.at[tgt, slot].set(
            jnp.where(w_push, w_src, q_src[tgt, slot]))
        q_dst = q_dst.at[tgt, slot].set(
            jnp.where(w_push, w_dst, q_dst[tgt, slot]))
        q_hop = q_hop.at[tgt, slot].set(
            jnp.where(w_push, w_hop + 1, q_hop[tgt, slot]))
        size = size.at[tgt].add(jnp.where(w_push, 1, 0))

        # ---- injection -----------------------------------------------------
        key, k1, k2 = jax.random.split(key, 3)
        want = jax.random.uniform(k1, (n,)) < rate
        dsts = jax.random.randint(k2, (n,), 0, n - 1)
        srcs = jnp.arange(n)
        dsts = jnp.where(dsts >= srcs, dsts + 1, dsts)
        c0 = path[srcs, dsts, 0]
        v0 = vcs[srcs, dsts, 0].astype(jnp.int32)
        iq = qid(c0, v0)
        has_space = size[iq] < slots
        inj = want & has_space
        slot = (head[iq] + size[iq]) % slots
        q_src = q_src.at[iq, slot].set(jnp.where(inj, srcs, q_src[iq, slot]))
        q_dst = q_dst.at[iq, slot].set(jnp.where(inj, dsts, q_dst[iq, slot]))
        q_hop = q_hop.at[iq, slot].set(jnp.where(inj, 0, q_hop[iq, slot]))
        size = size.at[iq].add(jnp.where(inj, 1, 0))

        measure = i >= warmup
        offered = offered + jnp.where(measure, want.sum(), 0)
        accepted = accepted + jnp.where(measure, inj.sum(), 0)
        delivered = delivered + jnp.where(measure, w_consume.sum(), 0)
        return (q_src, q_dst, q_hop, head, size, rr, busy, key,
                (offered, accepted, delivered))

    stats0 = (jnp.zeros((), jnp.int32),) * 3
    carry = (q_src, q_dst, q_hop, head, size, rr, busy, key, stats0)
    carry = jax.lax.fori_loop(0, cycles, cycle, carry)
    offered, accepted, delivered = carry[-1]
    return offered, accepted, delivered


def run(tables: SimTables, rate: float, cycles: int = 6000,
        warmup: int = 2000, slots: int = 128, seed: int = 0,
        flits: int = 4):
    # the simulator's integer carries are written for 32-bit mode; shield
    # it from processes that enabled x64 (e.g. the LP solver)
    with jax.experimental.disable_x64():
        off, acc, dlv = _simulate(
            jnp.asarray(tables.ch_dst), jnp.asarray(tables.path),
            jnp.asarray(tables.vcs), jnp.float32(rate),
            jax.random.PRNGKey(seed), n=tables.n, n_ch=tables.n_ch,
            n_vc=tables.n_vc, slots=slots, cycles=cycles, warmup=warmup,
            flits=flits)
    meas = cycles - warmup
    return {
        "offered": float(off) / meas / tables.n,
        "accepted": float(acc) / meas / tables.n,
        "delivered": float(dlv) / meas / tables.n,
    }


def saturation_point(tables: SimTables, step: float = 0.01,
                     max_rate: float = 1.0, deficit: float = 0.05,
                     cycles: int = 6000, warmup: int = 2000,
                     slots: int = 128, flits: int = 4
                     ) -> Tuple[float, List[Dict]]:
    """Sweep injection rate; saturation = last rate where delivered covers
    (1 - deficit) of offered."""
    trace = []
    sat = 0.0
    rate = step
    while rate <= max_rate + 1e-9:
        r = run(tables, rate, cycles=cycles, warmup=warmup, slots=slots,
                flits=flits)
        r["rate"] = rate
        trace.append(r)
        if r["delivered"] >= (1 - deficit) * r["offered"]:
            sat = r["delivered"]
        else:
            break
        rate += step
    return sat, trace


# ---------------------------------------------------------------------------
# DOR baseline on prismatic tori (XYZ order, dateline VC switching)
# ---------------------------------------------------------------------------


def dor_paths(topo: Topology) -> Tuple[Dict, Dict]:
    """Dimension-ordered minimal routing on a torus with dateline VC rule:
    start on VC0, switch to VC1 after crossing a wrap link in any dim."""
    from repro.core.topology import Pod
    ch = Channels.from_topology(topo)
    pod = topo.pod
    X, Y, Z = pod.dims
    dims = pod.dims
    paths, vcseqs = {}, {}
    for s in range(topo.n):
        sc = list(pod.coords(s))
        for d in range(topo.n):
            if s == d:
                continue
            dc = list(pod.coords(d))
            cur = list(sc)
            seq, vseq = [], []
            vc = 0
            for axis in range(3):
                delta = (dc[axis] - cur[axis]) % dims[axis]
                if delta == 0:
                    continue
                step = 1 if delta <= dims[axis] - delta else -1
                count = delta if step == 1 else dims[axis] - delta
                for _ in range(count):
                    nxt = list(cur)
                    nxt[axis] = (cur[axis] + step) % dims[axis]
                    u = pod.node_id(*cur)
                    v = pod.node_id(*nxt)
                    key = (u, v)
                    if key not in ch.index:
                        raise KeyError(f"DOR needs torus link {key}")
                    seq.append(ch.index[key])
                    if (step == 1 and nxt[axis] == 0) or \
                       (step == -1 and cur[axis] == 0):
                        vc = 1  # crossed the dateline
                    vseq.append(vc)
                    cur = nxt
            paths[(s, d)] = tuple(seq)
            vcseqs[(s, d)] = vseq
    return paths, vcseqs


def dor_tables(topo: Topology, n_vc: int = 2) -> SimTables:
    paths, vcseqs = dor_paths(topo)
    loads = np.zeros(2 * len(topo.edges()))
    for p in paths.values():
        loads[list(p)] += 1
    routed = RoutingResult(paths, loads, float(loads.max()),
                           float(np.mean([len(p) for p in paths.values()])),
                           0)
    return build_tables(topo, routed, vcseqs, n_vc=n_vc)


def at_tables(topo: Topology, at: ATResult, routed: RoutingResult,
              balance: bool = True) -> SimTables:
    from repro.core.vcalloc import allocate_vcs
    vcs, _ = allocate_vcs(at, routed.paths, balance=balance)
    return build_tables(topo, routed, vcs, n_vc=at.n_vc)
