"""Cycle-level network simulator, vectorised and jitted in JAX.

Replaces CNSim (paper Section 6.1) for this container: synchronous
packet-granularity wormhole approximation with per-(channel, VC) FIFOs,
round-robin VC arbitration, one packet serviced per channel per cycle and
one packet accepted per queue per cycle (crossbar constraint; losers
stall and retry), static single-path routing tables and per-hop VC
assignments from the AT pipeline.

The default kernel is *CSR-native* (``kernel="csr"``): packet words carry
a routed-flow id, and next-channel/next-VC lookups gather from the
``CSRPathTable``'s concatenated hop array via ``hop_indptr[flow] + hop``
indexing. Peak simulator memory therefore scales with total routed hops
(O(H), ~73 MB at 12^3) instead of the dense ``(n, n, MAXHOP)`` gather
tables (O(n^2 * MAXHOP), ~480 MB at 12^3 and ~3.4 GB at 16^3, which also
exceeds the dense packet word's 12-bit node fields). The legacy dense
kernel survives as ``kernel="dense"``: it consumes the same flow-slot
traffic tables and the same RNG stream, so its per-rate counters are
bit-identical to the CSR kernel's -- the equivalence oracle exercised by
``tests/test_netsim_csr.py``. Keep the two cycle bodies in lockstep.

Traffic is pluggable (:class:`repro.core.traffic.TrafficPattern`): demand
matrices compile onto the table's flow slots
(:class:`repro.core.traffic.CompiledFlowTraffic`, O(F) alias tables), so
uniform-random, permutation, hotspot and demand-driven patterns all share
one compiled simulator. Demand on unrouted pairs is dropped at compile
time (rows renormalise over routed flows). Injection-rate sweeps run all
rates in one batched device execution (lane-flattened rather than
``jax.vmap``-ed -- see :func:`_sweep_csr`) instead of a Python loop of
per-rate jit calls.

Accounting: ``delivered`` is the measurement-window consumption rate (the
steady-state throughput estimator -- arrivals of warmup-injected packets
cancel the still-in-flight tail). Packets injected during the window are
additionally tagged, and ``delivered_tagged`` counts only those arrivals,
so ``delivered_tagged <= accepted <= offered`` holds exactly;
``injected_total`` / ``consumed_total`` / ``in_flight`` (whole run)
satisfy packet conservation ``injected == consumed + in_flight``.
Saturation = largest rate whose delivered throughput tracks the offered
rate (CNSim's first-timeout criterion, in deficit form).

Defaults follow Table 2 where representable at packet granularity
(radix 6, 2 escape VCs of the 4 total, buffering in packet slots).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.pathtable import MAXHOP, CSRPathTable, PathTable
from repro.core.routing import ATResult, Channels, RoutingResult
from repro.core.topology import Topology
from repro.core.traffic import (CompiledFlowTraffic, CompiledTraffic,
                                TrafficPattern, compile_flow_traffic)


@dataclasses.dataclass
class SimTables:
    """Static routing tables for the simulator.

    Accepts either path-table layout and keeps it as-is in ``table``;
    the CSR form is what the default simulator kernel consumes directly,
    so a 12^3/16^3 route-and-simulate pipeline never materialises the
    ``n^2 * MAXHOP`` arrays. Conversions are cached on the side:
    :meth:`csr` packs a dense table once, :meth:`dense` (and the
    ``path``/``vcs``/``hops`` views, kept for the dense kernel and
    API-edge consumers) densifies a CSR table once.
    """
    n: int
    n_ch: int
    n_vc: int
    ch_dst: np.ndarray                  # (C,)
    table: Union[PathTable, CSRPathTable]
    _dense_cache: Optional[PathTable] = \
        dataclasses.field(default=None, repr=False)
    _csr_cache: Optional[CSRPathTable] = \
        dataclasses.field(default=None, repr=False)

    def dense(self) -> PathTable:
        if isinstance(self.table, PathTable):
            return self.table
        if self._dense_cache is None:
            self._dense_cache = self.table.to_dense()
        return self._dense_cache

    def csr(self) -> CSRPathTable:
        if isinstance(self.table, CSRPathTable):
            return self.table
        if self._csr_cache is None:
            self._csr_cache = CSRPathTable.from_dense(self.table)
        return self._csr_cache

    @property
    def path(self) -> np.ndarray:
        return self.dense().path

    @property
    def vcs(self) -> np.ndarray:
        return self.dense().vcs

    @property
    def hops(self) -> np.ndarray:
        return self.dense().hops


def build_tables(topo: Topology,
                 table: Union[PathTable, CSRPathTable, RoutingResult]
                 ) -> SimTables:
    """Packed path table (or a RoutingResult carrying one) -> SimTables.

    No per-pair python loops: the table arrives already packed from path
    selection / DOR construction / VC allocation, in either the dense or
    the CSR layout.
    """
    if isinstance(table, RoutingResult):
        table = table.table
    ch = Channels.from_topology(topo)
    if table.n_ch != ch.n:
        raise ValueError(f"table built for {table.n_ch} channels, "
                         f"topology has {ch.n}")
    return SimTables(table.n, ch.n, table.n_vc, ch.dst.astype(np.int32),
                     table)


# ---------------------------------------------------------------------------
# Jitted kernels: all injection rates batched as lane-flattened simulations
# ---------------------------------------------------------------------------


# Packet word layouts (one int32 per packet; packing all attributes into
# one word turns the four per-attribute scatter updates of the seed
# kernel into a single scatter -- scatters serialise on CPU and dominated
# the vmapped sweep's wall-clock):
#
#   dense kernel:  src[0:12] | dst[12:24] | hop[24:30] | tag[30]
#                  (n <= 4095 -- the dense kernel cannot pack 16^3)
#   csr kernel:    flow[0:24] | hop[24:30] | tag[30]
#                  (F <= 2^24 - 1; all-pairs 16^3 = 4096*4095 still fits)
#
# MAXHOP <= 63 for both; checked in `sweep`.
_SRC_BITS = 12
_DST_SHIFT = 12
_HOP_SHIFT = 24
_TAG_SHIFT = 30
_FIELD_MASK = (1 << 12) - 1
_HOP_MASK = (1 << 6) - 1
_FLOW_MASK = (1 << 24) - 1


def _pack(src, dst, hop, tag):
    return (src | (dst << _DST_SHIFT) | (hop << _HOP_SHIFT)
            | (tag.astype(jnp.int32) << _TAG_SHIFT))


def _pack_flow(flow, hop, tag):
    return (flow | (hop << _HOP_SHIFT)
            | (tag.astype(jnp.int32) << _TAG_SHIFT))


@partial(jax.jit, static_argnames=("R", "n", "n_ch", "n_vc", "slots",
                                   "cycles", "warmup", "flits"))
def _sweep_csr(ch_dst, pvf, hptr, lenm1, src_ptr, deg, fprob, falias,
               src_rate, rates, key, *, R, n, n_ch, n_vc, slots, cycles,
               warmup, flits):
    """R independent simulations (one per injection rate) in one compiled
    execution, gathering routes from the CSR hop arrays.

    The batch is *lane-flattened* rather than ``jax.vmap``-ed: lane ``l``'s
    queue (c, v) lives at flat row ``l*NQ + c*n_vc + v``, so every update
    in the cycle body stays an ordinary rank-1 gather/scatter. (A vmapped
    version was measured first: XLA CPU lowers batched scatter/sort so
    poorly that it ran slower than the sequential python loop. Because the
    flat queue id factors as ``fc * n_vc + v`` with ``fc = l*C + c``, the
    single-lane arbitration/rank formulas carry over verbatim.)

    Route lookups are flow-native: a head word's next (channel, VC) is
    ``pvf[hptr[flow] + hop + 1]`` and it consumes when ``hop`` reaches
    ``lenm1[flow]`` -- no (n, n, MAXHOP) arrays anywhere. ``pvf`` packs
    ``channel * n_vc + vc`` per hop (one gather serves both fields).
    """
    C = R * n_ch                    # flat channels across lanes
    NQ = C * n_vc                   # flat queues across lanes
    N = R * n                       # flat sources across lanes
    H = pvf.shape[0]

    # queue state: per-(lane, channel, vc) ring buffers of packed words
    q = jnp.zeros((NQ, slots), jnp.int32)
    head = jnp.zeros((NQ,), jnp.int32)
    size = jnp.zeros((NQ,), jnp.int32)
    rr = jnp.zeros((C,), jnp.int32)
    busy = jnp.zeros((C,), jnp.int32)   # flit-serialisation countdown

    srcs = jnp.tile(jnp.arange(n), R)            # local node ids per lane
    lane_q = (jnp.arange(N) // n) * (n_ch * n_vc)
    thresh = (rates[:, None] * src_rate[None, :]).reshape(N)

    def cycle(i, carry):
        q, head, size, rr, busy, key, stats = carry
        offered, accepted, tagged, consumed_meas, consumed, injected = stats

        # ---- head packet per (lane, channel, vc) --------------------------
        hw = q[jnp.arange(NQ), head]
        hf = hw & _FLOW_MASK
        hh = (hw >> _HOP_SHIFT) & _HOP_MASK
        nonempty = size > 0

        consume_q = nonempty & (hh == lenm1[hf])
        nxt = pvf[jnp.minimum(hptr[hf] + hh + 1, H - 1)]
        lane_base = (jnp.arange(NQ) // (n_ch * n_vc)) * (n_ch * n_vc)
        tq = jnp.where(consume_q, -1, lane_base + nxt)
        fwd_ok = nonempty & ~consume_q & (size[jnp.clip(tq, 0, NQ - 1)]
                                          < slots)
        eligible = consume_q | fwd_ok                   # per (c, v)

        # ---- round-robin arbitration: one vc per channel ------------------
        # multi-flit packets occupy the link for `flits` cycles
        eligible = eligible & jnp.repeat(busy == 0, n_vc)
        elig_cv = eligible.reshape(C, n_vc)
        offs = (rr[:, None] + jnp.arange(n_vc)[None, :]) % n_vc
        pri = jnp.take_along_axis(elig_cv, offs, axis=1)
        first = jnp.argmax(pri, axis=1)
        any_e = pri.any(axis=1)
        win_v = (rr + first) % n_vc
        win_q = jnp.arange(C) * n_vc + win_v             # (C,)
        win_valid = any_e
        rr = jnp.where(win_valid, (win_v + 1) % n_vc, rr)

        w_word = hw[win_q]
        w_tag = (w_word >> _TAG_SHIFT) & 1
        w_consume = consume_q[win_q] & win_valid
        w_target = jnp.where(win_valid & ~w_consume, tq[win_q], -1)

        # ---- crossbar constraint: one push per target queue per cycle ----
        # (a router output accepts one packet from the crossbar per cycle;
        # the lowest-id input wins, losers stall and retry next cycle).
        # Targets never collide across lanes: flat queue ids are disjoint.
        cand = win_valid & ~w_consume & (w_target >= 0)
        tgt = jnp.clip(w_target, 0, NQ - 1)
        first = jnp.full((NQ + 1,), C, jnp.int32) \
            .at[jnp.where(cand, tgt, NQ)].min(jnp.arange(C, dtype=jnp.int32))
        w_push = cand & (first[tgt] == jnp.arange(C))
        w_pop = w_consume | w_push
        busy = jnp.where(w_pop, flits - 1, jnp.maximum(busy - 1, 0))

        # ---- push slots ----------------------------------------------------
        # post-pop (head + size) equals pre-pop (head + size): a pop moves
        # head forward and shrinks size by one, so the tail slot is stable
        p_slot = (head[tgt] + size[tgt]) % slots
        push_word = w_word + (1 << _HOP_SHIFT)      # hop += 1, rest intact

        # ---- injection: alias-sampled routed flow per source --------------
        measure = i >= warmup
        key, k1, k2, k3 = jax.random.split(key, 4)
        want = jax.random.uniform(k1, (N,)) < thresh
        u1 = jax.random.uniform(k2, (N,))
        dg = deg[srcs]
        j = jnp.minimum((u1 * dg.astype(jnp.float32)).astype(jnp.int32),
                        dg - 1)
        f0 = src_ptr[srcs] + jnp.maximum(j, 0)
        u2 = jax.random.uniform(k3, (N,))
        fid = jnp.where(u2 < fprob[f0], f0, falias[f0])
        cv0 = pvf[hptr[fid]]
        iq = lane_q + cv0
        # queue iq was popped this cycle iff its channel's winner is iq
        i_pop = (w_pop[iq // n_vc]
                 & (win_q[iq // n_vc] == iq)).astype(jnp.int32)
        # at most one push lands in iq this cycle (crossbar constraint)
        i_push = (first[iq] < C).astype(jnp.int32)
        has_space = size[iq] - i_pop + i_push < slots
        inj = want & has_space & (dg > 0)
        i_slot = (head[iq] + size[iq] + i_push) % slots
        inj_word = _pack_flow(fid, jnp.zeros((N,), jnp.int32),
                              measure & inj)

        # ---- one fused scatter for pushes + injections --------------------
        all_rows = jnp.concatenate([jnp.where(w_push, tgt, NQ),
                                    jnp.where(inj, iq, NQ)])
        all_slots = jnp.concatenate([p_slot, i_slot])
        all_words = jnp.concatenate([push_word, inj_word])
        q = q.at[all_rows, all_slots].set(all_words, mode="drop")

        # ---- one fused scatter-add for every size delta, one for heads ----
        popq = jnp.where(w_pop, win_q, NQ)
        d_rows = jnp.concatenate([popq, all_rows])
        d_vals = jnp.concatenate([jnp.full((C,), -1, jnp.int32),
                                  jnp.ones((C + N,), jnp.int32)])
        size = size.at[d_rows].add(d_vals, mode="drop")
        head = head.at[popq].add(1, mode="drop") % slots

        meas = jnp.where(measure, 1, 0)
        cons_lane = w_consume.reshape(R, n_ch).sum(axis=1)
        offered = offered + meas * want.reshape(R, n).sum(axis=1)
        accepted = accepted + meas * inj.reshape(R, n).sum(axis=1)
        tagged = tagged + (w_consume & (w_tag == 1)).reshape(
            R, n_ch).sum(axis=1)
        consumed_meas = consumed_meas + meas * cons_lane
        consumed = consumed + cons_lane
        injected = injected + inj.reshape(R, n).sum(axis=1)
        return (q, head, size, rr, busy, key,
                (offered, accepted, tagged, consumed_meas, consumed,
                 injected))

    stats0 = (jnp.zeros((R,), jnp.int32),) * 6
    carry = (q, head, size, rr, busy, key, stats0)
    carry = jax.lax.fori_loop(0, cycles, cycle, carry)
    size = carry[2]
    offered, accepted, tagged, consumed_meas, consumed, injected = carry[-1]
    return (offered, accepted, tagged, consumed_meas, consumed, injected,
            size.reshape(R, -1).sum(axis=1))


@partial(jax.jit, static_argnames=("R", "n", "n_ch", "n_vc", "slots",
                                   "cycles", "warmup", "flits"))
def _sweep_dense(ch_dst, pv, fdst, src_ptr, deg, fprob, falias,
                 src_rate, rates, key, *, R, n, n_ch, n_vc, slots, cycles,
                 warmup, flits):
    """Legacy dense-gather kernel: identical cycle body to
    :func:`_sweep_csr` (same RNG stream, same flow-slot sampling, same
    arbitration) except route lookups gather from the dense
    ``(n, n, MAXHOP)`` composite table and packet words carry (src, dst)
    node ids. Kept as the bit-identity oracle for the CSR kernel -- edit
    the two cycle bodies in lockstep.
    """
    C = R * n_ch
    NQ = C * n_vc
    N = R * n

    q = jnp.zeros((NQ, slots), jnp.int32)
    head = jnp.zeros((NQ,), jnp.int32)
    size = jnp.zeros((NQ,), jnp.int32)
    rr = jnp.zeros((C,), jnp.int32)
    busy = jnp.zeros((C,), jnp.int32)

    arrive_node = jnp.tile(ch_dst, R)[jnp.arange(NQ) // n_vc]
    srcs = jnp.tile(jnp.arange(n), R)
    lane_q = (jnp.arange(N) // n) * (n_ch * n_vc)
    thresh = (rates[:, None] * src_rate[None, :]).reshape(N)

    def cycle(i, carry):
        q, head, size, rr, busy, key, stats = carry
        offered, accepted, tagged, consumed_meas, consumed, injected = stats

        hw = q[jnp.arange(NQ), head]
        hs = hw & _FIELD_MASK
        hd = (hw >> _DST_SHIFT) & _FIELD_MASK
        hh = (hw >> _HOP_SHIFT) & _HOP_MASK
        nonempty = size > 0

        consume_q = nonempty & (arrive_node == hd)
        # pv packs channel * n_vc + vc per hop: one gather for both
        nxt = pv[hs, hd, hh + 1]
        lane_base = (jnp.arange(NQ) // (n_ch * n_vc)) * (n_ch * n_vc)
        tq = jnp.where(consume_q, -1, lane_base + nxt)
        fwd_ok = nonempty & ~consume_q & (size[jnp.clip(tq, 0, NQ - 1)]
                                          < slots)
        eligible = consume_q | fwd_ok

        eligible = eligible & jnp.repeat(busy == 0, n_vc)
        elig_cv = eligible.reshape(C, n_vc)
        offs = (rr[:, None] + jnp.arange(n_vc)[None, :]) % n_vc
        pri = jnp.take_along_axis(elig_cv, offs, axis=1)
        first = jnp.argmax(pri, axis=1)
        any_e = pri.any(axis=1)
        win_v = (rr + first) % n_vc
        win_q = jnp.arange(C) * n_vc + win_v
        win_valid = any_e
        rr = jnp.where(win_valid, (win_v + 1) % n_vc, rr)

        w_word = hw[win_q]
        w_tag = (w_word >> _TAG_SHIFT) & 1
        w_consume = consume_q[win_q] & win_valid
        w_target = jnp.where(win_valid & ~w_consume, tq[win_q], -1)

        cand = win_valid & ~w_consume & (w_target >= 0)
        tgt = jnp.clip(w_target, 0, NQ - 1)
        first = jnp.full((NQ + 1,), C, jnp.int32) \
            .at[jnp.where(cand, tgt, NQ)].min(jnp.arange(C, dtype=jnp.int32))
        w_push = cand & (first[tgt] == jnp.arange(C))
        w_pop = w_consume | w_push
        busy = jnp.where(w_pop, flits - 1, jnp.maximum(busy - 1, 0))

        p_slot = (head[tgt] + size[tgt]) % slots
        push_word = w_word + (1 << _HOP_SHIFT)

        measure = i >= warmup
        key, k1, k2, k3 = jax.random.split(key, 4)
        want = jax.random.uniform(k1, (N,)) < thresh
        u1 = jax.random.uniform(k2, (N,))
        dg = deg[srcs]
        j = jnp.minimum((u1 * dg.astype(jnp.float32)).astype(jnp.int32),
                        dg - 1)
        f0 = src_ptr[srcs] + jnp.maximum(j, 0)
        u2 = jax.random.uniform(k3, (N,))
        fid = jnp.where(u2 < fprob[f0], f0, falias[f0])
        dsts = fdst[fid]
        cv0 = pv[srcs, dsts, 0]
        iq = lane_q + jnp.clip(cv0, 0, n_ch * n_vc - 1)
        i_pop = (w_pop[iq // n_vc]
                 & (win_q[iq // n_vc] == iq)).astype(jnp.int32)
        i_push = (first[iq] < C).astype(jnp.int32)
        has_space = size[iq] - i_pop + i_push < slots
        inj = want & has_space & (dg > 0)
        i_slot = (head[iq] + size[iq] + i_push) % slots
        inj_word = _pack(srcs, dsts, jnp.zeros((N,), jnp.int32),
                         measure & inj)

        all_rows = jnp.concatenate([jnp.where(w_push, tgt, NQ),
                                    jnp.where(inj, iq, NQ)])
        all_slots = jnp.concatenate([p_slot, i_slot])
        all_words = jnp.concatenate([push_word, inj_word])
        q = q.at[all_rows, all_slots].set(all_words, mode="drop")

        popq = jnp.where(w_pop, win_q, NQ)
        d_rows = jnp.concatenate([popq, all_rows])
        d_vals = jnp.concatenate([jnp.full((C,), -1, jnp.int32),
                                  jnp.ones((C + N,), jnp.int32)])
        size = size.at[d_rows].add(d_vals, mode="drop")
        head = head.at[popq].add(1, mode="drop") % slots

        meas = jnp.where(measure, 1, 0)
        cons_lane = w_consume.reshape(R, n_ch).sum(axis=1)
        offered = offered + meas * want.reshape(R, n).sum(axis=1)
        accepted = accepted + meas * inj.reshape(R, n).sum(axis=1)
        tagged = tagged + (w_consume & (w_tag == 1)).reshape(
            R, n_ch).sum(axis=1)
        consumed_meas = consumed_meas + meas * cons_lane
        consumed = consumed + cons_lane
        injected = injected + inj.reshape(R, n).sum(axis=1)
        return (q, head, size, rr, busy, key,
                (offered, accepted, tagged, consumed_meas, consumed,
                 injected))

    stats0 = (jnp.zeros((R,), jnp.int32),) * 6
    carry = (q, head, size, rr, busy, key, stats0)
    carry = jax.lax.fori_loop(0, cycles, cycle, carry)
    size = carry[2]
    offered, accepted, tagged, consumed_meas, consumed, injected = carry[-1]
    return (offered, accepted, tagged, consumed_meas, consumed, injected,
            size.reshape(R, -1).sum(axis=1))


def _compiled_flows(traffic, tables: SimTables) -> CompiledFlowTraffic:
    """Compile any accepted traffic input onto the table's flow slots."""
    if isinstance(traffic, CompiledFlowTraffic):
        return traffic
    t = tables.csr()
    ct = compile_flow_traffic(traffic, t.src_indptr, t.dst)
    if len(ct.prob) != t.n_flows:
        raise ValueError("flow traffic does not match the path table")
    return ct


def sweep(tables: SimTables, rates: Sequence[float],
          traffic: Optional[Union[TrafficPattern, CompiledTraffic,
                                  CompiledFlowTraffic]] = None,
          cycles: int = 6000, warmup: int = 2000, slots: int = 128,
          seed: int = 0, flits: int = 4, kernel: str = "csr",
          stats: Optional[dict] = None) -> List[Dict]:
    """Simulate every rate in one batched (lane-flattened) kernel
    execution; one dict per rate.

    ``kernel="csr"`` (default) gathers routes from the CSR hop arrays
    and never touches the dense ``(n, n, MAXHOP)`` tables;
    ``kernel="dense"`` runs the legacy dense-gather kernel on the same
    flow-slot traffic tables and RNG stream -- the counters of the two
    kernels are bit-identical (the CSR parity tests rely on it). A
    ``stats`` dict, when given, records the kernel used and the peak
    device-array bytes staged per call under ``"array_bytes"``.
    """
    if MAXHOP > _HOP_MASK:
        raise ValueError(f"packed packet words support MAXHOP <= "
                         f"{_HOP_MASK}")
    ct = _compiled_flows(traffic, tables)
    rates = np.asarray(list(rates), np.float32)
    R = len(rates)
    NQ = R * tables.n_ch * tables.n_vc
    F = len(ct.prob)
    state_bytes = NQ * slots * 4 + NQ * 8 + R * tables.n_ch * 8
    traffic_bytes = (ct.src_indptr.nbytes + ct.deg.nbytes + ct.prob.nbytes
                     + ct.alias.nbytes + ct.src_rate.nbytes)
    if F == 0:
        if stats is not None:
            stats["kernel"] = kernel
            stats["array_bytes"] = max(stats.get("array_bytes", 0),
                                       state_bytes + traffic_bytes)
        return [{"rate": float(r), "offered": 0.0, "accepted": 0.0,
                 "delivered": 0.0, "delivered_tagged": 0.0,
                 "consumed_total": 0, "injected_total": 0, "in_flight": 0}
                for r in rates]
    if kernel == "csr":
        t = tables.csr()
        if t.n_flows > _FLOW_MASK:
            raise ValueError(f"packed packet words support F <= "
                             f"{_FLOW_MASK} flows")
        pvf = (t.chan.astype(np.int64) * tables.n_vc
               + t.vc.astype(np.int64)).astype(np.int32)
        hptr = t.hop_indptr[:-1].astype(np.int32)
        lenm1 = (np.diff(t.hop_indptr) - 1).astype(np.int32)
        route_bytes = pvf.nbytes + hptr.nbytes + lenm1.nbytes
        args = (jnp.asarray(tables.ch_dst), jnp.asarray(pvf),
                jnp.asarray(hptr), jnp.asarray(lenm1))
        fn = _sweep_csr
    elif kernel == "dense":
        if tables.n > _FIELD_MASK:
            raise ValueError(f"the dense kernel's packed packet words "
                             f"support n <= {_FIELD_MASK}")
        # composite per-hop (channel * n_vc + vc) table: one kernel gather
        pv = np.where(tables.path < 0, -1,
                      tables.path * tables.n_vc
                      + tables.vcs.astype(np.int32)).astype(np.int32)
        fdst = np.asarray(tables.csr().dst, np.int32)
        route_bytes = pv.nbytes + fdst.nbytes
        args = (jnp.asarray(tables.ch_dst), jnp.asarray(pv),
                jnp.asarray(fdst))
        fn = _sweep_dense
    else:
        raise ValueError(f"unknown kernel {kernel!r}")
    if stats is not None:
        stats["kernel"] = kernel
        stats["array_bytes"] = max(stats.get("array_bytes", 0),
                                   state_bytes + traffic_bytes
                                   + route_bytes)
    # the simulator's integer carries are written for 32-bit mode; shield
    # it from processes that enabled x64 (e.g. the LP solver)
    with jax.experimental.disable_x64():
        out = fn(*args, jnp.asarray(ct.src_indptr[:-1]),
                 jnp.asarray(ct.deg), jnp.asarray(ct.prob),
                 jnp.asarray(ct.alias), jnp.asarray(ct.src_rate),
                 jnp.asarray(rates), jax.random.PRNGKey(seed), R=R,
                 n=tables.n, n_ch=tables.n_ch, n_vc=tables.n_vc,
                 slots=slots, cycles=cycles, warmup=warmup, flits=flits)
    off, acc, tagd, consm, cons, injd, infl = (np.asarray(a) for a in out)
    meas = cycles - warmup
    trace = []
    for i, rate in enumerate(rates):
        trace.append({
            "rate": float(rate),
            "offered": float(off[i]) / meas / tables.n,
            "accepted": float(acc[i]) / meas / tables.n,
            # steady-state throughput: window consumption rate
            "delivered": float(consm[i]) / meas / tables.n,
            # conservation-safe: only packets injected inside the window
            "delivered_tagged": float(tagd[i]) / meas / tables.n,
            "consumed_total": int(cons[i]),
            "injected_total": int(injd[i]),
            "in_flight": int(infl[i]),
        })
    return trace


def run(tables: SimTables, rate: float,
        traffic: Optional[Union[TrafficPattern, CompiledTraffic,
                                CompiledFlowTraffic]] = None,
        cycles: int = 6000, warmup: int = 2000, slots: int = 128,
        seed: int = 0, flits: int = 4, kernel: str = "csr",
        stats: Optional[dict] = None) -> Dict:
    """Single-rate convenience wrapper over :func:`sweep`."""
    return sweep(tables, [rate], traffic, cycles=cycles, warmup=warmup,
                 slots=slots, seed=seed, flits=flits, kernel=kernel,
                 stats=stats)[0]


def saturation_point(tables: SimTables, step: float = 0.01,
                     max_rate: float = 1.0, deficit: float = 0.05,
                     cycles: int = 6000, warmup: int = 2000,
                     slots: int = 128, flits: int = 4,
                     traffic: Optional[Union[TrafficPattern,
                                             CompiledTraffic,
                                             CompiledFlowTraffic]] = None,
                     seed: int = 0, kernel: str = "csr",
                     stats: Optional[dict] = None) -> Tuple[float,
                                                            List[Dict]]:
    """Saturation = last rate whose delivered throughput covers
    (1 - deficit) of offered, before the first shortfall.

    Two batched stages instead of a python loop of per-rate jit calls: a
    coarse sub-grid at half the cycle budget brackets the saturation rate,
    then the grid rates inside the bracketing cell run at full fidelity in
    a second batched execution. Each stage is one compile (cached per
    rate-count) + one device execution; only full-fidelity rates enter the
    returned trace. A bracketing error costs at most one grid step of
    saturation accuracy -- within the deficit criterion's own noise.

    The traffic pattern is compiled onto the table's flow slots once and
    shared by every stage; ``kernel``/``stats`` forward to :func:`sweep`.
    """
    ct = _compiled_flows(traffic, tables)
    rates = np.arange(step, max_rate + 1e-9, step)
    stride = max(1, int(round(np.sqrt(len(rates)))))
    coarse_idx = list(range(stride - 1, len(rates), stride))
    if coarse_idx[-1] != len(rates) - 1:
        coarse_idx.append(len(rates) - 1)
    coarse = sweep(tables, rates[coarse_idx], ct,
                   cycles=max(cycles // 2, warmup // 2 + 1),
                   warmup=warmup // 2, slots=slots, seed=seed, flits=flits,
                   kernel=kernel, stats=stats)

    def ok(r):
        return r["delivered"] >= (1 - deficit) * r["offered"]

    first_bad = next((i for i, r in enumerate(coarse) if not ok(r)),
                     None)
    if first_bad is None:
        lo, hi = max(len(rates) - stride, 0), len(rates)
    else:
        lo = coarse_idx[first_bad - 1] + 1 if first_bad >= 1 else 0
        hi = coarse_idx[first_bad] + 1
    # full-fidelity refinement; if the half-budget bracket overshot (its
    # lower edge already saturated at full fidelity), slide down a cell
    # until the window's first rate passes or the grid floor is reached
    trace: List[Dict] = []
    while True:
        fine = sweep(tables, rates[lo:hi], ct, cycles=cycles,
                     warmup=warmup, slots=slots, seed=seed, flits=flits,
                     kernel=kernel, stats=stats)
        trace = fine + trace
        if lo == 0 or (fine and ok(fine[0])):
            break
        hi = lo
        lo = max(lo - stride, 0)
    sat = 0.0
    for r in trace:
        if ok(r):
            sat = r["delivered"]
        else:
            break
    return sat, trace


# ---------------------------------------------------------------------------
# DOR baseline on prismatic tori (XYZ order, dateline VC switching),
# vectorised over all (src, dst) pairs at once.
# ---------------------------------------------------------------------------


def dor_paths(topo: Topology) -> PathTable:
    """Dimension-ordered minimal routing on a torus with dateline VC rule:
    start on VC0, switch to VC1 after crossing a wrap link in any dim.

    Fully vectorised: the outer loop runs 3 axes x (dim // 2) steps; each
    step advances every still-moving pair simultaneously via a dense
    (u, v) -> channel lookup. No per-pair python loops, no dicts.
    """
    ch = Channels.from_topology(topo)
    pod = topo.pod
    n = topo.n
    X, Y, Z = pod.dims
    chan_of = np.full((n, n), -1, np.int64)
    chan_of[ch.src, ch.dst] = np.arange(ch.n)

    coords = pod.all_coords().astype(np.int64)
    cur = np.broadcast_to(coords[:, None, :], (n, n, 3)).copy()
    tgt = np.broadcast_to(coords[None, :, :], (n, n, 3))

    table = PathTable.empty(n, ch.n, 2)
    hops = table.hops
    vc = np.zeros((n, n), np.int8)
    for axis in range(3):
        dim = pod.dims[axis]
        delta = (tgt[..., axis] - cur[..., axis]) % dim
        step = np.where(2 * delta <= dim, 1, -1)
        count = np.where(step == 1, delta, dim - delta)
        for k in range(dim // 2):
            act = count > k
            if not act.any():
                break
            c_ax = cur[..., axis]
            nxt_ax = (c_ax + step) % dim
            nxt = cur.copy()
            nxt[..., axis] = nxt_ax
            u = cur[..., 0] + X * (cur[..., 1] + Y * cur[..., 2])
            v = nxt[..., 0] + X * (nxt[..., 1] + Y * nxt[..., 2])
            si, di = np.nonzero(act)
            cidx = chan_of[u[si, di], v[si, di]]
            if (cidx < 0).any():
                raise KeyError("DOR needs torus links along every axis")
            crossed = ((step == 1) & (nxt_ax == 0)) | \
                ((step == -1) & (c_ax == 0))
            vc = np.where(act & crossed, np.int8(1), vc)
            h = hops[si, di]
            table.path[si, di, h] = cidx.astype(np.int32)
            table.vcs[si, di, h] = vc[si, di]
            hops[si, di] = h + 1
            cur = np.where(act[..., None], nxt, cur)
    return table


def dor_tables(topo: Topology, n_vc: int = 2) -> SimTables:
    table = dor_paths(topo)
    table.n_vc = n_vc
    return build_tables(topo, table)


def at_tables(topo: Topology, at: ATResult, routed: RoutingResult,
              balance: Optional[bool] = True,
              stats: Optional[dict] = None) -> SimTables:
    """VC-allocate the routed paths and build simulator tables.

    Works on a copy of ``routed.table`` so the caller's RoutingResult is
    not mutated and the returned SimTables cannot be rewritten by later
    allocations on the same result. Both table layouts pass through
    unchanged (a CSR table stays CSR -- and feeds the CSR-native kernel
    without ever densifying).

    ``balance=None`` skips re-allocation and keeps the VC assignment
    already in the table -- the array and sharded path-selection engines
    emit each winning candidate's BFS state-path VCs, which are valid by
    construction (fast path for large pods / fault sweeps where the
    balanced re-allocation is not needed). ``stats`` is forwarded to
    :func:`~repro.core.vcalloc.allocate_vcs` (greedy dead-end
    counters)."""
    from repro.core.vcalloc import allocate_vcs
    table = routed.table.copy()
    if balance is not None:
        allocate_vcs(at, table, balance=balance, stats=stats)
    table.n_vc = at.n_vc
    return build_tables(topo, table)
