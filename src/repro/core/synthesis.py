"""TONS topology synthesis: the dualized LR LP with edge variables.

Implements Table 1 of the paper over *our* (validated) MCF conventions:

  primal (P):  min  sum_p m_p d_p
               s.t. sum_{unordered pairs p} d_p >= 1            [lambda]
                    d_ij - d_ik - d_kj <= 0,
                        ordered triples, (i,k) in L_valid       [y_ijk]
                    d >= 0
  dual (TONS): max lambda
               s.t. for every unordered pair {a,b}:
                    lambda - sum_{k in Lv(a)} y[a,b,k]
                           - sum_{k in Lv(b)} y[b,a,k]
                           + [ (a,b) in Lv ] ( sum_j y[a,j,b]
                                             + sum_j y[b,j,a] )
                           + sum_{i in Lv(a)} y[i,b,a]
                           + sum_{i in Lv(b)} y[i,a,b]
                           <= m_ab
               lambda, y >= 0;  m in [0,1] constrained by C3 (one circuit
               per OCS port) with electrical m fixed to 1.

Scaling reductions: one-leg (y only for (i,k) in L_valid), edge/vertex
symmetry (cube translations collapse y to canonical sources and m to edge
orbits; constraints only for canonical pair classes), and Algorithm 3's
iterative LP relaxation with greedy integer fixing.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import topology as T
from repro.core.lp import COOMatrix, solve, solve_highs, solve_pdhg
from repro.core.mcf import PairCanon


@dataclasses.dataclass
class SynthesisLP:
    pod: T.Pod
    pc: PairCanon
    n_var: int
    c: np.ndarray
    A: COOMatrix
    b: np.ndarray
    lo: np.ndarray
    hi: np.ndarray
    m_slice: slice                      # m variables within x
    orbit_keys: List[int]               # orbit key per m var
    orbit_members: List[List[Tuple[int, int, int]]]   # (u, v, color)
    port_of: Dict[Tuple[int, int], int]  # (chip, axis) -> port row id


def _neighbors(pod: T.Pod, candidates):
    """L_valid adjacency: electrical + all candidate optical partners."""
    n = pod.n
    adj = [set() for _ in range(n)]
    for u, v in T.electrical_edges(pod):
        adj[int(u)].add(int(v))
        adj[int(v)].add(int(u))
    for u, v, _ in candidates:
        adj[u].add(v)
        adj[v].add(u)
    return [sorted(s) for s in adj]


def build_synthesis_lp(pod: T.Pod, symmetric: bool = True,
                       fault_f: Optional[int] = None,
                       pair_weight=None) -> SynthesisLP:
    n = pod.n
    perms = T.cube_translations(pod) if symmetric else \
        np.arange(n, dtype=np.int32)[None, :]
    pc = PairCanon(perms, n, directed=False)
    P = pc.perms
    g_of = pc.node_g

    candidates = T.valid_optical_pairs(pod)
    elec = {tuple(sorted(e)) for e in T.electrical_edges(pod).tolist()}
    cand_set = {(u, v): c for u, v, c in candidates}
    Lv = _neighbors(pod, candidates)

    # ---- m variables: orbits of candidate edges --------------------------
    cu = np.array([u for u, v, _ in candidates])
    cv = np.array([v for u, v, _ in candidates])
    ckeys = pc.key(cu, cv)
    orbit_map: Dict[int, int] = {}
    orbit_keys: List[int] = []
    orbit_members: List[List[Tuple[int, int, int]]] = []
    for (u, v, col), k in zip(candidates, ckeys.tolist()):
        if k not in orbit_map:
            orbit_map[k] = len(orbit_keys)
            orbit_keys.append(k)
            orbit_members.append([])
        orbit_members[orbit_map[k]].append((u, v, col))
    n_m = len(orbit_keys)

    # ---- y variables ------------------------------------------------------
    S = pc.sources.tolist()
    y_idx: Dict[Tuple[int, int, int], int] = {}
    for s in S:
        for k in Lv[s]:
            for j in range(n):
                if j != s and j != k:
                    y_idx[(s, j, k)] = len(y_idx)
    n_y = len(y_idx)

    # layout: [lambda | m (n_m) | y (n_y)]
    n_var = 1 + n_m + n_y
    m_off, y_off = 1, 1 + n_m

    def yv(i, j, k):
        """canonicalised y variable id for ordered triple (i, j, k)."""
        g = g_of[i]
        return y_off + y_idx[(int(P[g, i]), int(P[g, j]), int(P[g, k]))]

    rows: List[np.ndarray] = []
    cols: List[np.ndarray] = []
    vals: List[np.ndarray] = []
    b: List[float] = []
    r = 0

    def add(rr, cc, vv):
        rows.append(np.asarray(rr, np.int64))
        cols.append(np.asarray(cc, np.int64))
        vals.append(np.asarray(vv, np.float64))

    # ---- C4 rows: one per canonical unordered pair class ------------------
    seen_pairs = set()
    for a in S:
        for bb in range(n):
            if bb == a:
                continue
            key = pc.key(np.array([a]), np.array([bb]))[0]
            if key in seen_pairs:
                continue
            seen_pairs.add(key)
            rc, cc, vv = [], [], []
            cc.append(0)
            # +w_ab * lambda (w == 1 for uniform all-to-all demand)
            wab = 1.0 if pair_weight is None else float(
                pair_weight(np.array([a]), np.array([bb]))[0])
            if wab <= 0.0:
                wab = 0.0
            vv.append(wab)
            for (x0, x1) in ((a, bb), (bb, a)):
                for k in Lv[x0]:
                    if k != x1:
                        cc.append(yv(x0, x1, k))
                        vv.append(-1.0)
            in_lv = bb in Lv[a]
            if in_lv:
                for (x0, x1) in ((a, bb), (bb, a)):
                    for j in range(n):
                        if j != a and j != bb:
                            cc.append(yv(x0, j, x1))
                            vv.append(1.0)
            for (x0, x1) in ((a, bb), (bb, a)):
                # + sum_{i in Lv(x1)} y[i, x0, x1]
                for i in Lv[x1]:
                    if i != x0:
                        cc.append(yv(i, x0, x1))
                        vv.append(1.0)
            u, v = min(a, bb), max(a, bb)
            rhs = 0.0
            if (u, v) in elec:
                rhs = 1.0
            elif (u, v) in cand_set:
                cc.append(m_off + orbit_map[int(key)] if in_lv else
                          m_off + orbit_map[int(pc.key(np.array([u]),
                                                       np.array([v]))[0])])
                vv.append(-1.0)
            add([r] * len(cc), cc, vv)
            b.append(rhs)
            r += 1

    # ---- C3: one circuit per canonical port (equality as two ineqs) ------
    port_rows: Dict[Tuple[int, int], List[int]] = defaultdict(list)
    canon_chips = set(S)
    port_of: Dict[Tuple[int, int], int] = {}
    for oi, members in enumerate(orbit_members):
        for (u, v, col) in members:
            axis = col // T.N_POS
            for chip in (u, v):
                if chip in canon_chips:
                    port_rows[(chip, axis)].append(oi)
    for pid, ((chip, axis), olist) in enumerate(sorted(port_rows.items())):
        port_of[(chip, axis)] = pid
        ouniq, ocnt = np.unique(olist, return_counts=True)
        add([r] * len(ouniq), m_off + ouniq, ocnt.astype(np.float64))
        b.append(1.0)
        r += 1
        add([r] * len(ouniq), m_off + ouniq, -ocnt.astype(np.float64))
        b.append(-1.0)
        r += 1

    # ---- C8: fault tolerance lambda >= (f+1)/(32 n) -----------------------
    if fault_f is not None:
        add([r], [0], [-1.0])
        b.append(-(fault_f + 1) / (32.0 * n))
        r += 1

    A = COOMatrix.from_triplets(np.concatenate(rows), np.concatenate(cols),
                                np.concatenate(vals), (r, n_var))
    c = np.zeros(n_var)
    c[0] = -1.0  # max lambda
    lo = np.zeros(n_var)
    hi = np.ones(n_var)
    hi[0] = 1.0
    return SynthesisLP(pod, pc, n_var, c, A, np.asarray(b), lo, hi,
                       slice(m_off, m_off + n_m), orbit_keys, orbit_members,
                       port_of)


def _orbit_ports(members) -> List[Tuple[int, int]]:
    out = []
    for (u, v, col) in members:
        axis = col // T.N_POS
        out.append((u, axis))
        out.append((v, axis))
    return out


@dataclasses.dataclass
class SynthesisResult:
    topology: T.Topology
    lambdas: List[float]          # LP objective per greedy iterate
    times: List[float]
    status: str


def synthesize(podspec: Tuple[int, int, int], symmetric: bool = True,
               interval: int = 1, fault_f: Optional[int] = None,
               prefer: str = "auto", verbose: bool = False,
               max_lp_iters: int = 12000, tol: float = 2e-4,
               pair_weight=None) -> SynthesisResult:
    """Algorithm 3: iterative relaxed LP + greedy integral fixing."""
    pod = T.Pod(podspec)
    lp = build_synthesis_lp(pod, symmetric=symmetric, fault_f=fault_f,
                            pair_weight=pair_weight)
    lo, hi = lp.lo.copy(), lp.hi.copy()
    n_m = lp.m_slice.stop - lp.m_slice.start

    used_ports = set()
    fixed = np.zeros(n_m, bool)
    blocked = np.zeros(n_m, bool)
    lambdas: List[float] = []
    times: List[float] = []
    t0 = time.time()
    x_prev = y_prev = None

    def feasible(oi):
        if fixed[oi] or blocked[oi]:
            return False
        return all(p not in used_ports for p in
                   _orbit_ports(lp.orbit_members[oi]))

    def fix(oi):
        fixed[oi] = True
        lo[lp.m_slice][oi] = hi[lp.m_slice][oi] = 1.0
        for p in _orbit_ports(lp.orbit_members[oi]):
            used_ports.add(p)
        for oj in range(n_m):
            if not fixed[oj] and not blocked[oj] and not feasible(oj):
                blocked[oj] = True
                hi[lp.m_slice][oj] = 0.0

    status = "ok"
    while True:
        remaining = [oi for oi in range(n_m) if feasible(oi)]
        if not remaining:
            break
        use_ipm = prefer in ("highs", "ipm") or \
            (prefer == "auto" and lp.n_var < 2_000_000)
        if use_ipm:
            # interior point (the paper found IPM fastest too, Section 2.3)
            res = solve_highs(lp.c, lp.A, lp.b, lo, hi, method="highs-ipm")
        else:
            res = solve_pdhg(lp.c, lp.A, lp.b, lo, hi,
                             max_iters=max_lp_iters, tol=tol,
                             x0=x_prev, y0=y_prev, verbose=False)
            x_prev, y_prev = res.x, res.y
        lam = -res.obj
        lambdas.append(lam)
        times.append(time.time() - t0)
        if verbose:
            print(f"  synth it={len(lambdas)} lambda={lam:.6f} "
                  f"fixed={int(fixed.sum())}/{n_m} ({res.status})")
        if res.status not in ("optimal", "max_iters"):
            status = res.status
            # fall back to arbitrary feasible completion
            for oi in remaining:
                if feasible(oi):
                    fix(oi)
            break
        mv = res.x[lp.m_slice].copy()
        mv[~np.array([feasible(oi) for oi in range(n_m)])] = -np.inf
        order = np.argsort(-mv)
        picked = 0
        for oi in order:
            if picked >= interval:
                break
            if feasible(int(oi)) and mv[int(oi)] > -np.inf:
                fix(int(oi))
                picked += 1
        if picked == 0:
            for oi in remaining:
                if feasible(oi):
                    fix(oi)
                    break

    optical = []
    for oi in range(n_m):
        if fixed[oi]:
            optical.extend(lp.orbit_members[oi])
    optical = sorted(set(optical))
    topo = T.Topology(pod, optical,
                      name=f"TONS{'_SYM' if symmetric else ''} {podspec}")
    return SynthesisResult(topo, lambdas, times, status)
