"""TONS topology synthesis: the dualized LR LP with edge variables.

Implements Table 1 of the paper over *our* (validated) MCF conventions:

  primal (P):  min  sum_p m_p d_p
               s.t. sum_{unordered pairs p} d_p >= 1            [lambda]
                    d_ij - d_ik - d_kj <= 0,
                        ordered triples, (i,k) in L_valid       [y_ijk]
                    d >= 0
  dual (TONS): max lambda
               s.t. for every unordered pair {a,b}:
                    lambda - sum_{k in Lv(a)} y[a,b,k]
                           - sum_{k in Lv(b)} y[b,a,k]
                           + [ (a,b) in Lv ] ( sum_j y[a,j,b]
                                             + sum_j y[b,j,a] )
                           + sum_{i in Lv(a)} y[i,b,a]
                           + sum_{i in Lv(b)} y[i,a,b]
                           <= m_ab
               lambda, y >= 0;  m in [0,1] constrained by C3 (one circuit
               per OCS port) with electrical m fixed to 1.

Scaling reductions: one-leg (y only for (i,k) in L_valid), edge/vertex
symmetry (cube translations collapse y to canonical sources and m to edge
orbits; constraints only for canonical pair classes), and Algorithm 3's
iterative LP relaxation with greedy integer fixing.

Engineering (PR 5): the LP rows/columns are assembled as ragged-CSR
cross-products (``engine="batched"``, the default) -- no per-pair python
loops -- with the seed's dict/loop construction kept as
``engine="reference"``, the bit-exactness oracle. The greedy fixing loop
is batched: each LP re-solve fixes a *block* of mutually port-compatible
orbit variables (warm-started PDHG between rounds), and a final
edge-granularity matching completion fills any ports the orbit-level
greedy could not cover, so synthesized pods always come out radix-6.
``SynthesisResult.to_topology`` + :func:`evaluate_end_to_end` wire the
synthesized edge set through the full stack: ``Channels.from_topology``
-> ``allowed_turns`` -> ``select_paths(engine="sharded")`` -> VC
allocation -> deadlock-free verification -> (optional) netsim saturation.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import topology as T
from repro.core.lp import COOMatrix, solve_highs, solve_pdhg
from repro.core.mcf import PairCanon

# above this variable count the HiGHS oracle stops being competitive on
# this container and synthesize() switches to warm-started PDHG rounds
HIGHS_VAR_CAP = 2_000_000
# above this variable count: loosen the IPM tolerance (the fixing loop
# only consumes the ordering of the fractional m values) and cut the
# number of LP re-solves -- at 8^3 one exact solve is ~4.5 min, and
# matrix-free PDHG needs >10 min to reach a usable gap on this LP
LARGE_LP_VARS = 200_000


@dataclasses.dataclass
class SynthesisLP:
    pod: T.Pod
    pc: PairCanon
    n_var: int
    c: np.ndarray
    A: COOMatrix
    b: np.ndarray
    lo: np.ndarray
    hi: np.ndarray
    m_slice: slice                      # m variables within x
    orbit_keys: List[int]               # orbit key per m var
    orbit_members: List[List[Tuple[int, int, int]]]   # (u, v, color)
    port_of: Dict[Tuple[int, int], int]  # (chip, axis) -> port row id


def _neighbors(pod: T.Pod, candidates):
    """L_valid adjacency: electrical + all candidate optical partners."""
    n = pod.n
    adj = [set() for _ in range(n)]
    for u, v in T.electrical_edges(pod):
        adj[int(u)].add(int(v))
        adj[int(v)].add(int(u))
    for u, v, _ in candidates:
        adj[u].add(v)
        adj[v].add(u)
    return [sorted(s) for s in adj]


def build_synthesis_lp(pod: T.Pod, symmetric: bool = True,
                       fault_f: Optional[int] = None,
                       pair_weight=None,
                       engine: str = "batched") -> SynthesisLP:
    """Build the dual synthesis LP.

    ``engine="batched"`` (default) assembles all rows as vectorised
    ragged-CSR cross-products; ``engine="reference"`` is the seed's
    per-pair python loop. Both produce the *identical* variable layout
    and (up to COO duplicate coalescing) the identical matrix -- the
    equivalence is asserted in ``tests/test_synthesis.py``.
    """
    if engine == "reference":
        return _build_synthesis_lp_reference(pod, symmetric, fault_f,
                                             pair_weight)
    if engine != "batched":
        raise ValueError(f"unknown engine {engine!r}")
    return _build_synthesis_lp_batched(pod, symmetric, fault_f, pair_weight)


# ---------------------------------------------------------------------------
# Batched builder: ragged-CSR cross-products, no per-pair python loops
# ---------------------------------------------------------------------------


def _expand_csr(indptr: np.ndarray, indices: np.ndarray,
                nodes: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Cross-product expansion of CSR rows: for ``nodes[i]`` with degree
    d_i, emit (i repeated d_i times, the d_i neighbors)."""
    deg = (indptr[nodes + 1] - indptr[nodes]).astype(np.int64)
    total = int(deg.sum())
    rr = np.repeat(np.arange(len(nodes), dtype=np.int64), deg)
    base = np.repeat(indptr[nodes].astype(np.int64), deg)
    within = np.arange(total, dtype=np.int64) - \
        np.repeat(np.cumsum(deg) - deg, deg)
    return rr, indices[base + within].astype(np.int64)


def _first_occurrence_unique(keys: np.ndarray):
    """(unique keys in first-occurrence order, their first index,
    rank-per-element) -- reproduces python dict insertion-order dedup."""
    uk, first, inv = np.unique(keys, return_index=True, return_inverse=True)
    order = np.argsort(first, kind="stable")
    rank = np.empty(len(uk), np.int64)
    rank[order] = np.arange(len(uk))
    return uk[order], first[order], rank[inv]


def _build_synthesis_lp_batched(pod: T.Pod, symmetric: bool,
                                fault_f: Optional[int],
                                pair_weight) -> SynthesisLP:
    n = pod.n
    perms = T.cube_translations(pod) if symmetric else \
        np.arange(n, dtype=np.int32)[None, :]
    pc = PairCanon(perms, n, directed=False)
    P = pc.perms
    g_of = pc.node_g
    S = pc.sources.astype(np.int64)

    cu, cv, ccol = T.valid_optical_pairs_arrays(pod)
    elec = T.electrical_edges(pod).astype(np.int64)

    # ---- L_valid adjacency as one deduplicated CSR (sorted neighbors) ----
    eu = np.concatenate([elec[:, 0], elec[:, 1], cu, cv])
    ev = np.concatenate([elec[:, 1], elec[:, 0], cv, cu])
    adj_keys = np.unique(eu.astype(np.int64) * n + ev.astype(np.int64))
    au = adj_keys // n
    av = adj_keys % n
    indptr = np.searchsorted(au, np.arange(n + 1)).astype(np.int64)

    # ---- m variables: orbits of candidate edges (first-occurrence ids) ---
    ckeys = pc.key(cu, cv)
    okeys, _, oid = _first_occurrence_unique(ckeys)
    n_m = len(okeys)
    osort = np.argsort(oid, kind="stable")
    osizes = np.bincount(oid, minlength=n_m)
    orbit_members: List[List[Tuple[int, int, int]]] = []
    mem = np.stack([cu[osort], cv[osort], ccol[osort]], axis=1)
    pos = 0
    for sz in osizes.tolist():
        orbit_members.append(
            [tuple(r) for r in mem[pos:pos + sz].tolist()])
        pos += sz
    # key -> orbit id lookup over the sorted key array
    okey_sort = np.argsort(okeys, kind="stable")
    okeys_sorted = okeys[okey_sort]

    # ---- y variables: (s, k in Lv[s], j != s,k) for canonical sources ----
    # identical ids to the reference dict: s ascending, k ascending within
    # Lv[s], j ascending with s and k skipped -> block offset arithmetic.
    sdeg = (indptr[S + 1] - indptr[S]).astype(np.int64)
    n_sk = int(sdeg.sum())
    sk_rows = np.repeat(S, sdeg)
    _, sk_cols = _expand_csr(indptr, av, S)
    ypos = np.full((n, n), -1, np.int32)
    ypos[sk_rows, sk_cols] = np.arange(n_sk, dtype=np.int32)
    n_y = n_sk * (n - 2)

    n_var = 1 + n_m + n_y
    m_off, y_off = 1, 1 + n_m

    def yv(i, j, k):
        """Canonicalised y column ids for ordered-triple arrays."""
        g = g_of[i]
        ci = P[g, i]
        cj = P[g, j]
        ck = P[g, k]
        base = ypos[ci, ck].astype(np.int64)
        off = cj - (cj > ci) - (cj > ck)
        return y_off + base * (n - 2) + off

    # ---- canonical unordered pair classes, in the reference row order ----
    aa = np.repeat(S, n)
    bb = np.tile(np.arange(n, dtype=np.int64), len(S))
    keep = aa != bb
    aa, bb = aa[keep], bb[keep]
    pkeys_all = pc.key(aa, bb)
    _, first, _ = _first_occurrence_unique(pkeys_all)
    pa, pb = aa[first], bb[first]
    pkeys = pkeys_all[first]
    R = len(pa)

    rows: List[np.ndarray] = []
    cols: List[np.ndarray] = []
    vals: List[np.ndarray] = []

    def add(rr, cc, vv):
        rows.append(np.asarray(rr, np.int64))
        cols.append(np.asarray(cc, np.int64))
        vals.append(np.asarray(vv, np.float64))

    # lambda coefficient (w == 1 for uniform all-to-all demand)
    if pair_weight is None:
        wab = np.ones(R)
    else:
        wab = np.asarray(pair_weight(pa, pb), np.float64)
        wab = np.where(wab <= 0.0, 0.0, wab)
    add(np.arange(R), np.zeros(R, np.int64), wab)

    # -sum_{k in Lv(x0), k != x1} y[x0, x1, k], both pair orders
    for x0, x1 in ((pa, pb), (pb, pa)):
        rr, kk = _expand_csr(indptr, av, x0)
        m = kk != x1[rr]
        add(rr[m], yv(x0[rr[m]], x1[rr[m]], kk[m]), -np.ones(int(m.sum())))

    # + sum_j y[x0, j, x1] for adjacent pairs only
    adj_mask = np.isin(pa * n + pb, adj_keys)
    radj = np.nonzero(adj_mask)[0]
    if len(radj):
        rr3 = np.repeat(radj, n)
        jj = np.tile(np.arange(n, dtype=np.int64), len(radj))
        m3 = (jj != pa[rr3]) & (jj != pb[rr3])
        rr3, jj = rr3[m3], jj[m3]
        for x0, x1 in ((pa, pb), (pb, pa)):
            add(rr3, yv(x0[rr3], jj, x1[rr3]), np.ones(len(jj)))

    # + sum_{i in Lv(x1), i != x0} y[i, x0, x1], both pair orders
    for x0, x1 in ((pa, pb), (pb, pa)):
        rr, ii = _expand_csr(indptr, av, x1)
        m = ii != x0[rr]
        add(rr[m], yv(ii[m], x0[rr[m]], x1[rr[m]]), np.ones(int(m.sum())))

    # -m[orbit] for candidate pair classes; rhs 1 for electrical pairs
    is_cand = np.isin(pkeys, okeys_sorted)
    rc = np.nonzero(is_cand)[0]
    coid = okey_sort[np.searchsorted(okeys_sorted, pkeys[rc])]
    add(rc, m_off + coid, -np.ones(len(rc)))
    ekeys = np.sort(np.minimum(elec[:, 0], elec[:, 1]) * n +
                    np.maximum(elec[:, 0], elec[:, 1]))
    b_pairs = np.isin(np.minimum(pa, pb) * n + np.maximum(pa, pb),
                      ekeys).astype(np.float64)

    # ---- C3: one circuit per canonical port (equality as two ineqs) ------
    is_canon = np.zeros(n, bool)
    is_canon[S] = True
    caxis = (ccol // T.N_POS).astype(np.int64)
    ends_chip = np.concatenate([cu.astype(np.int64), cv.astype(np.int64)])
    ends_axis = np.concatenate([caxis, caxis])
    ends_oid = np.concatenate([oid, oid])
    sel = is_canon[ends_chip]
    pkey = ends_chip[sel] * 3 + ends_axis[sel]
    poid = ends_oid[sel]
    combo = pkey * n_m + poid
    ucombo, ucnt = np.unique(combo, return_counts=True)
    gp, go = ucombo // n_m, ucombo % n_m
    port_ids = np.unique(gp)                 # sorted == seed's sorted items
    gidx = np.searchsorted(port_ids, gp)
    r3 = R + 2 * gidx
    add(r3, m_off + go, ucnt.astype(np.float64))
    add(r3 + 1, m_off + go, -ucnt.astype(np.float64))
    b3 = np.tile([1.0, -1.0], len(port_ids))
    port_of = {(int(p) // 3, int(p) % 3): i
               for i, p in enumerate(port_ids.tolist())}
    r = R + 2 * len(port_ids)

    # ---- C8: fault tolerance lambda >= (f+1)/(32 n) -----------------------
    b_parts = [b_pairs, b3]
    if fault_f is not None:
        add([r], [0], [-1.0])
        b_parts.append(np.array([-(fault_f + 1) / (32.0 * n)]))
        r += 1

    A = COOMatrix.from_triplets(np.concatenate(rows), np.concatenate(cols),
                                np.concatenate(vals), (r, n_var))
    c = np.zeros(n_var)
    c[0] = -1.0  # max lambda
    lo = np.zeros(n_var)
    hi = np.ones(n_var)
    return SynthesisLP(pod, pc, n_var, c, A, np.concatenate(b_parts), lo,
                       hi, slice(m_off, m_off + n_m), okeys.tolist(),
                       orbit_members, port_of)


# ---------------------------------------------------------------------------
# Reference builder: the seed's per-pair loops, kept as exactness oracle
# ---------------------------------------------------------------------------


def _build_synthesis_lp_reference(pod: T.Pod, symmetric: bool,
                                  fault_f: Optional[int],
                                  pair_weight) -> SynthesisLP:
    n = pod.n
    perms = T.cube_translations(pod) if symmetric else \
        np.arange(n, dtype=np.int32)[None, :]
    pc = PairCanon(perms, n, directed=False)
    P = pc.perms
    g_of = pc.node_g

    candidates = T.valid_optical_pairs(pod)
    elec = {tuple(sorted(e)) for e in T.electrical_edges(pod).tolist()}
    cand_set = {(u, v): c for u, v, c in candidates}
    Lv = _neighbors(pod, candidates)

    # ---- m variables: orbits of candidate edges --------------------------
    cu = np.array([u for u, v, _ in candidates])
    cv = np.array([v for u, v, _ in candidates])
    ckeys = pc.key(cu, cv)
    orbit_map: Dict[int, int] = {}
    orbit_keys: List[int] = []
    orbit_members: List[List[Tuple[int, int, int]]] = []
    for (u, v, col), k in zip(candidates, ckeys.tolist()):
        if k not in orbit_map:
            orbit_map[k] = len(orbit_keys)
            orbit_keys.append(k)
            orbit_members.append([])
        orbit_members[orbit_map[k]].append((u, v, col))
    n_m = len(orbit_keys)

    # ---- y variables ------------------------------------------------------
    S = pc.sources.tolist()
    y_idx: Dict[Tuple[int, int, int], int] = {}
    for s in S:
        for k in Lv[s]:
            for j in range(n):
                if j != s and j != k:
                    y_idx[(s, j, k)] = len(y_idx)
    n_y = len(y_idx)

    # layout: [lambda | m (n_m) | y (n_y)]
    n_var = 1 + n_m + n_y
    m_off, y_off = 1, 1 + n_m

    def yv(i, j, k):
        """canonicalised y variable id for ordered triple (i, j, k)."""
        g = g_of[i]
        return y_off + y_idx[(int(P[g, i]), int(P[g, j]), int(P[g, k]))]

    rows: List[np.ndarray] = []
    cols: List[np.ndarray] = []
    vals: List[np.ndarray] = []
    b: List[float] = []
    r = 0

    def add(rr, cc, vv):
        rows.append(np.asarray(rr, np.int64))
        cols.append(np.asarray(cc, np.int64))
        vals.append(np.asarray(vv, np.float64))

    # ---- C4 rows: one per canonical unordered pair class ------------------
    seen_pairs = set()
    for a in S:
        for bb in range(n):
            if bb == a:
                continue
            key = pc.key(np.array([a]), np.array([bb]))[0]
            if key in seen_pairs:
                continue
            seen_pairs.add(key)
            rc, cc, vv = [], [], []
            cc.append(0)
            # +w_ab * lambda (w == 1 for uniform all-to-all demand)
            wab = 1.0 if pair_weight is None else float(
                pair_weight(np.array([a]), np.array([bb]))[0])
            if wab <= 0.0:
                wab = 0.0
            vv.append(wab)
            for (x0, x1) in ((a, bb), (bb, a)):
                for k in Lv[x0]:
                    if k != x1:
                        cc.append(yv(x0, x1, k))
                        vv.append(-1.0)
            in_lv = bb in Lv[a]
            if in_lv:
                for (x0, x1) in ((a, bb), (bb, a)):
                    for j in range(n):
                        if j != a and j != bb:
                            cc.append(yv(x0, j, x1))
                            vv.append(1.0)
            for (x0, x1) in ((a, bb), (bb, a)):
                # + sum_{i in Lv(x1)} y[i, x0, x1]
                for i in Lv[x1]:
                    if i != x0:
                        cc.append(yv(i, x0, x1))
                        vv.append(1.0)
            u, v = min(a, bb), max(a, bb)
            rhs = 0.0
            if (u, v) in elec:
                rhs = 1.0
            elif (u, v) in cand_set:
                cc.append(m_off + orbit_map[int(key)] if in_lv else
                          m_off + orbit_map[int(pc.key(np.array([u]),
                                                       np.array([v]))[0])])
                vv.append(-1.0)
            add([r] * len(cc), cc, vv)
            b.append(rhs)
            r += 1

    # ---- C3: one circuit per canonical port (equality as two ineqs) ------
    port_rows: Dict[Tuple[int, int], List[int]] = defaultdict(list)
    canon_chips = set(S)
    port_of: Dict[Tuple[int, int], int] = {}
    for oi, members in enumerate(orbit_members):
        for (u, v, col) in members:
            axis = col // T.N_POS
            for chip in (u, v):
                if chip in canon_chips:
                    port_rows[(chip, axis)].append(oi)
    for pid, ((chip, axis), olist) in enumerate(sorted(port_rows.items())):
        port_of[(chip, axis)] = pid
        ouniq, ocnt = np.unique(olist, return_counts=True)
        add([r] * len(ouniq), m_off + ouniq, ocnt.astype(np.float64))
        b.append(1.0)
        r += 1
        add([r] * len(ouniq), m_off + ouniq, -ocnt.astype(np.float64))
        b.append(-1.0)
        r += 1

    # ---- C8: fault tolerance lambda >= (f+1)/(32 n) -----------------------
    if fault_f is not None:
        add([r], [0], [-1.0])
        b.append(-(fault_f + 1) / (32.0 * n))
        r += 1

    A = COOMatrix.from_triplets(np.concatenate(rows), np.concatenate(cols),
                                np.concatenate(vals), (r, n_var))
    c = np.zeros(n_var)
    c[0] = -1.0  # max lambda
    lo = np.zeros(n_var)
    hi = np.ones(n_var)
    return SynthesisLP(pod, pc, n_var, c, A, np.asarray(b), lo, hi,
                       slice(m_off, m_off + n_m), orbit_keys, orbit_members,
                       port_of)


@dataclasses.dataclass
class SynthesisResult:
    topology: T.Topology
    lambdas: List[float]          # LP objective per greedy iterate
    times: List[float]
    status: str
    n_orbits: int = 0
    n_fixed: int = 0
    n_completed: int = 0          # edges added by the matching completion
    stats: Optional[dict] = None  # LP sizes + per-round solver detail

    @property
    def lp_lambda(self) -> float:
        """Final LP-relaxation objective (upper-bounds the integral MCF
        of the completed topology up to solver tolerance)."""
        return self.lambdas[-1] if self.lambdas else float("nan")

    def to_topology(self) -> T.Topology:
        """The synthesized topology, ready for ``Channels.from_topology``
        -> ``allowed_turns`` -> ``select_paths`` -> VC alloc -> netsim."""
        return self.topology


def synthesize(podspec: Tuple[int, int, int], symmetric: bool = True,
               interval: Optional[int] = None, fault_f: Optional[int] = None,
               prefer: str = "auto", verbose: bool = False,
               max_lp_iters: int = 12000, tol: float = 2e-4,
               pair_weight=None, lp_engine: str = "batched",
               complete: bool = True, target_rounds: int = 10,
               min_frac: float = 0.02) -> SynthesisResult:
    """Algorithm 3: iterative relaxed LP + batched greedy integral fixing.

    ``interval`` is the number of orbit variables fixed per LP re-solve
    (the paper's interval parameter); ``None`` picks a block size that
    lands the full greedy in ~``target_rounds`` LP solves. Each round
    fixes the top fractional-value orbits that are mutually
    port-compatible; orbits whose value falls below ``min_frac`` are left
    for the next re-solve (fixing zero-value orbits early is how a big
    block loses throughput). PDHG rounds are warm-started from the
    previous solve's primal/dual iterates. ``complete=True`` finishes any
    ports the orbit-level greedy left unmatched with a per-OCS matching
    at edge granularity (breaking orbit symmetry only where the LP left
    no symmetric choice), so the result is always a full radix-6 fabric.
    """
    pod = T.Pod(podspec)
    t0 = time.time()
    lp = build_synthesis_lp(pod, symmetric=symmetric, fault_f=fault_f,
                            pair_weight=pair_weight, engine=lp_engine)
    t_build = time.time() - t0
    lo, hi = lp.lo.copy(), lp.hi.copy()
    n_m = lp.m_slice.stop - lp.m_slice.start
    n = pod.n

    # ---- vectorised orbit/port bookkeeping -------------------------------
    osizes = np.array([len(m) for m in lp.orbit_members], np.int64)
    flat = np.array([(u, v, c) for mem in lp.orbit_members
                     for (u, v, c) in mem], np.int64).reshape(-1, 3)
    maxis = flat[:, 2] // T.N_POS
    # per-orbit port list (chip * 3 + axis), orbit-major
    op_ports = np.stack([flat[:, 0] * 3 + maxis,
                         flat[:, 1] * 3 + maxis], axis=1).ravel()
    op_oid = np.repeat(np.arange(n_m), 2 * osizes)
    op_indptr = np.searchsorted(op_oid, np.arange(n_m + 1))
    # reverse map: port -> orbits touching it
    psort = np.argsort(op_ports, kind="stable")
    rev_ports = op_ports[psort]
    rev_oid = op_oid[psort]
    rev_indptr = np.searchsorted(rev_ports, np.arange(3 * n + 1))
    # orbits whose own members already collide on a port can never be
    # integral (C3 caps them at 1/2) -- block them up front
    dup = np.zeros(n_m, bool)
    okey = op_oid * (3 * n) + op_ports
    oks = np.sort(okey)
    same = oks[1:] == oks[:-1]
    dup[(oks[1:] // (3 * n))[same]] = True

    used = np.zeros(3 * n, bool)
    fixed = np.zeros(n_m, bool)
    blocked = dup.copy()
    hi[lp.m_slice][blocked] = 0.0

    def fix(oi: int) -> None:
        fixed[oi] = True
        lo[lp.m_slice][oi] = hi[lp.m_slice][oi] = 1.0
        pts = op_ports[op_indptr[oi]:op_indptr[oi + 1]]
        used[pts] = True
        for p in pts.tolist():
            aff = rev_oid[rev_indptr[p]:rev_indptr[p + 1]]
            nb = aff[~fixed[aff]]
            blocked[nb] = True
            hi[lp.m_slice][nb] = 0.0

    def live_feasible(oi: int) -> bool:
        return not fixed[oi] and not blocked[oi] and \
            not used[op_ports[op_indptr[oi]:op_indptr[oi + 1]]].any()

    if interval is None:
        # aim for ~target_rounds LP solves: estimate the total number of
        # orbit fixes as ports / (2 * mean orbit size); large instances
        # (expensive solves) get a third of the rounds
        mean_sz = max(float(osizes.mean()) if n_m else 1.0, 1.0)
        n_ports = int((rev_indptr[1:] > rev_indptr[:-1]).sum())
        est_fixes = max(1, int(np.ceil(n_ports / (2.0 * mean_sz))))
        rounds = target_rounds if lp.n_var < LARGE_LP_VARS \
            else max(3, target_rounds // 3)
        interval = max(1, -(-est_fixes // rounds))

    lambdas: List[float] = []
    times: List[float] = []
    solve_log: List[dict] = []
    x_prev = y_prev = None
    status = "ok"
    while True:
        feas = ~fixed & ~blocked
        if not feas.any():
            break
        use_ipm = prefer in ("highs", "ipm") or \
            (prefer == "auto" and lp.n_var < HIGHS_VAR_CAP)
        ts = time.time()
        if use_ipm:
            # interior point (the paper found IPM fastest too, Section 2.3)
            opts = {"ipm_optimality_tolerance": 1e-4} \
                if lp.n_var >= LARGE_LP_VARS else {}
            res = solve_highs(lp.c, lp.A, lp.b, lo, hi, method="highs-ipm",
                              **opts)
        else:
            res = solve_pdhg(lp.c, lp.A, lp.b, lo, hi,
                             max_iters=max_lp_iters, tol=tol,
                             x0=x_prev, y0=y_prev, verbose=False)
            x_prev, y_prev = res.x, res.y
        solve_log.append({"solver": "highs-ipm" if use_ipm else "pdhg",
                          "s": round(time.time() - ts, 3),
                          "status": res.status,
                          "iters": getattr(res, "iters", 0)})
        lam = -res.obj
        if verbose:
            print(f"  synth it={len(lambdas) + 1} lambda={lam:.6f} "
                  f"fixed={int(fixed.sum())}/{n_m} ({res.status} "
                  f"{solve_log[-1]['s']:.1f}s)")
        if res.status not in ("optimal", "max_iters"):
            # failed solve: don't record its bogus objective as a lambda
            status = res.status
            # fall back to arbitrary feasible completion
            for oi in range(n_m):
                if live_feasible(oi):
                    fix(oi)
            break
        lambdas.append(lam)
        times.append(time.time() - t0)
        mv = res.x[lp.m_slice].copy()
        mv[~feas] = -np.inf
        order = np.argsort(-mv, kind="stable")
        picked = 0
        for oi in order.tolist():
            if picked >= interval:
                break
            if mv[oi] == -np.inf:
                break
            if picked > 0 and mv[oi] < min_frac:
                break   # leave low-value orbits for the next re-solve
            if live_feasible(oi):
                fix(oi)
                picked += 1
        if picked == 0:
            # progress guarantee: the single best feasible orbit
            for oi in order.tolist():
                if mv[oi] == -np.inf:
                    break
                if live_feasible(oi):
                    fix(oi)
                    picked = 1
                    break
        if picked == 0:
            break

    optical = []
    for oi in range(n_m):
        if fixed[oi]:
            optical.extend(lp.orbit_members[oi])

    # ---- matching completion: fill leftover ports per OCS group ----------
    n_completed = 0
    if complete:
        by_color: Dict[int, List[int]] = defaultdict(list)
        for p in T.ports(pod):
            if not used[p.chip * 3 + p.axis]:
                by_color[p.color].append(p.chip)
        for color in sorted(by_color):
            chips = sorted(by_color[color])
            half = len(chips) // 2
            for i in range(half):
                u, v = chips[i], chips[i + half]
                optical.append((min(u, v), max(u, v), color))
                n_completed += 1

    optical = sorted(set(optical))
    topo = T.Topology(pod, optical,
                      name=f"TONS{'_SYM' if symmetric else ''} {podspec}")
    return SynthesisResult(
        topo, lambdas, times, status,
        n_orbits=n_m, n_fixed=int(fixed.sum()), n_completed=n_completed,
        stats={"n_var": lp.n_var, "n_rows": lp.A.shape[0],
               "nnz": len(lp.A.vals), "build_s": round(t_build, 3),
               "interval": int(interval), "solves": solve_log,
               "wall_s": round(time.time() - t0, 3)})


# ---------------------------------------------------------------------------
# End-to-end wiring: synthesized topology -> routed, verified pod
# ---------------------------------------------------------------------------


def evaluate_end_to_end(topo: T.Topology, n_vc: int = 2, K: int = 4,
                        select_engine: str = "sharded",
                        local_search_rounds: int = 2, seed: int = 0,
                        priority: str = "apl", saturation: bool = False,
                        sat_kwargs: Optional[dict] = None) -> dict:
    """Route a (synthesized) topology through the production pipeline
    (:func:`repro.core.pipeline.route_pod`) and report scalars:
    allowed turns -> path selection -> VC allocation -> deadlock-free
    verification -> (optionally) netsim saturation throughput.
    """
    from repro.core import netsim as NS, routing as R
    from repro.core.pipeline import PipelineConfig, route_pod

    out: dict = {"n": topo.n, "name": topo.name}
    cfg = PipelineConfig(n_vc=n_vc, K=K, priority=priority, seed=seed,
                         engine=select_engine,
                         local_search_rounds=local_search_rounds,
                         verify=True)
    rp = route_pod(topo, cfg)
    out["at_s"] = round(rp.timings["at_s"], 3)
    out["n_allowed_turns"] = len(rp.at.allowed)
    out["select_s"] = round(rp.timings["select_s"], 3)
    out["l_max"] = rp.l_max
    out["avg_hops"] = round(rp.avg_hops, 4)
    out["unreachable"] = rp.unreachable
    out["load_lower_bound"] = float(R.load_lower_bound(topo))
    tab = rp.tables
    out["vcalloc_tables_s"] = round(rp.timings["vc_s"], 3)
    out["vc_greedy_dead_ends"] = int(rp.vc_stats.get("greedy_dead_ends", 0))
    out["deadlock_free"] = bool(rp.deadlock_free)
    out["end_to_end_s"] = round(out["at_s"] + out["select_s"] +
                                out["vcalloc_tables_s"], 3)
    if saturation:
        sstats: dict = {}
        t0 = time.time()
        sat, _ = NS.saturation_point(tab, stats=sstats,
                                     **(sat_kwargs or {}))
        out["saturation"] = round(float(sat), 5)
        out["saturation_s"] = round(time.time() - t0, 3)
        out["sim_kernel"] = sstats.get("kernel", "csr")
        out["sim_array_bytes"] = int(sstats.get("array_bytes", 0))
    return out
