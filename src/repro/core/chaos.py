"""Chaos campaign engine: multi-fault schedules, degraded-mode serving
and channel restoration over a live fabric.

PR 7's :func:`~repro.core.repair.repair_fault` handles a single fault;
PR 8's :func:`~repro.core.fault.fault_event` injects one mid-sweep OCS
loss. Production resilience (MRC/SRv6; ACOS's many cheap fault-prone
optical switches -- PAPERS.md) is a *timeline*: faults arrive, overlap,
and heal. This module generates seeded randomized fault schedules and
drives a :class:`~repro.core.repair.ServingState` through them:

- **Event kinds.** ``ocs`` (one optical switch dies, killing every
  link routed through it), ``links`` (a correlated regional group:
  every channel incident to a node neighbourhood -- the shared-rack /
  shared-power failure domain; the fully-isolating variant forces a
  genuine disconnection served in degraded mode), storms (multiple OCS
  losses with overlapping arrival times, coalesced by the campaign
  runner into ONE repair pool), and ``restore`` events that revive
  previously-failed channels (:func:`~repro.core.repair.restore_channels`).
- **Machine-checked invariants** after every event -- chaos is only
  useful when every step is checkable: reachability accounting (the
  lost set is exactly the set of truly disconnected pairs), deadlock
  freedom of the whole served table, loads / VC-count consistency
  against the table, untouched-flow bit-identity versus the pre-event
  table, and no dead channel under any served path.
- **Metrics** per event: MTTR (repair wall-clock), flows re-routed,
  lost pairs, served-pair availability, post-event ``l_max``, and
  optional netsim throughput probes (the degraded table compacted
  through the CSR kernel, watchdog outputs included).

Every random draw -- schedule sampling and the repair engines'
tie-breaking -- comes from explicit seeded ``np.random.Generator``
state, so a campaign replays bit-identically from its seed
(:func:`CampaignResult.fingerprint` condenses the outcome for replay
equality checks).
"""
from __future__ import annotations

import dataclasses
import time
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.repair import (RepairResult, ServingState, repair_fault,
                               restore_channels)
from repro.core.routing import node_distances
from repro.core.vcalloc import verify_deadlock_free

# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ChaosEvent:
    """One arrival on the campaign timeline. ``kind`` is ``"ocs"``,
    ``"links"`` or ``"restore"``; ``channels`` is the sorted channel-id
    set the event kills / revives; ``colors`` names the OCS colors
    involved (empty for link groups)."""
    t: float
    kind: str
    channels: np.ndarray
    colors: Tuple[int, ...] = ()


@dataclasses.dataclass
class ChaosSchedule:
    """A seeded fault/heal timeline. ``events`` are in arrival order;
    regenerating with the same AT and parameters replays the identical
    schedule (every sample comes from one ``default_rng(seed)``)."""
    seed: int
    events: List[ChaosEvent]

    @property
    def n_events(self) -> int:
        return len(self.events)

    def kinds(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.events:
            out[e.kind] = out.get(e.kind, 0) + 1
        return out


def generate_schedule(at, n_arrivals: int = 20, seed: int = 0,
                      p_storm: float = 0.2, p_links: float = 0.25,
                      p_restore: float = 0.25,
                      storm_size: Tuple[int, int] = (2, 4),
                      storm_span: float = 0.5, mean_gap: float = 10.0,
                      p_disconnect: float = 0.5,
                      ensure_coverage: bool = True,
                      final_heal: bool = True) -> ChaosSchedule:
    """Sample a randomized fault/heal timeline against an AT's channel
    space. ``n_arrivals`` counts sampling steps; storms emit several
    events per step, so ``len(schedule.events)`` can exceed it.

    Arrival gaps are exponential with mean ``mean_gap``; a storm packs
    its OCS losses within ``storm_span`` (below the campaign runner's
    default coalescing window, so they repair as one pool). ``links``
    events kill the channels incident to a random node -- with
    probability ``p_disconnect`` *all* of them, isolating the node so
    the fabric must serve degraded. Restores revive a previously-failed
    OCS in full or a random slice of the currently-dead set.

    ``ensure_coverage`` pins one storm and one isolating link-group
    onto random slots so every campaign exercises the coalescing and
    degraded-mode paths; ``final_heal`` appends a restore of whatever
    is still dead, closing the fault->heal round trip. The generation
    itself tracks the evolving dead set, so every event is well-formed
    (restores only touch dead channels, faults only live ones).
    """
    rng = np.random.default_rng(seed)
    ch = at.channels
    colors = np.unique(ch.color[ch.color >= 0]).astype(np.int64)
    live_colors = colors.tolist()
    dead_colors: List[int] = []
    dead = np.zeros(0, np.int64)
    events: List[ChaosEvent] = []
    t = 0.0

    forced: Dict[int, str] = {}
    if ensure_coverage and n_arrivals >= 6:
        pos = rng.choice(np.arange(1, n_arrivals), size=2, replace=False)
        forced = {int(pos[0]): "storm", int(pos[1]): "isolate"}

    def color_channels(c: int) -> np.ndarray:
        return np.sort(np.nonzero(ch.color == c)[0].astype(np.int64))

    for i in range(n_arrivals):
        t += float(rng.exponential(mean_gap))
        r = float(rng.random())
        kind = forced.get(i)
        if kind is None:
            if r < p_restore and len(dead):
                kind = "restore"
            elif r < p_restore + p_storm and len(live_colors) >= 2:
                kind = "storm"
            elif r < p_restore + p_storm + p_links:
                kind = "links"
            elif live_colors:
                kind = "ocs"
            else:
                kind = "restore" if len(dead) else "links"

        if kind == "restore":
            if not len(dead):
                continue
            if dead_colors and rng.random() < 0.7:
                c = dead_colors.pop(int(rng.integers(len(dead_colors))))
                live_colors.append(c)
                chans = np.intersect1d(color_channels(c), dead)
                if not len(chans):
                    continue
                ev = ChaosEvent(t, "restore", chans, (int(c),))
            else:
                k = int(rng.integers(1, len(dead) + 1))
                chans = np.sort(rng.choice(dead, size=k, replace=False))
                ev = ChaosEvent(t, "restore", chans)
                # a random slice may fully revive some OCS's channels
                for c in list(dead_colors):
                    cc = color_channels(c)
                    if not len(np.setdiff1d(cc, np.setdiff1d(dead, chans))):
                        dead_colors.remove(c)
                        live_colors.append(c)
            dead = np.setdiff1d(dead, ev.channels)
            events.append(ev)
        elif kind == "storm" and len(live_colors) >= 2:
            k = min(int(rng.integers(storm_size[0], storm_size[1] + 1)),
                    len(live_colors))
            picks = sorted(rng.choice(len(live_colors), size=k,
                                      replace=False).tolist(),
                           reverse=True)
            offs = np.sort(rng.random(k)) * storm_span
            for j, pi in enumerate(picks):
                c = live_colors.pop(pi)
                dead_colors.append(c)
                chans = color_channels(c)
                events.append(ChaosEvent(t + float(offs[j]), "ocs",
                                         chans, (int(c),)))
                dead = np.union1d(dead, chans)
        elif kind in ("links", "isolate"):
            node = int(rng.integers(ch.n_nodes))
            inc = np.sort(np.nonzero((ch.src == node)
                                     | (ch.dst == node))[0]).astype(np.int64)
            if kind == "isolate" or rng.random() < p_disconnect:
                chans = inc                      # full isolation
            else:
                chans = inc[ch.color[inc] < 0]   # electrical links only
            if not len(np.setdiff1d(chans, dead)):
                continue
            events.append(ChaosEvent(t, "links", chans))
            dead = np.union1d(dead, chans)
        elif kind == "ocs" and live_colors:
            c = live_colors.pop(int(rng.integers(len(live_colors))))
            dead_colors.append(c)
            chans = color_channels(c)
            events.append(ChaosEvent(t, "ocs", chans, (int(c),)))
            dead = np.union1d(dead, chans)

    if final_heal and len(dead):
        t += float(rng.exponential(mean_gap))
        events.append(ChaosEvent(t, "restore", dead.copy()))
    events.sort(key=lambda e: e.t)
    return ChaosSchedule(seed, events)


# ---------------------------------------------------------------------------
# Invariant suite
# ---------------------------------------------------------------------------


def _hop_ranges(hop_indptr: np.ndarray, flows: np.ndarray) -> np.ndarray:
    lens = (hop_indptr[flows + 1] - hop_indptr[flows]).astype(np.int64)
    return np.repeat(hop_indptr[flows] - (np.cumsum(lens) - lens),
                     lens) + np.arange(int(lens.sum()), dtype=np.int64)


def check_invariants(prev: ServingState, rr: RepairResult,
                     untouched: bool = True) -> Dict[str, bool]:
    """The full post-event invariant suite, each check independent so a
    failure pinpoints the broken layer:

    - ``loads_match`` / ``vc_counts_match``: the state's incremental
      load and per-VC hop accounting equals a from-scratch reduction
      over the table.
    - ``no_dead_channel``: no served path crosses a dead channel.
    - ``deadlock_free``: every consecutive (channel, vc) hop of every
      served flow is an allowed turn (whole table, not just the pool).
    - ``lost_is_zero_length``: the lost-flow bookkeeping is exactly the
      set of zero-length table slots.
    - ``lost_truly_unreachable``: reachability accounting -- every lost
      pair is genuinely disconnected on the current AT with the current
      dead set (a reachable pair parked in ``lost`` is a repair bug;
      served pairs carry their own constructive proof, a verified
      path).
    - ``untouched_bit_identical``: flows outside the event's re-route
      pool kept byte-for-byte identical hops and VCs.
    """
    st = rr.state
    table = st.table
    out: Dict[str, bool] = {}
    out["loads_match"] = bool(
        (st.loads[:-1] == table.loads().astype(np.int64)).all())
    out["vc_counts_match"] = bool(
        (st.vc_counts == table.vc_hop_counts()).all())
    dead_mask = np.zeros(st.at.channels.n, bool)
    dead_mask[st.dead] = True
    out["no_dead_channel"] = not bool(dead_mask[table.chan].any())
    out["deadlock_free"] = bool(verify_deadlock_free(st.at, table))
    zero = np.nonzero(table.flow_len == 0)[0]
    out["lost_is_zero_length"] = bool(
        np.array_equal(np.sort(np.asarray(st.lost, np.int64)), zero))
    if len(st.lost):
        srcs = np.unique(table.flow_src[st.lost].astype(np.int64))
        best = node_distances(st.at, srcs, dead_channels=st.dead)
        pos = np.searchsorted(srcs, table.flow_src[st.lost])
        out["lost_truly_unreachable"] = bool(
            (best[pos, table.dst[st.lost]] < 0).all())
    else:
        out["lost_truly_unreachable"] = True
    if untouched and rr.pool_flows is not None \
            and prev.table.n_flows == table.n_flows and not rr.fallback:
        un = np.setdiff1d(np.arange(table.n_flows, dtype=np.int64),
                          rr.pool_flows)
        p0, p1 = prev.table, table
        l0 = (p0.hop_indptr[un + 1] - p0.hop_indptr[un])
        l1 = (p1.hop_indptr[un + 1] - p1.hop_indptr[un])
        same = np.array_equal(l0, l1)
        if same and len(un):
            i0 = _hop_ranges(p0.hop_indptr, un)
            i1 = _hop_ranges(p1.hop_indptr, un)
            same = (np.array_equal(p0.chan[i0], p1.chan[i1])
                    and np.array_equal(p0.vc[i0], p1.vc[i1]))
        out["untouched_bit_identical"] = bool(same)
    return out


# ---------------------------------------------------------------------------
# Campaign runner
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EventRecord:
    """Per-event campaign telemetry; everything scalar so records
    JSON-serialise straight into the benchmark trackers."""
    t: float
    kind: str                  # "ocs" | "links" | "storm" | "restore"
    n_channels: int
    coalesced: int             # arrivals merged into this repair pool
    mttr_s: float              # repair/restore wall-clock
    flows_rerouted: int
    lost_pairs: int
    served_fraction: float
    l_max: float
    fallback: bool
    readmitted: int
    invariants: Dict[str, bool]
    probe: Optional[dict] = None

    @property
    def ok(self) -> bool:
        return all(self.invariants.values())


@dataclasses.dataclass
class CampaignResult:
    schedule: ChaosSchedule
    records: List[EventRecord]
    state: ServingState        # the post-campaign serving state
    baseline_l_max: float
    baseline_probe: Optional[dict] = None

    @property
    def ok(self) -> bool:
        """Every invariant of every event green."""
        return all(r.ok for r in self.records)

    @property
    def min_served_fraction(self) -> float:
        return min((r.served_fraction for r in self.records), default=1.0)

    def timeline(self) -> Dict[str, list]:
        """Campaign trajectory as parallel lists (fig/JSON ready)."""
        out: Dict[str, list] = {
            "t": [r.t for r in self.records],
            "kind": [r.kind for r in self.records],
            "served_fraction": [r.served_fraction for r in self.records],
            "l_max": [r.l_max for r in self.records],
            "lost_pairs": [r.lost_pairs for r in self.records],
            "mttr_s": [r.mttr_s for r in self.records],
            "flows_rerouted": [r.flows_rerouted for r in self.records],
        }
        if any(r.probe is not None for r in self.records):
            base = (self.baseline_probe or {}).get("delivered", 0.0)
            out["throughput_retained"] = [
                None if r.probe is None else
                (r.probe["delivered"] / base if base else None)
                for r in self.records]
        return out

    def fingerprint(self) -> Tuple:
        """Condensed campaign outcome for bit-identical replay checks:
        the final table's hop/VC arrays digested (process-stable CRC,
        not python ``hash`` which is salted per process) with every
        per-event counter. Two runs from the same seed must match."""
        tab = self.state.table
        return (tuple((r.kind, r.n_channels, r.coalesced,
                       r.flows_rerouted, r.lost_pairs, r.l_max)
                      for r in self.records),
                zlib.crc32(tab.chan.tobytes()),
                zlib.crc32(tab.vc.tobytes()),
                zlib.crc32(tab.hop_indptr.tobytes()))


def probe_throughput(state: ServingState, rate: float = 0.05,
                     cycles: int = 1200, warmup: int = 400,
                     seed: int = 0) -> dict:
    """One netsim saturation probe of the current serving table. A
    degraded table is compacted first (the kernel samples traffic over
    flow slots and cannot inject into a lost pair); the probe reports
    the watchdog outputs alongside delivered throughput."""
    from repro.core import netsim as NS
    if len(state.lost):
        tab, _ = state.table.compact()
    else:
        tab = state.table
    stats: dict = {}
    r = NS.sweep(NS.build_tables(state.topo, tab), [rate], cycles=cycles,
                 warmup=warmup, seed=seed, stats=stats)[0]
    return {"rate": float(rate), "delivered": float(r["delivered"]),
            "offered": float(r["offered"]),
            "stalled_at": int(r["stalled_at"]),
            "cycles_run": int(stats.get("cycles_run", cycles)),
            "served_flows": int(tab.n_flows)}


def run_campaign(state: ServingState, schedule: ChaosSchedule,
                 coalesce: float = 1.0, probe_every: int = 0,
                 probe_rate: float = 0.05, probe_cycles: int = 1200,
                 probe_warmup: int = 400, rebalance: bool = True,
                 check_untouched: bool = True) -> CampaignResult:
    """Drive a live :class:`ServingState` through a fault/heal
    timeline. Fault arrivals within ``coalesce`` time units of each
    other merge into ONE repair pool (storm semantics: the repair sees
    the union of their dead channels, so overlapping arrivals cost one
    incremental repair, not one per event); restores never merge with
    faults. After every event the full invariant suite runs
    (:func:`check_invariants`) and, every ``probe_every`` events (0 =
    never), a netsim throughput probe samples the degraded fabric.

    Pure with respect to the input state (repairs/restores are pure),
    and deterministic: same state + same schedule => bit-identical
    result (:meth:`CampaignResult.fingerprint`).
    """
    groups: List[List[ChaosEvent]] = []
    for ev in sorted(schedule.events, key=lambda e: e.t):
        if (groups and ev.kind != "restore"
                and groups[-1][-1].kind != "restore"
                and ev.t - groups[-1][-1].t <= coalesce):
            groups[-1].append(ev)
        else:
            groups.append([ev])

    baseline_probe = None
    if probe_every:
        baseline_probe = probe_throughput(
            state, rate=probe_rate, cycles=probe_cycles,
            warmup=probe_warmup, seed=schedule.seed)
    cur = state
    records: List[EventRecord] = []
    for gi, g in enumerate(groups):
        chans = np.unique(np.concatenate([e.channels for e in g]))
        t0 = time.time()
        if g[0].kind == "restore":
            rr = restore_channels(cur, chans, rebalance=rebalance)
            kind = "restore"
        else:
            rr = repair_fault(cur, chans)
            kind = "storm" if len(g) > 1 else g[0].kind
        mttr = time.time() - t0
        inv = check_invariants(cur, rr, untouched=check_untouched)
        cur = rr.state
        rec = EventRecord(
            t=float(g[-1].t), kind=kind, n_channels=int(len(chans)),
            coalesced=len(g), mttr_s=round(mttr, 3),
            flows_rerouted=int(rr.flows_rerouted),
            lost_pairs=int(rr.lost),
            served_fraction=float(cur.served_fraction),
            l_max=float(rr.l_max), fallback=bool(rr.fallback),
            readmitted=int(rr.readmitted), invariants=inv)
        if probe_every and ((gi + 1) % probe_every == 0
                            or gi == len(groups) - 1):
            rec.probe = probe_throughput(
                cur, rate=probe_rate, cycles=probe_cycles,
                warmup=probe_warmup, seed=schedule.seed)
        records.append(rec)
    return CampaignResult(schedule, records, cur,
                          baseline_l_max=float(state.l_max),
                          baseline_probe=baseline_probe)
