"""Collective schedulers over arbitrary pod topologies (paper Section 6.1.2).

- all-gather / all-reduce: MultiTree-style greedy broadcast/reduction trees
  (one tree per root, edges picked to balance channel usage) [38].
- all-to-all: schedule quality from the routed min-max channel load,
  bounded by the MCF-derived limit (Basu et al. style) [5].

Quality metric: link utilisation = useful chunk-transmissions divided by
(schedule length x number of channels), as in Fig. 6. These schedules also
drive the collective term of the framework's roofline model and can be
exported as traces for the cycle-level simulator (Fig. 7).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.routing import Channels, RoutingResult
from repro.core.topology import Topology


@dataclasses.dataclass
class Schedule:
    kind: str
    epochs: float              # schedule length in link-serialisation units
    transmissions: float       # total chunk-hops
    n_channels: int
    ideal_epochs: float        # lower bound

    @property
    def utilization(self) -> float:
        return self.transmissions / (self.epochs * self.n_channels)

    @property
    def ideal_utilization(self) -> float:
        return self.transmissions / max(self.ideal_epochs, 1e-12) \
            / self.n_channels


def broadcast_trees(topo: Topology) -> Tuple[np.ndarray, List[Dict]]:
    """One BFS broadcast tree per root, greedily preferring low-load
    channels (MultiTree-flavoured). Returns per-channel usage counts."""
    ch = Channels.from_topology(topo)
    adj = topo.adjacency()
    n = topo.n
    loads = np.zeros(ch.n)
    trees = []
    for root in range(n):
        seen = np.zeros(n, bool)
        seen[root] = True
        frontier = [root]
        tree = {}
        while frontier:
            nxt = []
            # expand lowest-load channels first
            cand = []
            for u in frontier:
                for v in adj[u]:
                    if not seen[v]:
                        c = ch.index[(u, v)]
                        cand.append((loads[c], c, u, v))
            cand.sort()
            for _, c, u, v in cand:
                if seen[v]:
                    continue
                seen[v] = True
                tree[v] = (u, c)
                loads[c] += 1
                nxt.append(v)
            frontier = nxt
        trees.append(tree)
    return loads, trees


def all_gather(topo: Topology) -> Schedule:
    """Each node's shard broadcast to all others along its tree."""
    loads, _ = broadcast_trees(topo)
    n = topo.n
    transmissions = float(n * (n - 1))
    n_channels = 2 * len(topo.edges())
    ideal = transmissions / n_channels
    return Schedule("all-gather", float(loads.max()), transmissions,
                    n_channels, ideal)


def all_reduce(topo: Topology) -> Schedule:
    """reduce-scatter + all-gather (each a tree pass): 2x the traffic."""
    ag = all_gather(topo)
    return Schedule("all-reduce", 2 * ag.epochs, 2 * ag.transmissions,
                    ag.n_channels, 2 * ag.ideal_epochs)


def all_to_all(topo: Topology, routed: RoutingResult,
               mcf_lambda: Optional[float] = None) -> Schedule:
    """One chunk per ordered pair along the selected static paths; the
    schedule length is the max channel load; the MCF limit is 1/lambda."""
    transmissions = float(routed.table.hops.sum())
    n_channels = 2 * len(topo.edges())
    ideal = 1.0 / mcf_lambda if mcf_lambda else \
        transmissions / n_channels
    return Schedule("all-to-all", routed.l_max, transmissions, n_channels,
                    ideal)


def collective_report(topo: Topology, routed: RoutingResult,
                      mcf_lambda: Optional[float] = None) -> Dict[str, Dict]:
    out = {}
    for sched in (all_gather(topo), all_reduce(topo),
                  all_to_all(topo, routed, mcf_lambda)):
        out[sched.kind] = {
            "epochs": sched.epochs,
            "utilization": sched.utilization,
            "mcf_limit_utilization": min(1.0, sched.ideal_utilization),
        }
    return out


def effective_a2a_bandwidth(topo_lambda: float, n: int,
                            link_bw: float = 50e9) -> float:
    """Framework integration: sustained per-node all-to-all injection
    bandwidth implied by the topology's MCF (used by the roofline's
    collective term): lambda * (n-1) * link_bw per node."""
    return topo_lambda * (n - 1) * link_bw


# ---------------------------------------------------------------------------
# Trace export (Fig. 7-style trace-driven simulation)
# ---------------------------------------------------------------------------


def a2a_trace(topo: Topology, routed: RoutingResult, chunks_per_pair: int = 1
              ) -> List[Tuple[int, int, int]]:
    """(src, dst, n_chunks) trace for the packet simulator (API edge)."""
    ss, dd = np.nonzero(routed.table.routed_mask())
    return [(int(s), int(d), chunks_per_pair) for s, d in zip(ss, dd)]


def a2a_traffic(routed: RoutingResult):
    """All-to-all as a simulator TrafficPattern: uniform demand over every
    routed ordered pair (equals uniform-random when all pairs route, and
    respects unreachable pairs under faults)."""
    from repro.core.traffic import TrafficPattern
    return TrafficPattern.from_matrix(
        "all-to-all", routed.table.routed_mask().astype(np.float64))
