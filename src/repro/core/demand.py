"""Workload traffic matrices on the pod graph (framework <-> TONS bridge,
and the beyond-paper weighted-demand synthesis).

The paper optimizes uniform all-to-all. Real training steps have a *mix*:
DP all-reduce over the data axis, TP/EP collectives within model groups,
MoE token all-to-all. We map the mesh onto the pod with the natural TPU
assignment -- the "model" axis lives inside a cube (fast electrical mesh),
the "data" axis spans cubes -- and derive pairwise demand weights from the
dry-run's measured per-collective wire bytes. These weights are invariant
under cube translations (same-cube membership and cube-offset rings), so
the symmetric synthesis reductions still apply.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Callable, Dict, Optional

import numpy as np

from repro.core.topology import CUBE, Pod


@dataclasses.dataclass
class WorkloadDemand:
    """Pairwise weights: w_same_cube (TP/EP all-to-all within a cube) and
    w_ring (DP all-reduce ring across cubes at the same in-cube slot) and
    w_uniform (background)."""
    pod: Pod
    w_same_cube: float = 0.0
    w_ring: float = 0.0
    w_uniform: float = 1.0

    def weight_fn(self) -> Callable:
        pod = self.pod
        X, Y, Z = pod.dims
        cx, cy, cz = pod.cube_dims
        n_c = pod.n_cubes

        def cube_idx(i):
            x, y, z = i % X, (i // X) % Y, i // (X * Y)
            return (x // CUBE) + cx * ((y // CUBE) + cy * (z // CUBE))

        def incube(i):
            x, y, z = i % X, (i // X) % Y, i // (X * Y)
            return (x % CUBE) + CUBE * ((y % CUBE) + CUBE * (z % CUBE))

        ws, wr, wu = self.w_same_cube, self.w_ring, self.w_uniform

        def fn(a, b):
            a = np.asarray(a, np.int64)
            b = np.asarray(b, np.int64)
            ca = np.array([cube_idx(int(x)) for x in a.ravel()])
            cb = np.array([cube_idx(int(x)) for x in b.ravel()])
            ia = np.array([incube(int(x)) for x in a.ravel()])
            ib = np.array([incube(int(x)) for x in b.ravel()])
            w = np.full(a.size, wu, np.float64)
            w = np.where(ca == cb, w + ws, w)
            # ring neighbours: same in-cube slot, adjacent cube index.
            # (Translation-invariant for the <=4-cube pods we synthesise.)
            adj = (np.abs(ca - cb) == 1) | (np.abs(ca - cb) == n_c - 1)
            w = np.where((ia == ib) & adj & (ca != cb), w + wr, w)
            return w.reshape(a.shape)

        return fn

    def matrix(self) -> np.ndarray:
        """Dense (n, n) pairwise weights with zero diagonal -- the bridge
        into the simulator's TrafficPattern (repro.core.traffic)."""
        n = self.pod.n
        idx = np.arange(n)
        a = np.repeat(idx, n)
        b = np.tile(idx, n)
        w = self.weight_fn()(a, b).reshape(n, n)
        np.fill_diagonal(w, 0.0)
        return w


def from_mix(pod: Pod, wires: Dict[str, float]) -> WorkloadDemand:
    """Per-collective wire-byte mix -> pairwise weight levels.

    The single mapping shared by the dry-run reader below and the
    analytic estimator in :mod:`repro.core.workload`: MoE/EP
    all-to-all bytes load the same-cube weight (the model axis lives
    inside a cube), ring-style collectives (all-reduce,
    reduce-scatter, all-gather) load the cross-cube DP ring, and a
    uniform floor keeps every pair connected-by-demand.
    """
    a2a = wires.get("all-to-all", 0.0)
    ar = wires.get("all-reduce", 0.0) + wires.get("reduce-scatter", 0.0) \
        + wires.get("all-gather", 0.0)
    total = a2a + ar
    if total <= 0:
        return WorkloadDemand(pod)
    return WorkloadDemand(pod, w_same_cube=4.0 * a2a / total,
                          w_ring=4.0 * ar / total, w_uniform=0.25)


def from_dryrun(podspec, arch: str, shape: str,
                dryrun_dir: str = "benchmarks/results/dryrun",
                mesh: str = "single_pod_16x16") -> WorkloadDemand:
    """Build demand weights from a dry-run cell's measured collectives."""
    pod = Pod(podspec)
    f = Path(dryrun_dir) / f"{arch}__{shape}__{mesh}.json"
    if not f.exists():
        return WorkloadDemand(pod)
    d = json.loads(f.read_text())
    coll = d.get("collectives", {})
    wires = {k: v.get("wire_bytes", 0.0) for k, v in coll.items()}
    return from_mix(pod, wires)


def weighted_mcf(topo, demand: WorkloadDemand, perms=None,
                 prefer: str = "highs") -> float:
    from repro.core.mcf import mcf_uniform
    from repro.core.topology import cube_translations
    if perms is None:
        perms = cube_translations(topo.pod)
    lam, _ = mcf_uniform(topo.edges(), topo.n, perms=perms, prefer=prefer,
                         pair_weight=demand.weight_fn())
    return lam
