"""TPU v4/5p pod fabric model: cubes, OCS port groups, PT/PDTT baselines.

A job of chip dims (X, Y, Z) (each a multiple of 4, or exactly 4) is built
from 4x4x4 electrically-wired cubes. Chips on a cube face expose one optical
port per face axis; ports are grouped by (axis, in-cube face position) into
48 OCS domains ("colors"), and an optical circuit may connect any two ports
of the same OCS (paper Section 2.2). A topology is the fixed electrical mesh
plus a perfect matching per OCS group.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

CUBE = 4
N_POS = CUBE * CUBE           # 16 face positions per axis
N_COLORS = 3 * N_POS          # 48 OCS domains


@dataclasses.dataclass(frozen=True)
class Pod:
    dims: Tuple[int, int, int]            # chips per axis

    def __post_init__(self):
        for d in self.dims:
            assert d == CUBE or d % CUBE == 0, f"bad dim {d}"

    @property
    def n(self) -> int:
        x, y, z = self.dims
        return x * y * z

    @property
    def cube_dims(self) -> Tuple[int, int, int]:
        return tuple(d // CUBE for d in self.dims)

    @property
    def n_cubes(self) -> int:
        cx, cy, cz = self.cube_dims
        return cx * cy * cz

    # ---- chip indexing ----------------------------------------------------
    def node_id(self, x, y, z):
        X, Y, Z = self.dims
        return (x % X) + X * ((y % Y) + Y * (z % Z))

    def coords(self, i):
        X, Y, Z = self.dims
        return i % X, (i // X) % Y, i // (X * Y)

    def all_coords(self) -> np.ndarray:
        X, Y, Z = self.dims
        i = np.arange(self.n)
        return np.stack([i % X, (i // X) % Y, i // (X * Y)], axis=1)

    def cube_of(self, i) -> Tuple[int, int, int]:
        x, y, z = self.coords(i)
        return x // CUBE, y // CUBE, z // CUBE

    def incube(self, i) -> Tuple[int, int, int]:
        x, y, z = self.coords(i)
        return x % CUBE, y % CUBE, z % CUBE


def electrical_edges(pod: Pod) -> np.ndarray:
    """Intra-cube 3D mesh links (fixed copper), as (E, 2) with u < v."""
    edges = []
    X, Y, Z = pod.dims
    for i in range(pod.n):
        x, y, z = pod.coords(i)
        for axis, (dx, dy, dz) in enumerate([(1, 0, 0), (0, 1, 0),
                                             (0, 0, 1)]):
            nx, ny, nz = x + dx, y + dy, z + dz
            if nx >= X or ny >= Y or nz >= Z:
                continue
            # stay within the same cube
            if (nx // CUBE, ny // CUBE, nz // CUBE) != \
               (x // CUBE, y // CUBE, z // CUBE):
                continue
            edges.append((i, pod.node_id(nx, ny, nz)))
    return np.array(sorted(edges), dtype=np.int32)


@dataclasses.dataclass(frozen=True)
class Port:
    chip: int
    axis: int          # 0, 1, 2
    sign: int          # -1 (low face) or +1 (high face)
    pos: int           # 0..15 position within the face (other two coords)
    color: int         # OCS domain = axis * 16 + pos


def ports(pod: Pod) -> List[Port]:
    out = []
    for i in range(pod.n):
        ix, iy, iz = pod.incube(i)
        inc = (ix, iy, iz)
        for axis in range(3):
            o1, o2 = [inc[a] for a in range(3) if a != axis]
            pos = o1 * CUBE + o2
            if inc[axis] == 0:
                out.append(Port(i, axis, -1, pos, axis * N_POS + pos))
            elif inc[axis] == CUBE - 1:
                out.append(Port(i, axis, +1, pos, axis * N_POS + pos))
    return out


def ocs_groups(pod: Pod) -> Dict[int, List[Port]]:
    groups: Dict[int, List[Port]] = {c: [] for c in range(N_COLORS)}
    for p in ports(pod):
        groups[p.color].append(p)
    return groups


def valid_optical_pairs_arrays(pod: Pod
                               ) -> Tuple[np.ndarray, np.ndarray,
                                          np.ndarray]:
    """All OCS-feasible optical edges as ``(u, v, color)`` arrays, u < v.

    Vectorised per color: a port list is sorted by chip (one port per
    (chip, axis), so chips are distinct within a group) and the upper
    triangle of its chip array enumerates every circuit-connectable pair
    -- identical output order to the old ``itertools.combinations`` loop.
    """
    us, vs, cs = [], [], []
    for color, plist in ocs_groups(pod).items():
        chips = np.array([p.chip for p in plist], np.int32)
        if len(chips) < 2:
            continue
        iu, iv = np.triu_indices(len(chips), k=1)
        us.append(chips[iu])
        vs.append(chips[iv])
        cs.append(np.full(len(iu), color, np.int32))
    if not us:
        z = np.zeros(0, np.int32)
        return z, z, z
    return np.concatenate(us), np.concatenate(vs), np.concatenate(cs)


def valid_optical_pairs(pod: Pod) -> List[Tuple[int, int, int]]:
    """All OCS-feasible optical edges as (u, v, color), u < v chips.
    Any two distinct ports of the same OCS group may be circuit-connected."""
    u, v, c = valid_optical_pairs_arrays(pod)
    return list(zip(u.tolist(), v.tolist(), c.tolist()))


# ---------------------------------------------------------------------------
# Baseline topologies
# ---------------------------------------------------------------------------


def pt_optical(pod: Pod) -> List[Tuple[int, int, int]]:
    """Prismatic torus: per OCS group, chain the cubes into a ring along the
    group's axis (single-cube axes wrap a cube's own faces -> 4-torus)."""
    edges = []
    X, Y, Z = pod.dims
    for p in ports(pod):
        if p.sign != +1:
            continue
        x, y, z = pod.coords(p.chip)
        c = [x, y, z]
        c[p.axis] = (c[p.axis] + 1) % pod.dims[p.axis]
        v = pod.node_id(*c)
        u = p.chip
        edges.append((min(u, v), max(u, v), p.color))
    return sorted(set(edges))


def pdtt_lattice(pod: Pod, long_axis: Optional[int] = None,
                 shifts: Optional[Tuple[int, int]] = None):
    """The prismatic doubly twisted torus (Camara et al. [9]) is the Cayley
    graph of Z^3 modulo the lattice L spanned by
        X ex + s0 ez,   Y ey + s1 ez,   Z ez
    (for long axis z): the wraps of the SHORT dimensions are twisted along
    the LONG dimension, by half its length by default."""
    dims = pod.dims
    la = int(np.argmax(dims)) if long_axis is None else long_axis
    sa = [a for a in range(3) if a != la]
    if shifts is None:
        shifts = (dims[la] // 2, dims[la] // 2)
    return la, sa, shifts


def _pdtt_reduce(coords: np.ndarray, dims, la, sa, shifts) -> np.ndarray:
    """Reduce integer coordinates modulo the PDTT lattice."""
    c = coords.astype(np.int64).copy()
    for a, s in zip(sa, shifts):
        w = c[:, a] // dims[a]
        c[:, a] -= w * dims[a]
        c[:, la] += w * s
    c[:, la] %= dims[la]
    return c


def twisted_torus_optical(pod: Pod, long_axis: Optional[int] = None,
                          shifts: Optional[Tuple[int, int]] = None
                          ) -> List[Tuple[int, int, int]]:
    """Prismatic doubly twisted torus baseline (deployed TPU v4 variant).
    NOTE: twisted wraps connect ports of *different* OCS positions --
    allowed for the hardwired baseline only; TONS synthesis keeps strict
    same-color matchings (DESIGN.md)."""
    la, sa, shifts = pdtt_lattice(pod, long_axis, shifts)
    dims = pod.dims
    edges = []
    for p in ports(pod):
        if p.sign != +1:
            continue
        c = np.array([list(pod.coords(p.chip))])
        c[0, p.axis] += 1
        c = _pdtt_reduce(c, dims, la, sa, shifts)[0]
        v = pod.node_id(*c)
        u = p.chip
        edges.append((min(u, v), max(u, v), p.color))
    return sorted(set(edges))


def random_matching_optical(pod: Pod, seed: int = 0
                            ) -> List[Tuple[int, int, int]]:
    """TPU-constrained random topology: uniform random perfect matching per
    OCS group (the paper's random baseline in Fig. 2)."""
    rng = np.random.default_rng(seed)
    edges = []
    for color, plist in ocs_groups(pod).items():
        idx = rng.permutation(len(plist))
        for a in range(0, len(idx) - 1, 2):
            pa, pb = plist[idx[a]], plist[idx[a + 1]]
            if pa.chip == pb.chip:  # cannot happen (one port per axis/chip)
                continue
            u, v = sorted((pa.chip, pb.chip))
            edges.append((u, v, color))
    return sorted(edges)


# ---------------------------------------------------------------------------
# Graphs and symmetry
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Topology:
    pod: Pod
    optical: List[Tuple[int, int, int]]        # (u, v, color)
    name: str = "topo"

    @property
    def n(self) -> int:
        return self.pod.n

    def edges(self) -> np.ndarray:
        """All undirected edges (E, 2), electrical + optical."""
        e = electrical_edges(self.pod)
        o = np.array([(u, v) for u, v, _ in self.optical], dtype=np.int32)
        if len(o) == 0:
            return e
        return np.concatenate([e, o], axis=0)

    def edge_colors(self) -> np.ndarray:
        """-1 for electrical, OCS color id for optical."""
        e = electrical_edges(self.pod)
        return np.concatenate([
            np.full(len(e), -1, np.int32),
            np.array([c for _, _, c in self.optical], np.int32)])

    def adjacency(self) -> List[List[int]]:
        adj: List[List[int]] = [[] for _ in range(self.n)]
        for u, v in self.edges():
            adj[u].append(int(v))
            adj[v].append(int(u))
        return adj


def cube_translations(pod: Pod) -> np.ndarray:
    """Node permutations for all cube-grid translations, (n_cubes, n)."""
    cx, cy, cz = pod.cube_dims
    X, Y, Z = pod.dims
    coords = pod.all_coords()
    perms = []
    for tx in range(cx):
        for ty in range(cy):
            for tz in range(cz):
                nx = (coords[:, 0] + CUBE * tx) % X
                ny = (coords[:, 1] + CUBE * ty) % Y
                nz = (coords[:, 2] + CUBE * tz) % Z
                perms.append(nx + X * (ny + Y * nz))
    return np.array(perms, dtype=np.int32)


def torus_translations(pod: Pod, twisted: bool = False,
                       long_axis: Optional[int] = None) -> np.ndarray:
    """Full chip-level translation group of the (twisted) torus: these are
    Cayley graphs of Z^3 modulo a lattice, so all translations (reduced
    modulo that lattice) are automorphisms."""
    X, Y, Z = pod.dims
    dims = pod.dims
    coords = pod.all_coords()
    la, sa, shift = pdtt_lattice(pod, long_axis)
    perms = set()
    for tx in range(X):
        for ty in range(Y):
            for tz in range(Z):
                c = coords + np.array([tx, ty, tz])
                if twisted:
                    c = _pdtt_reduce(c, dims, la, sa, shift)
                else:
                    c = c % np.array(dims)
                perms.add(tuple(c[:, 0] + X * (c[:, 1] + Y * c[:, 2])))
    return np.array(sorted(perms), dtype=np.int32)


def pt(podspec: Tuple[int, int, int]) -> Topology:
    pod = Pod(podspec)
    return Topology(pod, pt_optical(pod), name=f"PT {podspec}")


def pdtt(podspec: Tuple[int, int, int],
         long_axis: Optional[int] = None) -> Topology:
    pod = Pod(podspec)
    return Topology(pod, twisted_torus_optical(pod, long_axis),
                    name=f"PDTT {podspec}")


def random_topology(podspec: Tuple[int, int, int], seed: int = 0) -> Topology:
    pod = Pod(podspec)
    return Topology(pod, random_matching_optical(pod, seed),
                    name=f"RAND {podspec} s{seed}")


# ---------------------------------------------------------------------------
# Simple graph metrics (BFS-based; the minplus Pallas kernel is the TPU path)
# ---------------------------------------------------------------------------


def bfs_all_pairs(topo: Topology, sources: Optional[np.ndarray] = None
                  ) -> np.ndarray:
    """Hop distances from each source (defaults: all), via scipy csgraph."""
    import scipy.sparse as sp
    import scipy.sparse.csgraph as csg
    e = topo.edges()
    n = topo.n
    a = sp.csr_matrix((np.ones(len(e)), (e[:, 0], e[:, 1])), shape=(n, n))
    a = a + a.T
    if sources is None:
        d = csg.shortest_path(a, method="D", unweighted=True)
    else:
        d = csg.shortest_path(a, method="D", unweighted=True,
                              indices=sources)
    return d


def diameter_avg_hops(topo: Topology) -> Tuple[int, float]:
    """Exploit cube-translation symmetry: BFS from one cube only."""
    perms = cube_translations(topo.pod)
    srcs = np.arange(64) if len(perms) > 1 else None
    if topo.n <= 64:
        srcs = None
    d = bfs_all_pairs(topo, sources=srcs)
    finite = d[np.isfinite(d)]
    diam = int(finite.max())
    # average over ordered pairs excluding self (paper counts avg hops)
    total = finite.sum()
    cnt = finite.size - d.shape[0]  # minus self-distances (zeros)
    return diam, float(total / cnt)
