"""OCS fault tolerance (paper Section 5.2 / Appendix D).

Fault model: one OCS (color) fails at a time, disabling every optical link
routed through it; the fault is known before job launch and fault-specific
routing tables are loaded (Google WFR-style, but re-solved through the AT
candidate set). C8 (lambda >= (f+1)/(32 n)) certifies f+1 OCS-disjoint
spanning trees via Nash-Williams, so connectivity survives.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.pipeline import PipelineConfig, route_pod
from repro.core.repair import RepairResult, ServingState, repair_fault
from repro.core.routing import ATResult, RoutingResult, allowed_turns
from repro.core.topology import N_COLORS, Topology


def colors_in_use(topo: Topology) -> List[int]:
    col = topo.edge_colors()
    return np.unique(col[col >= 0]).astype(np.int64).tolist()


def dead_channels_for_color(at: ATResult, color: int) -> np.ndarray:
    """Channel ids of every optical link through OCS ``color``, as a
    sorted int64 array (the form the routing/repair hot paths consume
    directly -- no python sets on the per-fault path). The channels-by-
    color grouping is built once per :class:`Channels` and cached, so a
    sweep over all colors pays one argsort total."""
    ch = at.channels
    cache = ch.__dict__.get("_color_csr")
    if cache is None:
        order = np.argsort(ch.color, kind="stable").astype(np.int64)
        vals = ch.color[order]
        ucol, starts = np.unique(vals, return_index=True)
        cache = (order, ucol, np.append(starts, len(vals)))
        ch.__dict__["_color_csr"] = cache
    order, ucol, starts = cache
    i = int(np.searchsorted(ucol, color))
    if i >= len(ucol) or ucol[i] != color:
        return np.zeros(0, np.int64)
    return np.sort(order[starts[i]:starts[i + 1]])


def fault_region_nodes(at: ATResult, color: int) -> np.ndarray:
    """Nodes incident to the failed OCS's links -- the impaired region
    that fault-correlated recovery traffic clusters around
    (:meth:`repro.core.traffic.TrafficPattern.fault_correlated`)."""
    ch = at.channels
    dead = ch.color == color
    return np.unique(np.concatenate([ch.src[dead], ch.dst[dead]]))


def fault_event(at: ATResult, color: int,
                t: int) -> Tuple[int, np.ndarray]:
    """A mid-sweep OCS failure as the ``fault=(t, dead_channels)`` pair
    :func:`repro.core.netsim.sweep` consumes: OCS ``color`` dies at
    cycle ``t``, killing every optical link routed through it. ``t``
    must be non-negative (range against the sweep's cycle budget is
    checked by the simulator, which knows it)."""
    if t < 0:
        raise ValueError(f"fault cycle must be >= 0, got {t}")
    return int(t), dead_channels_for_color(at, color)


def fault_tolerance_certificate(topo: Topology, lam: float, f: int = 1
                                ) -> Dict[str, float]:
    """Appendix D: t_max <= min(floor(32 n lambda), 48)."""
    n = topo.n
    by_throughput = int(np.floor(32 * n * lam))
    return {
        "throughput_implied_trees": by_throughput,
        "color_budget": N_COLORS,
        "t_max": min(by_throughput, N_COLORS),
        "certified_f": min(by_throughput, N_COLORS) - 1,
        "required_lambda": (f + 1) / (32.0 * n),
        "satisfies_c8": lam >= (f + 1) / (32.0 * n),
    }


@dataclasses.dataclass
class FaultSweepResult:
    color: int
    routed: RoutingResult
    connected: bool
    repair: Optional[RepairResult] = None   # set in repair mode


def fault_sweep(topo: Topology, at: ATResult, K: int = 6, seed: int = 0,
                repair_from: Optional[ServingState] = None,
                rng: Optional[np.random.Generator] = None
                ) -> List[FaultSweepResult]:
    """Re-route under each single-OCS fault using the (robust) AT set.

    ``repair_from`` switches the sweep to the incremental path: each
    fault is repaired from that live :class:`ServingState`
    (:func:`repro.core.repair.repair_fault`) instead of re-selecting
    every flow against the masked AT -- each color independently, like
    the recompute mode. The per-fault :class:`RepairResult` rides on the
    sweep entries.

    All randomness is explicit: pass one ``np.random.Generator`` as
    ``rng`` and every per-color selection draws its seed from it (no
    module-level RNG anywhere on the fault path), so a sweep replays
    bit-identically from the generator's seed; with ``rng=None`` every
    color uses the fixed ``seed`` (the legacy behaviour, equally
    deterministic).
    """
    out = []
    for color in colors_in_use(topo):
        dead = dead_channels_for_color(at, color)
        if repair_from is not None:
            rr = repair_fault(repair_from, dead)
            st = rr.state
            routed = RoutingResult(
                st.table, st.loads[:-1].astype(np.float64),
                float(rr.l_max), st.table.avg_hops(), rr.unreachable,
                stats=rr.stats)
            out.append(FaultSweepResult(color, routed,
                                        rr.unreachable == 0, repair=rr))
        else:
            s = seed if rng is None else int(rng.integers(0, 2**31 - 1))
            cfg = PipelineConfig(K=K, seed=s, engine="array",
                                 local_search_rounds=3, vc="none")
            routed = route_pod(topo, cfg, at=at,
                               dead_channels=dead).routed
            out.append(FaultSweepResult(color, routed,
                                        routed.unreachable == 0))
    return out
