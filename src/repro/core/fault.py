"""OCS fault tolerance (paper Section 5.2 / Appendix D).

Fault model: one OCS (color) fails at a time, disabling every optical link
routed through it; the fault is known before job launch and fault-specific
routing tables are loaded (Google WFR-style, but re-solved through the AT
candidate set). C8 (lambda >= (f+1)/(32 n)) certifies f+1 OCS-disjoint
spanning trees via Nash-Williams, so connectivity survives.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.routing import ATResult, RoutingResult, allowed_turns, \
    select_paths
from repro.core.topology import N_COLORS, Topology


def colors_in_use(topo: Topology) -> List[int]:
    return sorted({c for _, _, c in topo.optical})


def dead_channels_for_color(at: ATResult, color: int) -> set:
    ch = at.channels
    return set(np.nonzero(ch.color == color)[0].tolist())


def fault_region_nodes(at: ATResult, color: int) -> np.ndarray:
    """Nodes incident to the failed OCS's links -- the impaired region
    that fault-correlated recovery traffic clusters around
    (:meth:`repro.core.traffic.TrafficPattern.fault_correlated`)."""
    ch = at.channels
    dead = ch.color == color
    return np.unique(np.concatenate([ch.src[dead], ch.dst[dead]]))


def fault_tolerance_certificate(topo: Topology, lam: float, f: int = 1
                                ) -> Dict[str, float]:
    """Appendix D: t_max <= min(floor(32 n lambda), 48)."""
    n = topo.n
    by_throughput = int(np.floor(32 * n * lam))
    return {
        "throughput_implied_trees": by_throughput,
        "color_budget": N_COLORS,
        "t_max": min(by_throughput, N_COLORS),
        "certified_f": min(by_throughput, N_COLORS) - 1,
        "required_lambda": (f + 1) / (32.0 * n),
        "satisfies_c8": lam >= (f + 1) / (32.0 * n),
    }


@dataclasses.dataclass
class FaultSweepResult:
    color: int
    routed: RoutingResult
    connected: bool


def fault_sweep(topo: Topology, at: ATResult, K: int = 6, seed: int = 0
                ) -> List[FaultSweepResult]:
    """Re-route under each single-OCS fault using the (robust) AT set."""
    out = []
    n_pairs = topo.n * (topo.n - 1)
    for color in colors_in_use(topo):
        dead = dead_channels_for_color(at, color)
        routed = select_paths(at, K=K, seed=seed, dead_channels=dead)
        out.append(FaultSweepResult(color, routed,
                                    routed.unreachable == 0))
    return out
