"""Pluggable traffic patterns for the cycle-level simulator.

The paper evaluates uniform-random and all-to-all traffic only; related
work (TopoOpt's parallelization-derived traffic, UB-Mesh's hierarchically
localized patterns) shows traffic diversity is decisive when comparing
topologies. A :class:`TrafficPattern` is an (n, n) non-negative demand
matrix (zero diagonal) plus per-source relative injection intensities; it
compiles to per-source *alias sampling tables* (Vose's method) so that the
jitted simulator draws a destination in O(1) with two random numbers and
two gathers -- the same kernel serves every pattern, only the table
contents change (no per-pattern recompilation).

Built-in patterns:

- ``uniform``      -- uniform-random over all other nodes (paper Fig. 5)
- ``permutation``  -- one fixed partner per source (transpose/complement)
- ``hotspot``      -- a fraction of traffic targets a small hot set
- ``from_demand``  -- weights from a :class:`repro.core.demand.WorkloadDemand`
                      (parallelization-derived: DP rings + in-cube TP/EP)
- ``fault_correlated`` -- demand concentrated around a failed-OCS region
                      (the nodes that lost links): recovery traffic --
                      re-replication, checkpoint restore, re-sharding --
                      clusters exactly where capacity just dropped, the
                      adversarial case for fault re-routing (fig8)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class CompiledTraffic:
    """Alias tables ready for the jitted kernel (device-transferable)."""
    prob: np.ndarray        # (n, n) float32: alias acceptance probability
    alias: np.ndarray       # (n, n) int32: alias destination
    src_rate: np.ndarray    # (n,) float32: relative injection rate, mean 1


def _alias_tables(w: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Vose alias construction, batched over all rows at once.

    w: (n, n) non-negative weights. Rows with zero mass get a degenerate
    table (prob 0, alias 0) and must be masked by ``src_rate == 0`` on
    the caller side.

    The seed ran Vose's stack loop per row in python (O(n^2) interpreter
    steps per pattern -- the compile-time bottleneck at 512+ nodes). Here
    every row keeps its small/large stacks as columns of shared (n, n)
    index arrays with per-row tops, and each loop iteration retires one
    small entry of *every* unfinished row: <= 2n vectorised iterations
    total, identical alias-table semantics.
    """
    n = w.shape[0]
    prob = np.zeros((n, n), np.float32)
    alias = np.zeros((n, n), np.int32)
    total = w.sum(axis=1, dtype=np.float64)
    live = total > 0
    if not live.any():
        return prob, alias
    q = np.zeros((n, n), np.float64)
    q[live] = w[live] * (n / total[live, None])
    prob[live] = 1.0
    alias[live] = np.arange(n, dtype=np.int32)
    small_mask = (q < 1.0) & live[:, None]
    large_mask = (q >= 1.0) & live[:, None]
    # left-aligned per-row stacks: first `top` entries are the stack,
    # ascending index order (stable argsort of the mask), top = last
    st_small = np.argsort(~small_mask, kind="stable", axis=1) \
        .astype(np.int32)
    st_large = np.argsort(~large_mask, kind="stable", axis=1) \
        .astype(np.int32)
    top_s = small_mask.sum(axis=1).astype(np.int64)
    top_l = large_mask.sum(axis=1).astype(np.int64)
    while True:
        act = np.nonzero((top_s > 0) & (top_l > 0))[0]
        if not len(act):
            break
        s = st_small[act, top_s[act] - 1]
        l = st_large[act, top_l[act] - 1]
        qs = q[act, s]
        prob[act, s] = qs
        alias[act, s] = l
        ql = q[act, l] - (1.0 - qs)
        q[act, l] = ql
        top_s[act] -= 1
        # a large that dropped below 1 moves onto the small stack
        demote = act[ql < 1.0]
        if len(demote):
            st_small[demote, top_s[demote]] = st_large[demote,
                                                       top_l[demote] - 1]
            top_s[demote] += 1
            top_l[demote] -= 1
    # leftovers on either stack accept directly (prob stays 1)
    return prob, alias


@dataclasses.dataclass
class TrafficPattern:
    """Demand matrix + per-source intensity; compiles to alias tables."""
    name: str
    matrix: np.ndarray          # (n, n) float64, zero diagonal
    src_rate: Optional[np.ndarray] = None   # (n,), defaults to row-mass/mean

    def __post_init__(self):
        m = np.asarray(self.matrix, np.float64).copy()
        np.fill_diagonal(m, 0.0)
        self.matrix = m
        if self.src_rate is None:
            mass = m.sum(axis=1)
            mean = mass[mass > 0].mean() if (mass > 0).any() else 1.0
            self.src_rate = (mass / mean).astype(np.float32)
        else:
            self.src_rate = np.asarray(self.src_rate, np.float32)

    @property
    def n(self) -> int:
        return self.matrix.shape[0]

    def compiled(self) -> CompiledTraffic:
        prob, alias = _alias_tables(self.matrix)
        return CompiledTraffic(prob, alias,
                               np.asarray(self.src_rate, np.float32))

    # ---- constructors -----------------------------------------------------

    @staticmethod
    def uniform(n: int) -> "TrafficPattern":
        m = np.ones((n, n), np.float64)
        return TrafficPattern("uniform", m)

    @staticmethod
    def permutation(perm: Sequence[int],
                    name: str = "permutation") -> "TrafficPattern":
        """One destination per source; fixed points inject nothing."""
        perm = np.asarray(perm, np.int64)
        n = len(perm)
        m = np.zeros((n, n), np.float64)
        src = np.arange(n)
        ok = perm != src
        m[src[ok], perm[ok]] = 1.0
        return TrafficPattern(name, m)

    @staticmethod
    def transpose(pod) -> "TrafficPattern":
        """Coordinate-transpose permutation (x, y, z) -> (z, y, x) when the
        pod is axis-symmetric; otherwise the coordinate complement
        (x, y, z) -> (X-1-x, Y-1-y, Z-1-z), which is a fixed-point-free
        permutation on any pod shape."""
        X, Y, Z = pod.dims
        coords = pod.all_coords()
        if X == Z:
            perm = coords[:, 2] + X * (coords[:, 1] + Y * coords[:, 0])
            return TrafficPattern.permutation(perm, name="transpose")
        comp = np.array(pod.dims) - 1 - coords
        perm = comp[:, 0] + X * (comp[:, 1] + Y * comp[:, 2])
        return TrafficPattern.permutation(perm, name="transpose")

    @staticmethod
    def hotspot(n: int, hot: Optional[Sequence[int]] = None,
                frac: float = 0.5) -> "TrafficPattern":
        """``frac`` of each source's traffic targets the hot set uniformly,
        the rest is uniform-random over the non-hot nodes."""
        if hot is None:
            hot = [0]
        hot = np.asarray(sorted(set(int(h) for h in hot)), np.int64)
        cold = np.ones((n, n), np.float64)
        cold[:, hot] = 0.0
        np.fill_diagonal(cold, 0.0)
        cold_mass = cold.sum(axis=1, keepdims=True)
        m = cold / np.maximum(cold_mass, 1e-12) * (1.0 - frac)
        hotm = np.zeros((n, n), np.float64)
        hotm[:, hot] = 1.0
        np.fill_diagonal(hotm, 0.0)
        hot_mass = hotm.sum(axis=1, keepdims=True)
        m = m + hotm / np.maximum(hot_mass, 1e-12) * frac
        return TrafficPattern(f"hotspot{len(hot)}", m,
                              src_rate=np.ones(n, np.float32))

    @staticmethod
    def fault_correlated(n: int, region: Sequence[int],
                         frac: float = 0.5,
                         src_boost: float = 2.0) -> "TrafficPattern":
        """Demand concentrated on a failed-OCS region.

        ``region`` is the set of nodes that lost links to the fault
        (see :func:`repro.core.fault.fault_region_nodes`). Every source
        sends ``frac`` of its traffic uniformly into the region and the
        rest uniformly elsewhere -- recovery flows (re-replication,
        checkpoint restore) target the impaired machines -- while
        sources inside the region inject ``src_boost`` times the
        baseline rate (they also re-send what the dead links dropped).
        """
        region = np.asarray(sorted(set(int(r) for r in region)), np.int64)
        if not len(region) or len(region) >= n:
            raise ValueError("fault region must be a proper non-empty "
                             "subset of the nodes")
        inm = np.zeros((n, n), np.float64)
        inm[:, region] = 1.0
        np.fill_diagonal(inm, 0.0)
        out = np.ones((n, n), np.float64)
        out[:, region] = 0.0
        np.fill_diagonal(out, 0.0)
        in_mass = inm.sum(axis=1, keepdims=True)
        out_mass = out.sum(axis=1, keepdims=True)
        m = inm / np.maximum(in_mass, 1e-12) * frac \
            + out / np.maximum(out_mass, 1e-12) * (1.0 - frac)
        rate = np.ones(n, np.float32)
        rate[region] = src_boost
        return TrafficPattern(f"fault{len(region)}", m, src_rate=rate)

    @staticmethod
    def from_demand(wd) -> "TrafficPattern":
        """Weights from a WorkloadDemand (repro.core.demand): DP all-reduce
        rings across cubes + TP/EP all-to-all inside cubes + uniform floor,
        i.e. traffic derived from the job's parallelization strategy."""
        return TrafficPattern("demand", wd.matrix())

    @staticmethod
    def from_matrix(name: str, matrix: np.ndarray,
                    src_rate: Optional[np.ndarray] = None) -> "TrafficPattern":
        return TrafficPattern(name, matrix, src_rate)
