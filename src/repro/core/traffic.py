"""Pluggable traffic patterns for the cycle-level simulator.

The paper evaluates uniform-random and all-to-all traffic only; related
work (TopoOpt's parallelization-derived traffic, UB-Mesh's hierarchically
localized patterns) shows traffic diversity is decisive when comparing
topologies. A :class:`TrafficPattern` is an (n, n) non-negative demand
matrix (zero diagonal) plus per-source relative injection intensities; it
compiles to per-source *alias sampling tables* (Vose's method) so that the
jitted simulator draws a destination in O(1) with two random numbers and
two gathers -- the same kernel serves every pattern, only the table
contents change (no per-pattern recompilation).

Built-in patterns:

- ``uniform``      -- uniform-random over all other nodes (paper Fig. 5)
- ``permutation``  -- one fixed partner per source (transpose/complement)
- ``hotspot``      -- a fraction of traffic targets a small hot set
- ``from_demand``  -- weights from a :class:`repro.core.demand.WorkloadDemand`
                      (parallelization-derived: DP rings + in-cube TP/EP)
- ``fault_correlated`` -- demand concentrated around a failed-OCS region
                      (the nodes that lost links): recovery traffic --
                      re-replication, checkpoint restore, re-sharding --
                      clusters exactly where capacity just dropped, the
                      adversarial case for fault re-routing (fig8)

Beyond single stationary patterns (PR 10, workload co-design):

- :func:`compose_tenants` merges several jobs' sub-pod demand matrices
  (disjoint or overlapping node sets, per-job rate shares) into one
  pattern carrying a :class:`TenantMap`, and the sim kernels account
  injected/consumed/in-flight packets *per tenant*;
- :class:`PhasedTraffic` replays a recorded collective trace as a cyclic
  schedule of demand phases -- the spatial pattern itself switches over
  time (MoE all-to-all -> DP all-reduce -> background), complementing
  :class:`BurstSchedule` which only modulates intensity.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class CompiledTraffic:
    """Alias tables ready for the jitted kernel (device-transferable)."""
    prob: np.ndarray        # (n, n) float32: alias acceptance probability
    alias: np.ndarray       # (n, n) int32: alias destination
    src_rate: np.ndarray    # (n,) float32: relative injection rate, mean 1

    def row_probs(self) -> np.ndarray:
        """Exact (n, n) sampling distribution the alias tables encode
        (each row sums to 1 for live rows): the inverse of
        :func:`_alias_tables`, used to re-target a compiled pattern onto
        a different sampling domain (e.g. CSR flow slots)."""
        n = self.prob.shape[0]
        p = self.prob.astype(np.float64) / n
        rows = np.repeat(np.arange(n), n)
        np.add.at(p, (rows, self.alias.reshape(-1)),
                  ((1.0 - self.prob.astype(np.float64)) / n).reshape(-1))
        return p


def _alias_tables_ragged(w: np.ndarray,
                         deg: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Vose alias construction over ragged rows, batched.

    ``w`` is (R, W) non-negative weights where only the first ``deg[r]``
    columns of row ``r`` are real; padding columns never enter the
    stacks and keep (prob 0, alias = own column). Rows with zero mass
    get a degenerate self-alias table (prob 0, alias = own column: a
    draw deterministically returns the drawn slot) and must be masked by
    ``src_rate == 0`` on the caller side.

    Every row keeps its small/large stacks as columns of shared (R, W)
    index arrays with per-row tops, and each loop iteration retires one
    small entry of *every* unfinished row: <= 2W vectorised iterations
    total, identical alias-table semantics to the per-row scalar loop.
    """
    R, W = w.shape
    prob = np.zeros((R, W), np.float32)
    alias = np.broadcast_to(np.arange(W, dtype=np.int32), (R, W)).copy()
    colm = np.arange(W)[None, :] < np.asarray(deg)[:, None]
    wv = np.where(colm, w, 0.0)
    total = wv.sum(axis=1, dtype=np.float64)
    live = total > 0
    if not live.any():
        return prob, alias
    livec = live[:, None] & colm
    q = np.zeros((R, W), np.float64)
    q[livec] = (wv * (np.asarray(deg, np.float64)[:, None]
                      / np.where(live, total, 1.0)[:, None]))[livec]
    prob[livec] = 1.0
    small_mask = (q < 1.0) & livec
    large_mask = (q >= 1.0) & livec
    # left-aligned per-row stacks: first `top` entries are the stack,
    # ascending index order (stable argsort of the mask), top = last
    st_small = np.argsort(~small_mask, kind="stable", axis=1) \
        .astype(np.int32)
    st_large = np.argsort(~large_mask, kind="stable", axis=1) \
        .astype(np.int32)
    top_s = small_mask.sum(axis=1).astype(np.int64)
    top_l = large_mask.sum(axis=1).astype(np.int64)
    while True:
        act = np.nonzero((top_s > 0) & (top_l > 0))[0]
        if not len(act):
            break
        s = st_small[act, top_s[act] - 1]
        l = st_large[act, top_l[act] - 1]
        qs = q[act, s]
        prob[act, s] = qs
        alias[act, s] = l
        ql = q[act, l] - (1.0 - qs)
        q[act, l] = ql
        top_s[act] -= 1
        # a large that dropped below 1 moves onto the small stack
        demote = act[ql < 1.0]
        if len(demote):
            st_small[demote, top_s[demote]] = st_large[demote,
                                                       top_l[demote] - 1]
            top_s[demote] += 1
            top_l[demote] -= 1
    # leftovers on either stack accept directly (prob stays 1)
    return prob, alias


def _alias_tables(w: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Square (n, n) Vose construction: every column of every row is a
    real slot (the classic per-destination tables). Thin wrapper over the
    ragged builder, preserving the historical degenerate-row encoding
    (zero-mass rows get alias 0 rather than self-alias)."""
    n = w.shape[0]
    prob, alias = _alias_tables_ragged(w, np.full(n, n, np.int64))
    dead = w.sum(axis=1) <= 0
    alias[dead] = 0
    return prob, alias


@dataclasses.dataclass(frozen=True)
class BurstSchedule:
    """Deterministic on/off injection modulation (time-varying traffic).

    Each source's injection probability is multiplied by ``gain`` during
    the first ``round(duty * period)`` cycles of its period (offset by
    ``phase[src]``) and by the compensating off-gain
    ``(1 - duty * gain) / (1 - duty)`` the rest -- mean-preserving by
    construction, so bursty and steady sweeps at the same nominal rate
    offer the same long-run load and their saturation points stay
    comparable. ``phase=None`` synchronises every source (the hardest
    case: the whole fabric bursts together); pass per-source offsets to
    stagger.
    """
    period: int
    duty: float
    gain: float
    phase: Optional[np.ndarray] = None   # (n,) int cycle offsets

    def __post_init__(self):
        if self.period < 2:
            raise ValueError("burst period must be >= 2 cycles")
        if not 0.0 < self.duty < 1.0:
            raise ValueError("burst duty must be in (0, 1)")
        if not 1.0 <= self.gain <= 1.0 / self.duty + 1e-9:
            raise ValueError(f"burst gain must be in [1, 1/duty] "
                             f"(got {self.gain}, duty {self.duty}); the "
                             f"off-phase gain would go negative")

    def realize(self, n: int):
        """(on_cycles, g_on, g_off, phase array) for the kernel, with
        the duty re-derived from the integer on-window so the mean is
        preserved exactly."""
        on = int(np.clip(round(self.duty * self.period), 1,
                         self.period - 1))
        duty = on / self.period
        g_on = float(self.gain)
        g_off = (1.0 - duty * g_on) / (1.0 - duty)
        if g_off < 0:
            raise ValueError(f"burst gain {self.gain} too high for the "
                             f"realized duty {duty:.3f}")
        if self.phase is None:
            phase = np.zeros(n, np.int32)
        else:
            phase = np.asarray(self.phase, np.int32) % self.period
            if phase.shape != (n,):
                raise ValueError(f"burst phase must be ({n},)")
        return on, g_on, g_off, phase


@dataclasses.dataclass(frozen=True)
class CompiledFlowTraffic:
    """Alias tables over the *flow slots* of a CSR path table.

    Where :class:`CompiledTraffic` samples a destination node from
    (n, n) tables, this samples a routed flow id directly from flat
    (F,) tables aligned with ``CSRPathTable``'s row-major flow order:
    draw a slot ``j`` uniformly in ``[0, deg[s])``, then accept
    ``src_indptr[s] + j`` or take its alias. Demand on unrouted pairs is
    dropped at compile time (each live row renormalises over its routed
    flows), so offered traffic is always injectable; memory is O(F), not
    O(n^2) -- the sampling-side counterpart of the CSR simulator kernel.
    ``burst`` (when set) rides along from the source pattern and makes
    the kernel modulate injection thresholds over time.

    Compiled from a :class:`PhasedTraffic`, ``phases`` is P > 0 and
    ``prob``/``alias``/``src_rate`` grow a leading phase axis --
    (P, F)/(P, F)/(P, n) -- with ``phase_of`` mapping cycle-in-period to
    phase index; stationary patterns keep the flat shapes with
    ``phases == 0``. ``tenants`` (from :func:`compose_tenants`) rides
    along for the kernels' per-tenant packet accounting.
    """
    n: int
    src_indptr: np.ndarray  # (n + 1,) int32: flow range of each source
    deg: np.ndarray         # (n,) int32: routed flow count per source
    prob: np.ndarray        # (F,) float32 -- or (P, F) when phased
    alias: np.ndarray       # (F,) int32 alias flow id -- or (P, F)
    src_rate: np.ndarray    # (n,) float32 -- or (P, n) when phased
    burst: Optional[BurstSchedule] = None
    tenants: Optional[TenantMap] = None
    phases: int = 0                          # P; 0 = stationary
    phase_of: Optional[np.ndarray] = None    # (period,) int32 when phased


def compile_flow_traffic(traffic, src_indptr: np.ndarray,
                         dst: np.ndarray,
                         block: int = 2048) -> CompiledFlowTraffic:
    """Compile a traffic pattern onto a CSR flow space.

    ``traffic`` is a :class:`TrafficPattern`, a :class:`CompiledTraffic`
    (re-targeted exactly via :meth:`CompiledTraffic.row_probs`), a
    :class:`PhasedTraffic` (each phase compiled independently and
    stacked along a leading axis), or ``None`` for uniform.
    ``src_indptr``/``dst`` come straight from the ``CSRPathTable``. Rows
    are processed in blocks of ``block`` sources so the padded
    (block, max_deg) staging arrays stay small at 4096 chips.
    """
    if isinstance(traffic, PhasedTraffic):
        parts = [compile_flow_traffic(p, src_indptr, dst, block=block)
                 for p in traffic.patterns]
        phase_of = np.repeat(
            np.arange(len(parts), dtype=np.int32),
            np.asarray(traffic.cycles, np.int64))
        c0 = parts[0]
        return CompiledFlowTraffic(
            c0.n, c0.src_indptr, c0.deg,
            np.stack([c.prob for c in parts]),
            np.stack([c.alias for c in parts]),
            np.stack([c.src_rate for c in parts]),
            burst=traffic.burst, tenants=traffic.tenants,
            phases=len(parts), phase_of=phase_of)
    n = len(src_indptr) - 1
    F = len(dst)
    sptr = np.asarray(src_indptr, np.int64)
    deg = np.diff(sptr).astype(np.int32)
    prob = np.ones(F, np.float32)
    alias = np.arange(F, dtype=np.int32)
    if traffic is None:
        # uniform over routed flows: all weights equal -> every slot is
        # exactly "large" (q == 1) and accepts directly; skip the (n, n)
        # matrix entirely (134 MB at 16^3)
        return CompiledFlowTraffic(n, sptr.astype(np.int32), deg, prob,
                                   alias, np.ones(n, np.float32))
    burst = None
    tenants = None
    if isinstance(traffic, CompiledTraffic):
        matrix = traffic.row_probs()
        src_rate = np.asarray(traffic.src_rate, np.float32)
    else:
        matrix = traffic.matrix
        src_rate = np.asarray(traffic.src_rate, np.float32)
        burst = traffic.burst
        tenants = traffic.tenants
    if matrix.shape[0] != n:
        raise ValueError(f"pattern over {matrix.shape[0]} nodes, table "
                         f"over {n}")
    dst64 = np.asarray(dst, np.int64)
    for s0 in range(0, n, block):
        s1 = min(s0 + block, n)
        f0, f1 = int(sptr[s0]), int(sptr[s1])
        if f1 == f0:
            continue
        degb = deg[s0:s1].astype(np.int64)
        Wb = int(degb.max())
        colm = np.arange(Wb)[None, :] < degb[:, None]
        wpad = np.zeros((s1 - s0, Wb), np.float64)
        flow_src = np.repeat(np.arange(s0, s1), degb)
        wpad[colm] = matrix[flow_src, dst64[f0:f1]]
        p, a = _alias_tables_ragged(wpad, degb)
        prob[f0:f1] = p[colm]
        alias[f0:f1] = (sptr[s0:s1, None].astype(np.int64)
                        + a.astype(np.int64))[colm].astype(np.int32)
    return CompiledFlowTraffic(n, sptr.astype(np.int32), deg, prob, alias,
                               src_rate, burst=burst, tenants=tenants)


@dataclasses.dataclass
class TrafficPattern:
    """Demand matrix + per-source intensity; compiles to alias tables.

    ``burst`` attaches a :class:`BurstSchedule`: the *spatial* pattern
    (who talks to whom) is unchanged, only the injection intensity
    becomes time-varying in the kernel."""
    name: str
    matrix: np.ndarray          # (n, n) float64, zero diagonal
    src_rate: Optional[np.ndarray] = None   # (n,), defaults to row-mass/mean
    burst: Optional[BurstSchedule] = None
    tenants: Optional["TenantMap"] = None   # set by compose_tenants

    def __post_init__(self):
        m = np.asarray(self.matrix, np.float64).copy()
        np.fill_diagonal(m, 0.0)
        self.matrix = m
        if self.src_rate is None:
            mass = m.sum(axis=1)
            mean = mass[mass > 0].mean() if (mass > 0).any() else 1.0
            self.src_rate = (mass / mean).astype(np.float32)
        else:
            self.src_rate = np.asarray(self.src_rate, np.float32)

    @property
    def n(self) -> int:
        return self.matrix.shape[0]

    def compiled(self) -> CompiledTraffic:
        prob, alias = _alias_tables(self.matrix)
        return CompiledTraffic(prob, alias,
                               np.asarray(self.src_rate, np.float32))

    def with_burst(self, period: int, duty: float = 0.25,
                   gain: float = 3.0,
                   phase: Optional[np.ndarray] = None) -> "TrafficPattern":
        """Same spatial pattern, bursty in time (mean-preserving):
        ``gain``x injection for ``duty`` of each ``period``, compensated
        the rest. Returns a new pattern; the original is untouched."""
        return TrafficPattern(f"{self.name}+burst{period}", self.matrix,
                              src_rate=self.src_rate,
                              burst=BurstSchedule(period, duty, gain,
                                                  phase),
                              tenants=self.tenants)

    # ---- constructors -----------------------------------------------------

    @staticmethod
    def uniform(n: int) -> "TrafficPattern":
        m = np.ones((n, n), np.float64)
        return TrafficPattern("uniform", m)

    @staticmethod
    def permutation(perm: Sequence[int],
                    name: str = "permutation") -> "TrafficPattern":
        """One destination per source; fixed points inject nothing."""
        perm = np.asarray(perm, np.int64)
        n = len(perm)
        m = np.zeros((n, n), np.float64)
        src = np.arange(n)
        ok = perm != src
        m[src[ok], perm[ok]] = 1.0
        return TrafficPattern(name, m)

    @staticmethod
    def transpose(pod) -> "TrafficPattern":
        """Coordinate-transpose permutation (x, y, z) -> (z, y, x) when the
        pod is axis-symmetric; otherwise the coordinate complement
        (x, y, z) -> (X-1-x, Y-1-y, Z-1-z), which is a fixed-point-free
        permutation on any pod shape."""
        X, Y, Z = pod.dims
        coords = pod.all_coords()
        if X == Z:
            perm = coords[:, 2] + X * (coords[:, 1] + Y * coords[:, 0])
            return TrafficPattern.permutation(perm, name="transpose")
        comp = np.array(pod.dims) - 1 - coords
        perm = comp[:, 0] + X * (comp[:, 1] + Y * comp[:, 2])
        return TrafficPattern.permutation(perm, name="transpose")

    @staticmethod
    def hotspot(n: int, hot: Optional[Sequence[int]] = None,
                frac: float = 0.5) -> "TrafficPattern":
        """``frac`` of each source's traffic targets the hot set uniformly,
        the rest is uniform-random over the non-hot nodes."""
        if hot is None:
            hot = [0]
        hot = np.asarray(sorted(set(int(h) for h in hot)), np.int64)
        cold = np.ones((n, n), np.float64)
        cold[:, hot] = 0.0
        np.fill_diagonal(cold, 0.0)
        cold_mass = cold.sum(axis=1, keepdims=True)
        m = cold / np.maximum(cold_mass, 1e-12) * (1.0 - frac)
        hotm = np.zeros((n, n), np.float64)
        hotm[:, hot] = 1.0
        np.fill_diagonal(hotm, 0.0)
        hot_mass = hotm.sum(axis=1, keepdims=True)
        m = m + hotm / np.maximum(hot_mass, 1e-12) * frac
        return TrafficPattern(f"hotspot{len(hot)}", m,
                              src_rate=np.ones(n, np.float32))

    @staticmethod
    def fault_correlated(n: int, region: Sequence[int],
                         frac: float = 0.5,
                         src_boost: float = 2.0) -> "TrafficPattern":
        """Demand concentrated on a failed-OCS region.

        ``region`` is the set of nodes that lost links to the fault
        (see :func:`repro.core.fault.fault_region_nodes`). Every source
        sends ``frac`` of its traffic uniformly into the region and the
        rest uniformly elsewhere -- recovery flows (re-replication,
        checkpoint restore) target the impaired machines -- while
        sources inside the region inject ``src_boost`` times the
        baseline rate (they also re-send what the dead links dropped).
        """
        region = np.asarray(sorted(set(int(r) for r in region)), np.int64)
        if not len(region) or len(region) >= n:
            raise ValueError("fault region must be a proper non-empty "
                             "subset of the nodes")
        inm = np.zeros((n, n), np.float64)
        inm[:, region] = 1.0
        np.fill_diagonal(inm, 0.0)
        out = np.ones((n, n), np.float64)
        out[:, region] = 0.0
        np.fill_diagonal(out, 0.0)
        in_mass = inm.sum(axis=1, keepdims=True)
        out_mass = out.sum(axis=1, keepdims=True)
        m = inm / np.maximum(in_mass, 1e-12) * frac \
            + out / np.maximum(out_mass, 1e-12) * (1.0 - frac)
        rate = np.ones(n, np.float32)
        rate[region] = src_boost
        return TrafficPattern(f"fault{len(region)}", m, src_rate=rate)

    @staticmethod
    def from_demand(wd) -> "TrafficPattern":
        """Weights from a WorkloadDemand (repro.core.demand): DP all-reduce
        rings across cubes + TP/EP all-to-all inside cubes + uniform floor,
        i.e. traffic derived from the job's parallelization strategy."""
        return TrafficPattern("demand", wd.matrix())

    @staticmethod
    def from_matrix(name: str, matrix: np.ndarray,
                    src_rate: Optional[np.ndarray] = None) -> "TrafficPattern":
        return TrafficPattern(name, matrix, src_rate)

    @staticmethod
    def from_trace(n: int, trace: Sequence[Tuple[int, int, int]],
                   name: str = "trace") -> "TrafficPattern":
        """Demand from a recorded collective trace -- a sequence of
        ``(src, dst, n_chunks)`` transfers as emitted by
        :func:`repro.core.collectives.a2a_trace`. Chunk counts on the
        same pair accumulate."""
        m = np.zeros((n, n), np.float64)
        if len(trace):
            t = np.asarray([(s, d, c) for s, d, c in trace], np.int64)
            np.add.at(m, (t[:, 0], t[:, 1]), t[:, 2].astype(np.float64))
        return TrafficPattern(name, m)


# ---------------------------------------------------------------------------
# Multi-tenant composition: several jobs sharing one fabric
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One job in a shared pod: demand over its own node subset.

    ``matrix`` is (m, m) over ``nodes`` order (m = len(nodes));
    ``rate_share`` is the job's relative injection intensity -- a
    tenant's per-source demand mass is normalised to ``rate_share``, so
    two tenants with shares 1.0 and 0.5 offer a 2:1 per-node load ratio
    regardless of how their raw matrices were scaled.
    """
    name: str
    nodes: np.ndarray
    matrix: np.ndarray
    rate_share: float = 1.0


@dataclasses.dataclass(frozen=True)
class TenantMap:
    """Per-pair tenant attribution for a composed multi-job pattern.

    ``pair_tenant[s, d]`` is the tenant id whose demand dominates the
    (s, d) pair, -1 for pairs no tenant uses. For disjoint node sets the
    attribution is exact (each pair belongs to at most one tenant); for
    overlapping sets a shared pair is attributed to its dominant
    contributor (argmax of composed weight), an approximation the
    per-tenant counters inherit and the docstrings of
    :func:`compose_tenants` call out.
    """
    names: Tuple[str, ...]
    pair_tenant: np.ndarray     # (n, n) int32, -1 = unattributed
    n_nodes: Tuple[int, ...]    # node-set size per tenant

    @property
    def n_tenants(self) -> int:
        return len(self.names)


def compose_tenants(n: int,
                    tenants: Sequence[TenantSpec]) -> TrafficPattern:
    """Compose per-job sub-pod demands into one fabric-wide pattern.

    Each tenant's matrix is embedded at its global node ids, normalised
    so its mean per-source mass equals ``rate_share``, and summed.
    ``src_rate`` becomes each node's summed share (so a node serving two
    jobs injects both jobs' load); the returned pattern carries a
    :class:`TenantMap` that the sim kernels use for per-tenant
    injected/consumed/in-flight accounting (exact packet conservation
    per tenant -- every injected packet is consumed or still queued).
    """
    if not tenants:
        raise ValueError("need at least one tenant")
    names = [t.name for t in tenants]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate tenant names: {names}")
    total = np.zeros((n, n), np.float64)
    best = np.zeros((n, n), np.float64)
    pair = np.full((n, n), -1, np.int32)
    share = np.zeros(n, np.float64)
    for t_id, t in enumerate(tenants):
        nodes = np.asarray(t.nodes, np.int64)
        m = len(nodes)
        if m < 2 or len(np.unique(nodes)) != m:
            raise ValueError(f"tenant {t.name!r}: nodes must be >= 2 "
                             f"unique ids")
        if nodes.min() < 0 or nodes.max() >= n:
            raise ValueError(f"tenant {t.name!r}: node ids outside "
                             f"[0, {n})")
        sub = np.asarray(t.matrix, np.float64).copy()
        if sub.shape != (m, m):
            raise ValueError(f"tenant {t.name!r}: matrix {sub.shape} vs "
                             f"{m} nodes")
        np.fill_diagonal(sub, 0.0)
        if (sub < 0).any():
            raise ValueError(f"tenant {t.name!r}: negative demand")
        mass = sub.sum()
        if mass <= 0:
            raise ValueError(f"tenant {t.name!r}: zero demand mass")
        w = sub / mass * (float(t.rate_share) * m)
        ix = np.ix_(nodes, nodes)
        total[ix] += w
        blk = best[ix]
        pblk = pair[ix]
        take = w > blk
        pblk[take] = t_id
        pair[ix] = pblk
        best[ix] = np.maximum(blk, w)
        share[nodes] += float(t.rate_share)
    live = share > 0
    src_rate = (share / share[live].mean()).astype(np.float32)
    tmap = TenantMap(tuple(names), pair,
                     tuple(len(np.asarray(t.nodes)) for t in tenants))
    name = "tenants:" + "+".join(names)
    return TrafficPattern(name, total, src_rate=src_rate, tenants=tmap)


# ---------------------------------------------------------------------------
# Trace-driven replay: cyclic schedule of demand phases
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PhasedTraffic:
    """A recorded collective trace as a cyclic demand schedule.

    Where :class:`BurstSchedule` modulates injection *intensity* under a
    fixed spatial pattern, a PhasedTraffic switches the spatial demand
    itself: phase ``p`` runs ``cycles[p]`` sim cycles with
    ``patterns[p]``'s matrix and source rates, then the schedule wraps.
    This replays a training step's collective sequence (e.g. MoE
    all-to-all -> DP all-reduce ring -> background) against the fabric
    instead of a stationary average. Compiles per phase onto the CSR
    flow slots; the kernel indexes the phase by cycle with the same RNG
    draw count as the stationary path, so a single-phase schedule is
    bit-identical to its stationary pattern. ``burst`` (optional)
    modulates intensity on top of the phase schedule; ``tenants``
    attributes pairs for per-tenant accounting (phase-independent).
    """
    name: str
    patterns: Tuple[TrafficPattern, ...]
    cycles: Tuple[int, ...]
    burst: Optional[BurstSchedule] = None
    tenants: Optional[TenantMap] = None

    def __post_init__(self):
        if not self.patterns or len(self.patterns) != len(self.cycles):
            raise ValueError("need one cycle count per phase pattern")
        if any(int(c) < 1 for c in self.cycles):
            raise ValueError("every phase must last >= 1 cycle")
        if len({p.n for p in self.patterns}) != 1:
            raise ValueError("all phase patterns must cover the same "
                             "node count")

    @property
    def n(self) -> int:
        return self.patterns[0].n

    @property
    def period(self) -> int:
        return int(sum(self.cycles))
