"""Fig. 1 reproduction: directed 4-radix topologies, literature baselines
vs TONS synthesis without TPU constraints.

Baselines: Kautz [48], GenKautz/Imase-Itoh [40], Xpander [85] (random lifts
of K_{r+1}), Jellyfish [77] (random regular). Synthesis: the same dualized
LR formulation with degree-<=r constraints on a directed edge set.
Conventions here: directed edges of capacity 1, one unit of demand per
ordered pair; Fig. 1's y-axis is n * MCF.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.lp import COOMatrix, solve_highs
from repro.core.mcf import mcf_uniform


# ---------------------------------------------------------------------------
# Reference generators
# ---------------------------------------------------------------------------


def kautz(r: int, m: int) -> Optional[np.ndarray]:
    """Kautz digraph K(r, m): N = (r+1) r^m nodes, out/in degree r."""
    alpha = r + 1
    words = []
    for first in range(alpha):
        for rest in itertools.product(range(r), repeat=m):
            w = [first]
            for x in rest:
                # next symbol distinct from previous: offset encoding
                w.append((w[-1] + 1 + x) % alpha)
            words.append(tuple(w))
    idx = {w: i for i, w in enumerate(words)}
    edges = []
    for w in words:
        for nxt in range(alpha):
            if nxt == w[-1]:
                continue
            w2 = w[1:] + (nxt,)
            edges.append((idx[w], idx[w2]))
    return np.array(edges, np.int32)


def kautz_sizes(r: int, max_n: int) -> Dict[int, int]:
    out = {}
    m = 1
    while (r + 1) * r ** m <= max_n:
        out[(r + 1) * r ** m] = m
        m += 1
    return out


def gen_kautz(n: int, r: int) -> np.ndarray:
    """Imase-Itoh generalisation: i -> (-r*i - j) mod n, j = 1..r."""
    edges = []
    for i in range(n):
        for j in range(1, r + 1):
            v = (-r * i - j) % n
            if v != i:
                edges.append((i, v))
    return np.array(sorted(set(edges)), np.int32)


def xpander(n: int, r: int, seed: int = 0) -> Optional[np.ndarray]:
    """Random lift of K_{r+1}; needs n divisible by r+1. Undirected edges
    returned as both directed arcs."""
    base = r + 1
    if n % base:
        return None
    k = n // base
    rng = np.random.default_rng(seed)
    edges = []
    for u in range(base):
        for v in range(u + 1, base):
            perm = rng.permutation(k)
            for l in range(k):
                a = u * k + l
                b = v * k + int(perm[l])
                edges.append((a, b))
                edges.append((b, a))
    return np.array(edges, np.int32)


def jellyfish(n: int, r: int, seed: int = 0) -> Optional[np.ndarray]:
    """Random r-regular undirected graph (pairing model w/ retries)."""
    rng = np.random.default_rng(seed)
    for _ in range(200):
        stubs = np.repeat(np.arange(n), r)
        rng.shuffle(stubs)
        pairs = stubs.reshape(-1, 2)
        if (pairs[:, 0] == pairs[:, 1]).any():
            continue
        und = {tuple(sorted(p)) for p in pairs.tolist()}
        if len(und) < len(pairs):
            continue
        edges = []
        for u, v in und:
            edges.append((u, v))
            edges.append((v, u))
        return np.array(edges, np.int32)
    return None


def directed_mcf(edges: np.ndarray, n: int, prefer="highs") -> float:
    lam, _ = mcf_uniform(edges, n, perms=None, directed=True, prefer=prefer)
    return lam


# ---------------------------------------------------------------------------
# Directed synthesis (TONS formulation, degree-constrained)
# ---------------------------------------------------------------------------


def build_directed_synthesis_lp(n: int, r: int):
    """Variables [lambda | m (n^2 ordered) | y (ordered triples)]."""
    pairs = [(a, b) for a in range(n) for b in range(n) if a != b]
    pidx = {p: i for i, p in enumerate(pairs)}
    n_m = len(pairs)
    trips = [(i, j, k) for i in range(n) for j in range(n) for k in range(n)
             if i != j and j != k and i != k]
    tidx = {t: i for i, t in enumerate(trips)}
    n_y = len(trips)
    m_off, y_off = 1, 1 + n_m
    n_var = y_off + n_y

    rows, cols, vals, b = [], [], [], []
    row = 0
    # C4 rows per ordered pair
    for (a, bb) in pairs:
        cols.append(0)
        vals.append(1.0)
        rows.append(row)
        for k in range(n):
            if k != a and k != bb:
                cols.append(y_off + tidx[(a, bb, k)])
                vals.append(-1.0)
                rows.append(row)
        for j in range(n):
            if j != a and j != bb:
                cols.append(y_off + tidx[(a, j, bb)])
                vals.append(1.0)
                rows.append(row)
        for i in range(n):
            if i != a and i != bb:
                cols.append(y_off + tidx[(i, a, bb)])
                vals.append(1.0)
                rows.append(row)
        cols.append(m_off + pidx[(a, bb)])
        vals.append(-1.0)
        rows.append(row)
        b.append(0.0)
        row += 1
    # degree constraints
    for a in range(n):
        for bb in range(n):
            if a != bb:
                cols.append(m_off + pidx[(a, bb)])
                vals.append(1.0)
                rows.append(row)
        b.append(float(r))
        row += 1
    for bb in range(n):
        for a in range(n):
            if a != bb:
                cols.append(m_off + pidx[(a, bb)])
                vals.append(1.0)
                rows.append(row)
        b.append(float(r))
        row += 1

    A = COOMatrix.from_triplets(rows, cols, vals, (row, n_var))
    c = np.zeros(n_var)
    c[0] = -1.0
    lo = np.zeros(n_var)
    hi = np.ones(n_var)
    return c, A, np.asarray(b), lo, hi, pairs, slice(m_off, m_off + n_m)


def synthesize_directed(n: int, r: int = 4, interval: Optional[int] = None,
                        verbose: bool = False, restarts: int = 1,
                        seed: int = 0) -> Tuple[np.ndarray, List[float]]:
    """Algorithm 3 for the unconstrained directed case (Fig. 1), with
    randomized greedy restarts (tiny tie-break noise on the fractional m)."""
    if restarts > 1:
        best = None
        for s in range(restarts):
            edges, lams = synthesize_directed(n, r, interval, verbose,
                                              restarts=1, seed=seed + s)
            lam = directed_mcf(edges, n)
            if best is None or lam > best[0]:
                best = (lam, edges, lams)
        return best[1], best[2]
    rng_noise = np.random.default_rng(seed)
    c, A, b, lo, hi, pairs, m_sl = build_directed_synthesis_lp(n, r)
    interval = interval or max(1, n // 8)
    out_deg = np.zeros(n, int)
    in_deg = np.zeros(n, int)
    fixed = np.zeros(len(pairs), bool)
    lambdas = []

    def feasible(i):
        a, bb = pairs[i]
        return (not fixed[i]) and hi[m_sl][i] > 0 and out_deg[a] < r \
            and in_deg[bb] < r

    while True:
        rem = [i for i in range(len(pairs)) if feasible(i)]
        if not rem:
            break
        res = solve_highs(c, A, b, lo, hi, method="highs-ipm")
        if res.status != "optimal":
            break
        lambdas.append(-res.obj)
        if verbose:
            print(f"  dsynth lambda={-res.obj:.5f} "
                  f"fixed={int(fixed.sum())}/{4 * n}")
        mv = res.x[m_sl].copy()
        if seed:
            mv = mv + rng_noise.normal(0, 2e-3, len(mv))
        mv[[not feasible(i) for i in range(len(pairs))]] = -np.inf
        picked = 0
        for i in np.argsort(-mv):
            if picked >= interval:
                break
            if feasible(int(i)) and mv[int(i)] > 0.0:
                fixed[int(i)] = True
                lo[m_sl][int(i)] = hi[m_sl][int(i)] = 1.0
                a, bb = pairs[int(i)]
                out_deg[a] += 1
                in_deg[bb] += 1
                for jj, (a2, b2) in enumerate(pairs):
                    if not fixed[jj] and (out_deg[a2] >= r or
                                          in_deg[b2] >= r):
                        hi[m_sl][jj] = 0.0
                picked += 1
        if picked == 0:
            break

    edges = np.array([pairs[i] for i in range(len(pairs)) if fixed[i]],
                     np.int32)
    return edges, lambdas
