"""One-call cold-build routing pipeline (facade over the staged API).

The cold-build chain ``Channels.from_topology -> allowed_turns ->
select_paths -> allocate_vcs -> build_tables`` used to be copy-pasted
across synthesis evaluation, the serving-state builder, the fault sweep,
four benchmarks and the examples, each with its own kwarg tunnel.
:func:`route_pod` runs the same stages off one :class:`PipelineConfig`
and returns a :class:`RoutedPod` carrying every intermediate the call
sites used to re-derive (allowed turns, routing result, VC counts,
simulator tables, per-stage wall-clock). This module adds no routing
semantics of its own -- the staged functions stay the extension
surface -- and a migrated call site produces bit-identical tables for
the same config and seed (tests/test_pipeline.py proves it against the
raw chain).

Three VC modes cover every internal consumer:

- ``vc="tables"`` (default): :func:`repro.core.netsim.at_tables`
  semantics -- allocate on a *copy* of the routed table and return
  simulator-ready :class:`~repro.core.netsim.SimTables` (synthesis
  evaluation, benchmarks, examples).
- ``vc="inplace"``: :func:`repro.core.vcalloc.allocate_vcs` directly on
  ``routed.table`` (no copy, no SimTables) -- the serving-state cold
  build, where the live table and the VC counts must be the same
  object the repair path later patches.
- ``vc="none"``: selection only -- fault sweeps and ablations that
  score ``l_max`` without ever simulating.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Union

import numpy as np

from repro.core.routing import (ATResult, RoutingResult, allowed_turns,
                                select_paths)
from repro.core.topology import Topology

_VC_MODES = ("tables", "inplace", "none")


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """Every knob of the cold-build chain in one place.

    Field groups mirror the stages: admission (``n_vc``/``priority``/
    ``robust``/``at_engine``), selection (``K``/``seed``/``engine``/
    ``local_search_rounds``/``shard_sources``/``rounds``/``k_min``/
    ``refine_cap``/``uniq_dp``/``block``), VC allocation (``vc``/
    ``balance``/``reserve_escape``) and verification (``verify``).
    Defaults match the repo-wide common case (sharded selection at
    K=4, balanced VC allocation into simulator tables).
    """
    # ---- allowed-turn admission ----
    n_vc: int = 2
    priority: str = "apl"
    robust: bool = False
    at_engine: str = "batched"
    # ---- path selection ----
    K: int = 4
    seed: int = 0
    engine: str = "sharded"
    local_search_rounds: int = 2
    block: Optional[int] = None
    shard_sources: int = 64
    rounds: int = 4
    k_min: Optional[int] = None
    refine_cap: Optional[int] = None
    uniq_dp: Union[str, bool] = "auto"
    # ---- VC allocation / tables ----
    vc: str = "tables"                  # "tables" | "inplace" | "none"
    balance: Optional[bool] = True      # None skips re-allocation
    reserve_escape: bool = False
    # ---- verification ----
    verify: bool = False

    def __post_init__(self):
        if self.vc not in _VC_MODES:
            raise ValueError(f"vc mode must be one of {_VC_MODES}, "
                             f"got {self.vc!r}")


@dataclasses.dataclass
class RoutedPod:
    """Everything the cold-build chain produced, in one object."""
    topo: Topology
    cfg: PipelineConfig
    at: ATResult
    routed: RoutingResult
    tables: Optional[Any] = None          # SimTables (vc="tables")
    vc_counts: Optional[np.ndarray] = None  # (n_vc,) (vc="inplace")
    vc_stats: Optional[dict] = None
    deadlock_free: Optional[bool] = None  # set when cfg.verify
    timings: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def table(self):
        """The routed path table (allocated in place for vc="inplace";
        the SimTables carry their own allocated copy for vc="tables")."""
        return self.routed.table

    @property
    def l_max(self) -> float:
        return float(self.routed.l_max)

    @property
    def avg_hops(self) -> float:
        return float(self.routed.avg_hops)

    @property
    def unreachable(self) -> int:
        return int(self.routed.unreachable)


def route_pod(topo: Topology, cfg: Optional[PipelineConfig] = None, *,
              at: Optional[ATResult] = None,
              dead_channels=None, chosen_loads=None,
              pair_weight: Optional[np.ndarray] = None,
              dist_out: Optional[np.ndarray] = None,
              best_out: Optional[np.ndarray] = None,
              select_kw: Optional[dict] = None) -> RoutedPod:
    """Run the cold-build chain on ``topo`` under one config.

    ``at`` reuses a prebuilt allowed-turn set (fault sweeps re-route
    against the no-fault AT); ``dead_channels`` masks failed channels
    during selection; ``chosen_loads`` enables the CPL admission
    variant; ``pair_weight`` enables demand-weighted selection
    (``engine="array"`` only -- see
    :func:`~repro.core.routing.select_paths`);
    ``dist_out``/``best_out`` capture the sharded engine's BFS
    distance fields (the serving-state hooks); ``select_kw`` overrides
    individual :func:`~repro.core.routing.select_paths` kwargs on top
    of the config (escape hatch for staged experiments).
    """
    cfg = cfg or PipelineConfig()
    timings: Dict[str, float] = {}
    if at is None:
        t0 = time.time()
        at = allowed_turns(topo, n_vc=cfg.n_vc, priority=cfg.priority,
                           robust=cfg.robust, seed=cfg.seed,
                           chosen_loads=chosen_loads,
                           at_engine=cfg.at_engine)
        timings["at_s"] = time.time() - t0
    kw = dict(K=cfg.K, seed=cfg.seed, engine=cfg.engine,
              dead_channels=dead_channels,
              local_search_rounds=cfg.local_search_rounds,
              block=cfg.block, shard_sources=cfg.shard_sources,
              rounds=cfg.rounds, k_min=cfg.k_min,
              refine_cap=cfg.refine_cap, uniq_dp=cfg.uniq_dp,
              pair_weight=pair_weight,
              dist_out=dist_out, best_out=best_out)
    kw.update(select_kw or {})
    t0 = time.time()
    routed = select_paths(at, **kw)
    timings["select_s"] = time.time() - t0

    tables = None
    vc_counts = None
    vc_stats: dict = {}
    t0 = time.time()
    if cfg.vc == "tables":
        from repro.core.netsim import at_tables
        tables = at_tables(topo, at, routed, balance=cfg.balance,
                           stats=vc_stats,
                           reserve_escape=cfg.reserve_escape)
    elif cfg.vc == "inplace":
        from repro.core.vcalloc import allocate_vcs
        vc_counts = allocate_vcs(
            at, routed.table,
            balance=True if cfg.balance is None else cfg.balance,
            stats=vc_stats, reserve_escape=cfg.reserve_escape)
    timings["vc_s"] = time.time() - t0

    deadlock_free = None
    if cfg.verify:
        from repro.core.vcalloc import verify_deadlock_free
        tbl = tables.table if tables is not None else routed.table
        deadlock_free = bool(verify_deadlock_free(at, tbl))
    return RoutedPod(topo, cfg, at, routed, tables=tables,
                     vc_counts=vc_counts, vc_stats=vc_stats,
                     deadlock_free=deadlock_free, timings=timings)
