"""First-order LP solver in JAX (PDHG / PDLP-lite) + HiGHS oracle.

Problem form:   min  c.x   s.t.  A x <= b,  lo <= x <= hi.

The paper solves its synthesis LPs with Gurobi's barrier method (sparse
factorizations, 256 GB machines, days at pod scale). Our TPU-native
adaptation is matrix-free PDHG over a COO operator: every iteration is two
segment-sums and two clips -- bandwidth-bound streaming ops that map onto
accelerators, with Ruiz equilibration, power-iteration step sizing and
averaging restarts for convergence quality. scipy's HiGHS is kept as an
exactness oracle for small instances (tests / Fig.1-scale runs).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)  # LP numerics need f64


@dataclasses.dataclass
class COOMatrix:
    rows: np.ndarray
    cols: np.ndarray
    vals: np.ndarray
    shape: Tuple[int, int]

    @staticmethod
    def from_triplets(rows, cols, vals, shape) -> "COOMatrix":
        return COOMatrix(np.asarray(rows, np.int32),
                         np.asarray(cols, np.int32),
                         np.asarray(vals, np.float64), shape)

    def to_scipy(self):
        import scipy.sparse as sp
        return sp.coo_matrix((self.vals, (self.rows, self.cols)),
                             shape=self.shape).tocsr()


@dataclasses.dataclass
class LPResult:
    x: np.ndarray
    y: Optional[np.ndarray]
    obj: float
    status: str
    iters: int = 0
    rel_gap: float = 0.0
    primal_infeas: float = 0.0


def solve_highs(c, A: COOMatrix, b, lo, hi,
                method: str = "highs", **options) -> LPResult:
    """HiGHS oracle. Extra ``options`` are forwarded to scipy's linprog
    (e.g. ``ipm_optimality_tolerance=1e-4`` -- the synthesis fixing loop
    only consumes the *ordering* of the fractional m values, so loose
    IPM tolerances buy large-instance wall-clock at no quality cost)."""
    from scipy.optimize import linprog
    res = linprog(c, A_ub=A.to_scipy(), b_ub=b,
                  bounds=np.stack([lo, hi], axis=1), method=method,
                  options=options or None)
    y = None
    if res.status == 0 and hasattr(res, "ineqlin"):
        y = -np.asarray(res.ineqlin.marginals)
    return LPResult(res.x if res.x is not None else np.zeros_like(c),
                    y, float(res.fun) if res.fun is not None else np.nan,
                    "optimal" if res.status == 0 else f"status{res.status}")


def _ruiz_scale(A: COOMatrix, iters: int = 10):
    m, n = A.shape
    dr = np.ones(m)
    dc = np.ones(n)
    vals = A.vals.copy()
    for _ in range(iters):
        rmax = np.zeros(m)
        np.maximum.at(rmax, A.rows, np.abs(vals))
        rmax[rmax == 0] = 1.0
        vals /= np.sqrt(rmax)[A.rows]
        dr /= np.sqrt(rmax)
        cmax = np.zeros(n)
        np.maximum.at(cmax, A.cols, np.abs(vals))
        cmax[cmax == 0] = 1.0
        vals /= np.sqrt(cmax)[A.cols]
        dc /= np.sqrt(cmax)
    return vals, dr, dc


@partial(jax.jit, static_argnames=("m", "n", "inner"))
def _pdhg_chunk(rows, cols, vals, c, b, lo, hi, x, y, tau, sigma, m, n,
                inner):
    def matvec(v):
        return jax.ops.segment_sum(vals * v[cols], rows, num_segments=m)

    def rmatvec(u):
        return jax.ops.segment_sum(vals * u[rows], cols, num_segments=n)

    def body(i, carry):
        x, y, xs, ys = carry
        g = c + rmatvec(y)
        x_new = jnp.clip(x - tau * g, lo, hi)
        r = matvec(2.0 * x_new - x) - b
        y_new = jnp.maximum(0.0, y + sigma * r)
        return x_new, y_new, xs + x_new, ys + y_new

    x, y, xs, ys = jax.lax.fori_loop(
        0, inner, body, (x, y, jnp.zeros_like(x), jnp.zeros_like(y)))
    return x, y, xs / inner, ys / inner


def _residuals(A_sp, c, b, lo, hi, x, y):
    ax = A_sp @ x
    pinf = np.linalg.norm(np.maximum(ax - b, 0.0)) / (1 + np.linalg.norm(b))
    pobj = float(c @ x)
    r = c + (A_sp.T @ y)
    dobj = float(-b @ y + np.sum(np.where(r > 0, lo * r, hi * r)))
    gap = abs(pobj - dobj) / (1 + abs(pobj) + abs(dobj))
    return pobj, dobj, gap, pinf


def solve_pdhg(c, A: COOMatrix, b, lo, hi, max_iters: int = 40000,
               tol: float = 1e-5, inner: int = 250,
               x0: Optional[np.ndarray] = None,
               y0: Optional[np.ndarray] = None,
               verbose: bool = False) -> LPResult:
    m, n = A.shape
    c = np.asarray(c, np.float64)
    b = np.asarray(b, np.float64)
    lo = np.asarray(lo, np.float64)
    hi = np.asarray(hi, np.float64)

    vals_s, dr, dc = _ruiz_scale(A)
    # scaled problem: x = Dc xs, rows scaled by Dr:
    cs = c * dc
    bs = b * dr
    los = lo / dc
    his = hi / dc

    A_sp = A.to_scipy()

    # spectral norm of the scaled operator (power iteration)
    import scipy.sparse as sp
    As = sp.coo_matrix((vals_s, (A.rows, A.cols)), shape=A.shape).tocsr()
    v = np.random.default_rng(0).normal(size=n)
    v /= np.linalg.norm(v)
    for _ in range(60):
        w = As.T @ (As @ v)
        nw = np.linalg.norm(w)
        if nw == 0:
            break
        v = w / nw
    norm = float(np.sqrt(max(v @ (As.T @ (As @ v)), 1e-12)))
    step = 0.9 / max(norm, 1e-9)
    tau = sigma = step

    rows_j = jnp.asarray(A.rows)
    cols_j = jnp.asarray(A.cols)
    vals_j = jnp.asarray(vals_s, jnp.float64)
    cj = jnp.asarray(cs)
    bj = jnp.asarray(bs)
    loj = jnp.asarray(los)
    hij = jnp.asarray(his)

    x = np.clip(x0 / dc, los, his) if x0 is not None \
        else np.clip(np.zeros(n), los, his)
    y = (y0 / dr) if y0 is not None else np.zeros(m)
    xj = jnp.asarray(x)
    yj = jnp.asarray(np.maximum(y, 0.0))

    best = None
    it = 0
    while it < max_iters:
        xj, yj, xavg, yavg = _pdhg_chunk(rows_j, cols_j, vals_j, cj, bj,
                                         loj, hij, xj, yj, tau, sigma,
                                         m, n, inner)
        it += inner
        # evaluate averaged and current iterates in the original space
        x_avg_u = np.asarray(xavg) * dc
        y_avg_u = np.asarray(yavg) * dr
        x_cur_u = np.asarray(xj) * dc
        y_cur_u = np.asarray(yj) * dr
        for xu, yu, tag in ((x_avg_u, y_avg_u, "avg"),
                            (x_cur_u, y_cur_u, "cur")):
            pobj, dobj, gap, pinf = _residuals(A_sp, c, b, lo, hi, xu, yu)
            if best is None or (gap + pinf) < (best[2] + best[3]):
                best = (xu, yu, gap, pinf, pobj, tag)
        if verbose:
            print(f"  pdhg it={it} gap={best[2]:.2e} pinf={best[3]:.2e} "
                  f"obj={best[4]:.6g} ({best[5]})")
        if best[2] < tol and best[3] < tol:
            break
        # restart from the best candidate (rescaled)
        xj = jnp.asarray(best[0] / dc)
        yj = jnp.asarray(best[1] / dr)

    xu, yu, gap, pinf, pobj, _ = best
    status = "optimal" if (gap < tol and pinf < tol) else "max_iters"
    return LPResult(xu, yu, pobj, status, iters=it, rel_gap=gap,
                    primal_infeas=pinf)


def solve(c, A: COOMatrix, b, lo, hi, prefer: str = "auto",
          **kw) -> LPResult:
    """auto: HiGHS for small instances, PDHG otherwise."""
    small = A.shape[0] * A.shape[1] < 5e9 and len(A.vals) < 3e6 \
        and A.shape[1] < 200000
    if prefer == "highs" or (prefer == "auto" and small):
        try:
            res = solve_highs(c, A, b, lo, hi)
            if res.status == "optimal":
                return res
        except Exception:
            pass
    return solve_pdhg(c, A, b, lo, hi, **kw)
