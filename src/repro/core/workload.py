"""Workload co-design: parallelization-derived demand -> specialized TONS.

The generic synthesis LP maximises a uniform all-to-all throughput
proxy; TopoOpt and ACOS (PAPERS.md) show the real win comes from
co-optimising the fabric with the *training job*. This module closes
that loop end to end:

1. :func:`collective_mix` -- an analytic per-collective wire-byte
   estimate straight from a :class:`~repro.configs.base.ModelConfig` +
   :class:`~repro.configs.base.ShapeConfig` (DP gradient all-reduce, TP
   activation all-gather/reduce-scatter, MoE token all-to-all), used
   whenever no measured dry-run JSON exists on disk;
2. :func:`workload_demand` -- dry-run measurements when available
   (:func:`repro.core.demand.from_dryrun`), the analytic mix otherwise,
   both through the same :func:`repro.core.demand.from_mix` mapping, so
   the two sources are interchangeable;
3. :func:`synthesize_for_workload` -- the demand's translation-invariant
   ``weight_fn`` becomes ``pair_weight`` for the symmetric synthesis LP:
   a fabric optimised for *this* job's traffic;
4. :func:`replay_trace` -- the workload's one-step collective sequence
   as a :class:`~repro.core.traffic.PhasedTraffic` (in-cube TP/EP
   all-to-all phase -> cross-cube DP-ring phase -> uniform background,
   durations proportional to wire bytes) for the simulator's
   trace-replay mode;
5. :func:`evaluate_workload` -- demand-weighted MCF + trace-replay
   saturation of any topology on a workload, routed through
   :func:`repro.core.pipeline.route_pod` (the headline
   specialized-vs-generic-vs-torus comparison in bench_workload / fig11);
6. :func:`workload_tenant` -- a sub-pod slice of a workload's demand as
   a :class:`~repro.core.traffic.TenantSpec` for multi-job composition.

MoE archs come out all-to-all-heavy (same-cube demand), dense archs
all-reduce-heavy (cross-cube DP rings) -- so their specialized fabrics
genuinely differ, which is the point.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.configs.registry import get_config, get_shape
from repro.core import demand
from repro.core.demand import WorkloadDemand, weighted_mcf
from repro.core.pipeline import PipelineConfig, route_pod
from repro.core.topology import Pod
from repro.core.traffic import PhasedTraffic, TenantSpec, TrafficPattern

_BF16 = 2.0     # bytes per element on the wire


def collective_mix(model: ModelConfig, shape: ShapeConfig
                   ) -> Dict[str, float]:
    """Analytic per-collective wire-byte estimate for one step.

    Deliberately coarse -- it only needs to get the *ratios* right for
    the demand-weight mapping (:func:`repro.core.demand.from_mix`
    normalises to relative levels):

    - TP activation collectives: one all-gather + one reduce-scatter of
      the token activations per layer's mixer/FFN pair;
    - MoE dispatch + combine: ``top_k``-way token all-to-all, twice per
      MoE layer;
    - DP gradient sync (train shapes only): ring all-reduce over the
      parameters, ~2x param bytes on the wire.

    Decode shapes process one new token per step, so token-proportional
    terms collapse while the (absent, in decode) gradient term stays 0
    -- the mix degrades gracefully to TP-dominated, which is what a
    decode step actually looks like.
    """
    steps_tokens = shape.global_batch * (
        shape.seq_len if shape.kind in ("train", "prefill") else 1)
    D = float(model.d_model)
    enc_dec = model.family == "encdec"
    layers = (model.enc_layers + model.dec_layers) if enc_dec \
        else model.n_layers
    n_moe = 0 if enc_dec else sum(
        1 for i in range(layers) if model.ffn_kind(i) == "moe")
    wires = {"all-to-all": 0.0, "all-reduce": 0.0,
             "all-gather": 0.0, "reduce-scatter": 0.0}
    act = steps_tokens * D * _BF16
    wires["all-gather"] += layers * act
    wires["reduce-scatter"] += layers * act
    if n_moe and model.top_k:
        # dispatch + combine, top_k expert copies per token
        wires["all-to-all"] += 2 * n_moe * act * model.top_k
    if shape.kind == "train":
        wires["all-reduce"] += 2 * model.param_count() * _BF16
    return wires


def workload_demand(podspec, arch: str, shape: str = "train_4k",
                    dryrun_dir: str = "benchmarks/results/dryrun",
                    mesh: str = "single_pod_16x16") -> WorkloadDemand:
    """Demand weights for a registered arch on a pod: measured dry-run
    collectives when the JSON exists, the analytic mix otherwise --
    identical mapping either way (:func:`repro.core.demand.from_mix`).
    """
    from pathlib import Path
    f = Path(dryrun_dir) / f"{arch}__{shape}__{mesh}.json"
    if f.exists():
        return demand.from_dryrun(podspec, arch, shape,
                                  dryrun_dir=dryrun_dir, mesh=mesh)
    model = get_config(arch).model
    return demand.from_mix(Pod(podspec),
                           collective_mix(model, get_shape(shape)))


def synthesize_for_workload(podspec, arch: str, shape: str = "train_4k",
                            wd: Optional[WorkloadDemand] = None,
                            **synth_kw):
    """Synthesize a fabric specialized for one workload's demand.

    The demand's ``weight_fn`` (translation-invariant by construction:
    same-cube membership + cube-offset rings) rides into the symmetric
    synthesis LP as ``pair_weight``, so the orbit reductions still
    apply and only the objective changes. Returns
    ``(SynthesisResult, WorkloadDemand)``; extra kwargs forward to
    :func:`repro.core.synthesis.synthesize`.
    """
    from repro.core.synthesis import synthesize
    if wd is None:
        wd = workload_demand(podspec, arch, shape)
    res = synthesize(podspec, symmetric=True, pair_weight=wd.weight_fn(),
                     **synth_kw)
    return res, wd


def replay_trace(wd: WorkloadDemand, period: int = 256,
                 min_cycles: int = 8) -> PhasedTraffic:
    """The workload's one-step collective sequence as a cyclic phased
    demand schedule for the simulator.

    Up to three phases -- in-cube TP/EP all-to-all, cross-cube DP ring,
    uniform background -- each phase's spatial pattern the
    corresponding single-component :class:`WorkloadDemand` matrix, so a
    trace replay stresses the fabric the way the training step does:
    bursts of concentrated collective traffic, not a stationary blend.

    Phase durations are proportional to per-node wire *volume* (demand
    level x partner count, i.e. the component's row mass), floored at
    ``min_cycles`` and summing to ~``period`` cycles: at a fixed
    per-node injection bandwidth, a phase moving k times the bytes
    occupies k times the cycles. (Weight *levels* alone would misprice
    broad components -- a uniform floor touching every pair moves far
    more volume per node than one ring partner at a higher level.)
    Keep ``min_cycles`` small relative to ``period``: it exists only to
    stop a phase degenerating to zero cycles, and a large floor hands
    low-volume phases schedule share their bytes don't justify.
    """
    pod = wd.pod
    comps: List[Tuple[str, WorkloadDemand]] = []
    if wd.w_same_cube > 0:
        comps.append(("a2a", WorkloadDemand(
            pod, w_same_cube=wd.w_same_cube, w_uniform=0.0)))
    if wd.w_ring > 0:
        comps.append(("ring", WorkloadDemand(
            pod, w_ring=wd.w_ring, w_uniform=0.0)))
    comps.append(("background", WorkloadDemand(
        pod, w_uniform=max(float(wd.w_uniform), 1e-6))))
    patterns = []
    masses = []
    for name, d in comps:
        m = d.matrix()
        patterns.append(TrafficPattern.from_matrix(name, m))
        masses.append(float(m.sum()) / pod.n)      # per-node volume
    total = sum(masses)
    cycles = [max(min_cycles, int(round(period * m / total)))
              for m in masses]
    return PhasedTraffic("trace", tuple(patterns), tuple(cycles))


def demand_pair_weight(wd: WorkloadDemand, cap: int = 64) -> np.ndarray:
    """Quantize a demand matrix into the integer multiplicities that
    :func:`repro.core.routing.select_paths` consumes as ``pair_weight``:
    the smallest positive weight maps to 1, heavier pairs to their
    (capped) integer ratio. Zero-weight pairs still route at weight 1
    (every pair keeps a path; only the balance objective changes).
    """
    m = wd.matrix()
    pos = m[m > 0]
    if pos.size == 0:
        return np.ones_like(m)
    return np.clip(np.rint(m / pos.min()), 1, cap)


def evaluate_workload(topo, wd: WorkloadDemand,
                      trace: Optional[PhasedTraffic] = None,
                      cfg: Optional[PipelineConfig] = None,
                      sat_kwargs: Optional[dict] = None,
                      weighted_routing: bool = True) -> dict:
    """Score one topology on one workload: demand-weighted MCF (exact
    LP) + trace-replay saturation (simulated), via the routing facade.

    ``weighted_routing`` (default) routes with the demand's integer
    pair multiplicities so path selection balances the *workload's*
    channel load, not the uniform proxy -- the co-design applies to
    routing as well as synthesis. It forces the array engine (the
    weighted one); pass ``weighted_routing=False`` to score with the
    demand-blind pipeline exactly as the other benchmarks run it.
    """
    from repro.core.netsim import saturation_point
    out: dict = {"name": topo.name, "n": topo.n}
    out["weighted_mcf"] = float(weighted_mcf(topo, wd))
    cfg = cfg or PipelineConfig()
    pw = None
    if weighted_routing:
        pw = demand_pair_weight(wd)
        if cfg.engine != "array":
            cfg = dataclasses.replace(cfg, engine="array")
    rp = route_pod(topo, cfg, pair_weight=pw)
    out["l_max"] = rp.l_max
    sat, _ = saturation_point(rp.tables,
                              traffic=trace or replay_trace(wd),
                              **(sat_kwargs or {}))
    out["trace_saturation"] = float(sat)
    return out


def workload_tenant(name: str, podspec, nodes: Sequence[int], arch: str,
                    shape: str = "train_4k",
                    rate_share: float = 1.0) -> TenantSpec:
    """One job's sub-pod slice as a tenant: the workload's full-pod
    demand matrix restricted to ``nodes`` (a job placed on a cube keeps
    its in-cube TP/EP weights; a job spanning cubes keeps its rings).
    Compose several with :func:`repro.core.traffic.compose_tenants`.
    """
    wd = workload_demand(podspec, arch, shape)
    nodes = np.asarray(nodes, np.int64)
    sub = wd.matrix()[np.ix_(nodes, nodes)]
    return TenantSpec(name, nodes, sub, rate_share)
