"""Packed-array path representation for the routing -> simulation pipeline.

``PathTable`` is the single path/VC representation produced by path
selection (`routing.select_paths`), DOR construction (`netsim.dor_paths`)
and VC allocation (`vcalloc.allocate_vcs`), and consumed directly by the
cycle-level simulator (`netsim.build_tables`). It packs every (src, dst)
channel sequence into dense arrays:

    path: (n, n, MAXHOP) int32   channel ids along the route, -1 padded
    vcs:  (n, n, MAXHOP) int8    per-hop virtual-channel assignment
    hops: (n, n)         int32   route length (0 = unrouted / self)

The arrays are built incrementally (no intermediate ``Dict[(s, d), tuple]``
structures on the hot path) and all aggregate statistics -- per-channel
loads, L_max, average hops -- are vectorised numpy reductions. Dict views
exist only as explicit API edges (:meth:`as_dicts` / :meth:`from_dicts`)
for interop and debugging.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

MAXHOP = 40


@dataclasses.dataclass
class PathTable:
    n: int                    # nodes
    n_ch: int                 # directed channels
    n_vc: int                 # virtual channels
    path: np.ndarray          # (n, n, MAXHOP) int32, -1 pad
    vcs: np.ndarray           # (n, n, MAXHOP) int8
    hops: np.ndarray          # (n, n) int32

    # ---- construction -----------------------------------------------------

    @staticmethod
    def empty(n: int, n_ch: int, n_vc: int = 2) -> "PathTable":
        return PathTable(
            n, n_ch, n_vc,
            path=np.full((n, n, MAXHOP), -1, np.int32),
            vcs=np.zeros((n, n, MAXHOP), np.int8),
            hops=np.zeros((n, n), np.int32))

    def copy(self) -> "PathTable":
        return PathTable(self.n, self.n_ch, self.n_vc, self.path.copy(),
                         self.vcs.copy(), self.hops.copy())

    def set_path(self, s: int, d: int, channels,
                 vcs: Optional[List[int]] = None) -> None:
        """Incremental single-pair fill (API edge / tests)."""
        L = min(len(channels), MAXHOP)
        self.path[s, d, :L] = channels[:L]
        self.hops[s, d] = L
        if vcs is not None:
            self.vcs[s, d, :L] = vcs[:L]

    def set_paths_batch(self, src: np.ndarray, dst: np.ndarray,
                        chan: np.ndarray, length: np.ndarray,
                        vcs: Optional[np.ndarray] = None) -> None:
        """Bulk fill: chan is (F, W) padded with -1 (or any negative);
        ``vcs`` (same shape) optionally sets per-hop VC assignments."""
        L = chan.shape[1]
        self.path[src, dst, :L] = np.where(chan < 0, -1, chan)
        self.hops[src, dst] = length
        if vcs is not None:
            live = np.arange(L)[None, :] < np.asarray(length)[:, None]
            self.vcs[src, dst, :L] = np.where(live, vcs, 0).astype(np.int8)

    # ---- vectorised statistics -------------------------------------------

    def routed_mask(self) -> np.ndarray:
        """(n, n) bool: pairs with a route (excludes self / unrouted)."""
        return self.hops > 0

    def n_routed(self) -> int:
        return int(self.routed_mask().sum())

    def loads(self) -> np.ndarray:
        """Per-channel load: number of routes crossing each channel."""
        used = self.path[self.path >= 0]
        return np.bincount(used, minlength=self.n_ch).astype(np.float64)

    def l_max(self) -> float:
        loads = self.loads()
        return float(loads.max()) if loads.size else 0.0

    def avg_hops(self) -> float:
        m = self.routed_mask()
        return float(self.hops[m].mean()) if m.any() else 0.0

    def vc_hop_counts(self) -> np.ndarray:
        """Hops assigned to each VC across all routes, (n_vc,)."""
        valid = self.path >= 0
        return np.bincount(self.vcs[valid].astype(np.int64),
                           minlength=self.n_vc)

    # ---- dict views (API edges only) -------------------------------------

    def as_dicts(self) -> Tuple[Dict[Tuple[int, int], Tuple[int, ...]],
                                Dict[Tuple[int, int], List[int]]]:
        """Materialise ``{(s, d): channel tuple}`` / ``{(s, d): vc list}``.

        O(n^2) python -- strictly an interop/debugging edge, never called
        on the routing -> simulation hot path.
        """
        paths: Dict[Tuple[int, int], Tuple[int, ...]] = {}
        vcs: Dict[Tuple[int, int], List[int]] = {}
        ss, dd = np.nonzero(self.routed_mask())
        for s, d in zip(ss.tolist(), dd.tolist()):
            L = int(self.hops[s, d])
            paths[(s, d)] = tuple(int(c) for c in self.path[s, d, :L])
            vcs[(s, d)] = [int(v) for v in self.vcs[s, d, :L]]
        return paths, vcs

    @staticmethod
    def from_dicts(n: int, n_ch: int,
                   paths: Dict[Tuple[int, int], Tuple[int, ...]],
                   vcs: Optional[Dict[Tuple[int, int], List[int]]] = None,
                   n_vc: int = 2) -> "PathTable":
        """Interop edge for legacy dict-of-tuples producers."""
        t = PathTable.empty(n, n_ch, n_vc)
        for (s, d), p in paths.items():
            t.set_path(s, d, list(p), None if vcs is None else vcs[(s, d)])
        return t
