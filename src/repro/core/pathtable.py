"""Packed-array path representations for the routing -> simulation pipeline.

``PathTable`` is the dense path/VC representation produced by path
selection (`routing.select_paths`), DOR construction (`netsim.dor_paths`)
and VC allocation (`vcalloc.allocate_vcs`), and consumed directly by the
cycle-level simulator (`netsim.build_tables`). It packs every (src, dst)
channel sequence into dense arrays:

    path: (n, n, MAXHOP) int32   channel ids along the route, -1 padded
    vcs:  (n, n, MAXHOP) int8    per-hop virtual-channel assignment
    hops: (n, n)         int32   route length (0 = unrouted / self)

The arrays are built incrementally (no intermediate ``Dict[(s, d), tuple]``
structures on the hot path) and all aggregate statistics -- per-channel
loads, L_max, average hops -- are vectorised numpy reductions. Dict views
exist only as explicit API edges (:meth:`as_dicts` / :meth:`from_dicts`)
for interop and debugging.

``CSRPathTable`` is the packed sparse variant for large pods: the dense
layout allocates ``n * n * MAXHOP`` slots no matter how long routes
actually are (2.7 GB of channel ids alone at 16^3), while the CSR form
stores one entry per real hop -- per-source flow offsets, per-flow hop
offsets, and concatenated channel / VC arrays. It is what the streaming
per-source-shard selection engine emits, exposes the same statistics API,
and round-trips losslessly through :meth:`CSRPathTable.to_dense` /
:meth:`CSRPathTable.from_dense` (``build_tables`` accepts either form and
densifies lazily only when a simulator kernel actually needs the dense
gather tables).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Tuple

import numpy as np

MAXHOP = 40


@dataclasses.dataclass
class PathTable:
    n: int                    # nodes
    n_ch: int                 # directed channels
    n_vc: int                 # virtual channels
    path: np.ndarray          # (n, n, MAXHOP) int32, -1 pad
    vcs: np.ndarray           # (n, n, MAXHOP) int8
    hops: np.ndarray          # (n, n) int32

    # ---- construction -----------------------------------------------------

    @staticmethod
    def empty(n: int, n_ch: int, n_vc: int = 2) -> "PathTable":
        return PathTable(
            n, n_ch, n_vc,
            path=np.full((n, n, MAXHOP), -1, np.int32),
            vcs=np.zeros((n, n, MAXHOP), np.int8),
            hops=np.zeros((n, n), np.int32))

    def copy(self) -> "PathTable":
        return PathTable(self.n, self.n_ch, self.n_vc, self.path.copy(),
                         self.vcs.copy(), self.hops.copy())

    def set_path(self, s: int, d: int, channels,
                 vcs: Optional[List[int]] = None) -> None:
        """Incremental single-pair fill (API edge / tests)."""
        L = min(len(channels), MAXHOP)
        self.path[s, d, :L] = channels[:L]
        self.hops[s, d] = L
        if vcs is not None:
            self.vcs[s, d, :L] = vcs[:L]

    def set_paths_batch(self, src: np.ndarray, dst: np.ndarray,
                        chan: np.ndarray, length: np.ndarray,
                        vcs: Optional[np.ndarray] = None) -> None:
        """Bulk fill: chan is (F, W) padded with -1 (or any negative);
        ``vcs`` (same shape) optionally sets per-hop VC assignments."""
        L = chan.shape[1]
        self.path[src, dst, :L] = np.where(chan < 0, -1, chan)
        self.hops[src, dst] = length
        if vcs is not None:
            live = np.arange(L)[None, :] < np.asarray(length)[:, None]
            self.vcs[src, dst, :L] = np.where(live, vcs, 0).astype(np.int8)

    # ---- vectorised statistics -------------------------------------------

    def routed_mask(self) -> np.ndarray:
        """(n, n) bool: pairs with a route (excludes self / unrouted)."""
        return self.hops > 0

    def n_routed(self) -> int:
        return int(self.routed_mask().sum())

    def nbytes(self) -> int:
        """Bytes held by the dense ``(n, n, MAXHOP)`` route arrays --
        the quantity the CSR layout's O(total routed hops) replaces."""
        return int(self.path.nbytes + self.vcs.nbytes + self.hops.nbytes)

    def loads(self) -> np.ndarray:
        """Per-channel load: number of routes crossing each channel."""
        used = self.path[self.path >= 0]
        return np.bincount(used, minlength=self.n_ch).astype(np.float64)

    def l_max(self) -> float:
        loads = self.loads()
        return float(loads.max()) if loads.size else 0.0

    def avg_hops(self) -> float:
        m = self.routed_mask()
        return float(self.hops[m].mean()) if m.any() else 0.0

    def vc_hop_counts(self) -> np.ndarray:
        """Hops assigned to each VC across all routes, (n_vc,)."""
        valid = self.path >= 0
        return np.bincount(self.vcs[valid].astype(np.int64),
                           minlength=self.n_vc)

    # ---- dict views (API edges only) -------------------------------------

    def as_dicts(self) -> Tuple[Dict[Tuple[int, int], Tuple[int, ...]],
                                Dict[Tuple[int, int], List[int]]]:
        """Materialise ``{(s, d): channel tuple}`` / ``{(s, d): vc list}``.

        O(n^2) python -- strictly an interop/debugging edge, never called
        on the routing -> simulation hot path.

        .. deprecated:: PR 10
           Dict views are confined to API edges; internal consumers read
           the packed arrays directly.
        """
        warnings.warn(
            "PathTable.as_dicts() is an interop/debugging edge and is "
            "deprecated for internal use; read the packed arrays "
            "(path/hops/vcs or the CSR fields) instead.",
            DeprecationWarning, stacklevel=2)
        paths: Dict[Tuple[int, int], Tuple[int, ...]] = {}
        vcs: Dict[Tuple[int, int], List[int]] = {}
        ss, dd = np.nonzero(self.routed_mask())
        for s, d in zip(ss.tolist(), dd.tolist()):
            L = int(self.hops[s, d])
            paths[(s, d)] = tuple(int(c) for c in self.path[s, d, :L])
            vcs[(s, d)] = [int(v) for v in self.vcs[s, d, :L]]
        return paths, vcs

    @staticmethod
    def from_dicts(n: int, n_ch: int,
                   paths: Dict[Tuple[int, int], Tuple[int, ...]],
                   vcs: Optional[Dict[Tuple[int, int], List[int]]] = None,
                   n_vc: int = 2) -> "PathTable":
        """Interop edge for legacy dict-of-tuples producers."""
        t = PathTable.empty(n, n_ch, n_vc)
        for (s, d), p in paths.items():
            t.set_path(s, d, list(p), None if vcs is None else vcs[(s, d)])
        return t


# ---------------------------------------------------------------------------
# Packed CSR variant: per-source flow offsets + concatenated hop arrays
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CSRPathTable:
    """Sparse path/VC table: memory scales with total routed hops, not
    ``n^2 * MAXHOP``.

    Flows are stored in row-major ``(src, dst)`` order:

        src_indptr: (n + 1,)  int64   flow range of each source
        dst:        (F,)      int32   destination of each flow
        hop_indptr: (F + 1,)  int64   hop range of each flow
        chan:       (H,)      int32   concatenated channel ids
        vc:         (H,)      int8    concatenated per-hop VCs

    ``H`` is the total hop count over all routed flows. Unrouted pairs
    simply have no flow entry (self-pairs never do).
    """
    n: int
    n_ch: int
    n_vc: int
    src_indptr: np.ndarray
    dst: np.ndarray
    hop_indptr: np.ndarray
    chan: np.ndarray
    vc: np.ndarray

    # ---- construction -----------------------------------------------------

    def copy(self) -> "CSRPathTable":
        return CSRPathTable(self.n, self.n_ch, self.n_vc,
                            self.src_indptr.copy(), self.dst.copy(),
                            self.hop_indptr.copy(), self.chan.copy(),
                            self.vc.copy())

    @property
    def n_flows(self) -> int:
        return len(self.dst)

    @property
    def flow_src(self) -> np.ndarray:
        """(F,) source of each flow, expanded from the CSR offsets."""
        return np.repeat(np.arange(self.n, dtype=np.int32),
                         np.diff(self.src_indptr))

    @property
    def flow_len(self) -> np.ndarray:
        """(F,) hop count of each flow."""
        return np.diff(self.hop_indptr).astype(np.int32)

    @property
    def hops(self) -> np.ndarray:
        """Dense ``(n, n)`` hop-count matrix (API-edge parity with the
        dense table; materialised per access -- don't call in loops)."""
        h = np.zeros((self.n, self.n), np.int32)
        h[self.flow_src, self.dst] = self.flow_len
        return h

    @staticmethod
    def from_dense(t: PathTable) -> "CSRPathTable":
        """Pack a dense table; exact inverse of :meth:`to_dense`."""
        ss, dd = np.nonzero(t.hops > 0)             # row-major == sorted
        lens = t.hops[ss, dd].astype(np.int64)
        hop_indptr = np.zeros(len(ss) + 1, np.int64)
        np.cumsum(lens, out=hop_indptr[1:])
        W = int(lens.max()) if len(lens) else 1
        live = np.arange(W)[None, :] < lens[:, None]
        return CSRPathTable(
            t.n, t.n_ch, t.n_vc,
            src_indptr=np.searchsorted(ss, np.arange(t.n + 1)
                                       ).astype(np.int64),
            dst=dd.astype(np.int32),
            hop_indptr=hop_indptr,
            chan=t.path[ss, dd, :W][live].astype(np.int32),
            vc=t.vcs[ss, dd, :W][live].astype(np.int8))

    def to_dense(self) -> PathTable:
        """Materialise the dense ``(n, n, MAXHOP)`` form (simulator
        kernels gather from it; large pods should stay CSR until then)."""
        t = PathTable.empty(self.n, self.n_ch, self.n_vc)
        lens = self.flow_len.astype(np.int64)
        if not len(lens):
            return t
        ss = self.flow_src.astype(np.int64)
        dd = self.dst.astype(np.int64)
        pos = np.arange(len(self.chan)) - np.repeat(self.hop_indptr[:-1],
                                                    lens)
        fs, fd = np.repeat(ss, lens), np.repeat(dd, lens)
        t.path[fs, fd, pos] = self.chan
        t.vcs[fs, fd, pos] = self.vc
        t.hops[ss, dd] = lens
        return t

    # ---- block access (vcalloc / verification hot path) -------------------

    def block_paths(self, lo: int, hi: int) -> Tuple[np.ndarray, np.ndarray,
                                                     np.ndarray]:
        """Flows ``lo:hi`` as padded arrays: ``(chan (B, W), vc (B, W),
        lens (B,))``; ``chan`` padded with -1."""
        lens = np.diff(self.hop_indptr[lo:hi + 1]).astype(np.int64)
        B = hi - lo
        W = int(lens.max()) if B and lens.size else 1
        P = np.full((B, W), -1, np.int64)
        V = np.zeros((B, W), np.int8)
        pos = np.arange(W)[None, :]
        live = pos < lens[:, None]
        idx = self.hop_indptr[lo:hi, None] + pos
        P[live] = self.chan[idx[live]]
        V[live] = self.vc[idx[live]]
        return P, V, lens

    def set_block_vcs(self, lo: int, hi: int, V: np.ndarray,
                      lens: np.ndarray) -> None:
        """Write padded per-hop VCs ``V (B, W)`` back for flows
        ``lo:hi``."""
        W = V.shape[1]
        pos = np.arange(W)[None, :]
        live = pos < lens[:, None]
        idx = self.hop_indptr[lo:hi, None] + pos
        self.vc[idx[live]] = V[live].astype(np.int8)

    def gather_paths(self, flows: np.ndarray) -> Tuple[np.ndarray,
                                                       np.ndarray,
                                                       np.ndarray]:
        """Arbitrary flow subset as padded arrays -- the scatter/gather
        twin of :meth:`block_paths` for non-contiguous flow pools (the
        fault-repair re-route touches exactly the flows crossing dead
        channels, which are spread across every source). Returns
        ``(chan (B, W), vc (B, W), lens (B,))``, ``chan`` -1-padded."""
        flows = np.asarray(flows, np.int64)
        lens = (self.hop_indptr[flows + 1]
                - self.hop_indptr[flows]).astype(np.int64)
        B = len(flows)
        W = int(lens.max()) if B and lens.size else 1
        P = np.full((B, W), -1, np.int64)
        V = np.zeros((B, W), np.int8)
        pos = np.arange(W)[None, :]
        live = pos < lens[:, None]
        idx = self.hop_indptr[flows, None] + pos
        P[live] = self.chan[idx[live]]
        V[live] = self.vc[idx[live]]
        return P, V, lens

    def set_flow_vcs(self, flows: np.ndarray, V: np.ndarray,
                     lens: np.ndarray) -> None:
        """Write padded per-hop VCs ``V (B, W)`` back for an arbitrary
        flow subset (twin of :meth:`set_block_vcs`)."""
        flows = np.asarray(flows, np.int64)
        W = V.shape[1]
        pos = np.arange(W)[None, :]
        live = pos < lens[:, None]
        idx = self.hop_indptr[flows, None] + pos
        self.vc[idx[live]] = V[live].astype(np.int8)

    def compact(self) -> Tuple["CSRPathTable", np.ndarray]:
        """Drop zero-length (lost) flows; returns ``(table, kept)`` with
        ``kept`` mapping new flow ids back to old ones.

        Degraded-mode serving (:func:`repro.core.repair.repair_fault`
        with ``on_disconnect="degrade"``) keeps disconnected pairs as
        zero-length flow slots so flow ids stay stable across
        fault/restore events. The simulator samples traffic over flow
        slots and cannot inject a packet with no route, so throughput
        probes of a degraded fabric run on the compacted table."""
        lens = self.flow_len.astype(np.int64)
        kept = np.nonzero(lens > 0)[0]
        if len(kept) == len(lens):
            return self.copy(), kept
        src = self.flow_src.astype(np.int64)[kept]
        src_indptr = np.searchsorted(src,
                                     np.arange(self.n + 1)).astype(np.int64)
        hop_indptr = np.zeros(len(kept) + 1, np.int64)
        np.cumsum(lens[kept], out=hop_indptr[1:])
        # zero-length flows contribute no hops, so the concatenated
        # chan/vc arrays are already exactly the compacted ones
        return CSRPathTable(self.n, self.n_ch, self.n_vc, src_indptr,
                            self.dst[kept].copy(), hop_indptr,
                            self.chan.copy(), self.vc.copy()), kept

    # ---- vectorised statistics (PathTable API parity) ---------------------

    def routed_mask(self) -> np.ndarray:
        m = np.zeros((self.n, self.n), bool)
        live = self.flow_len > 0
        m[self.flow_src[live], self.dst[live]] = True
        return m

    def n_routed(self) -> int:
        """Flows with an actual route -- zero-length (lost) flow slots
        kept by degraded-mode serving don't count as routed."""
        return int((self.flow_len > 0).sum())

    def nbytes(self) -> int:
        """Bytes held by the packed CSR arrays (O(total routed hops))."""
        return int(self.src_indptr.nbytes + self.dst.nbytes
                   + self.hop_indptr.nbytes + self.chan.nbytes
                   + self.vc.nbytes)

    def loads(self) -> np.ndarray:
        return np.bincount(self.chan,
                           minlength=self.n_ch).astype(np.float64)

    def l_max(self) -> float:
        loads = self.loads()
        return float(loads.max()) if loads.size else 0.0

    def avg_hops(self) -> float:
        lens = self.flow_len
        lens = lens[lens > 0]
        return float(lens.mean()) if len(lens) else 0.0

    def vc_hop_counts(self) -> np.ndarray:
        return np.bincount(self.vc.astype(np.int64), minlength=self.n_vc)

    def escape_flows(self) -> np.ndarray:
        """Flow ids an escape-reserving VC allocation marked all-VC0
        (:func:`repro.core.vcalloc.allocate_vcs` with
        ``reserve_escape=True`` assigns VCs >= 1 everywhere else), i.e.
        the flows the adaptive kernel escape-routes from injection.
        Only meaningful on such tables -- on a normal allocation this
        simply returns the flows that happen to ride VC0 end to end."""
        lens = self.flow_len.astype(np.int64)
        nz = np.nonzero(lens > 0)[0]
        if not len(nz):
            return nz
        vmax = np.maximum.reduceat(self.vc.astype(np.int64),
                                   self.hop_indptr[nz])
        return nz[vmax == 0]

    # ---- dict views (API edges only) --------------------------------------

    def as_dicts(self) -> Tuple[Dict[Tuple[int, int], Tuple[int, ...]],
                                Dict[Tuple[int, int], List[int]]]:
        """.. deprecated:: PR 10 -- see :meth:`PathTable.as_dicts`."""
        warnings.warn(
            "CSRPathTable.as_dicts() is an interop/debugging edge and is "
            "deprecated for internal use; read the CSR arrays "
            "(hop_indptr/chan/vc/dst) instead.",
            DeprecationWarning, stacklevel=2)
        paths: Dict[Tuple[int, int], Tuple[int, ...]] = {}
        vcs: Dict[Tuple[int, int], List[int]] = {}
        src = self.flow_src
        for f in range(self.n_flows):
            lo, hi = int(self.hop_indptr[f]), int(self.hop_indptr[f + 1])
            key = (int(src[f]), int(self.dst[f]))
            paths[key] = tuple(int(c) for c in self.chan[lo:hi])
            vcs[key] = [int(v) for v in self.vc[lo:hi]]
        return paths, vcs
