"""VC allocation along chosen paths (paper Section 5.4).

Each selected channel-path gets a per-hop VC assignment found over the
allowed-turn CDG. The naive policy biases VC 0; TONS's online load
balancer marks the VC with the lowest accumulated hop count as "priority"
before each path and tries it first at every hop.

Assignment is an exact-lookahead DP, vectorised over flow blocks: every
consecutive channel pair resolves to a *turn id* with one batched
``searchsorted`` against the sorted base-turn keys, giving a direct-index
``(T, n_vc, n_vc)`` VC-compatibility table; a backward sweep marks which
VCs at each hop still admit a complete suffix, and the forward sweep then
takes the first priority-ordered VC that is both edge-compatible and
suffix-viable. That is bit-for-bit the assignment the reference per-flow
DFS (:func:`_assign_path`) finds -- depth-first in priority order, first
complete solution -- but with no per-flow python fallback at all. The old
vectorised first-fit dead-ended on ~45% of flows at 8^3 and fell back to
that DFS per flow, which dominated allocation wall-clock; the counter
``greedy_dead_ends`` in the optional ``stats`` dict records how many
flows would have taken that path, seeding the simulated greedy's hop 0
with the unconditional priority VC exactly as the old code did (the
lookahead resolves them all in the same vectorised pass).

Both path-table layouts are accepted: the dense ``(n, n, MAXHOP)``
:class:`~repro.core.pathtable.PathTable` and the packed
:class:`~repro.core.pathtable.CSRPathTable` emitted by the streaming
sharded selection engine (blocks stream through
:meth:`~repro.core.pathtable.CSRPathTable.block_paths` /
:meth:`~repro.core.pathtable.CSRPathTable.set_block_vcs`). Assignments
are written in place; per-VC hop counts come back as a vector.
Dict-based inputs are not accepted -- convert at the edge with
:meth:`PathTable.from_dicts`.

The :class:`~repro.core.routing.ATResult` consumed here is engine-
agnostic: the batched admission engine and the serial reference produce
the identical allowed set, and the ``StateGraph`` they compile to is
canonical, so allocations are bit-identical either way.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple, Union

import numpy as np

from repro.core.pathtable import CSRPathTable, PathTable
from repro.core.routing import (ATResult, Channels, _dead_channel_array,
                                _tree_turns_array)


# ---------------------------------------------------------------------------
# Escape sub-network: VC 0 over a spanning-tree turn set
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EscapeRoutes:
    """The always-safe escape sub-network for adaptive routing.

    A BFS spanning tree over the *surviving* channels, its non-reversing
    turn set (acyclic -- tree turns cannot close a cycle, the same
    argument that seeds the allowed-turn admission), and the per-node
    next-hop table the simulator kernel consumes: ``esc_next[u, d]`` is
    the channel leaving ``u`` toward ``d`` along the unique tree path
    (``-1`` on the diagonal and for unreachable pairs). A packet riding
    VC 0 follows ``esc_next`` hop by hop and never leaves the tree, so
    the escape channel-dependency graph is acyclic regardless of what
    the adaptive VCs are doing -- Duato's condition for deadlock-free
    adaptive routing with a connected escape layer.
    """
    n: int
    tree_channels: np.ndarray   # (2(n-1),) both directions of tree edges
    esc_next: np.ndarray        # (n, n) int32 next channel toward d, -1 pad
    turns: np.ndarray           # (K, 2) (cin, cout) tree-turn set
    connected: bool             # tree spans every surviving node pair


def escape_routes(topo, dead_channels=None, root: int = 0) -> EscapeRoutes:
    """Build the escape tree + next-hop table over surviving channels.

    Dead channels are excluded before the BFS, so after a fault the
    caller rebuilds this on the survivors and gets a valid post-fault
    escape layer (the netsim kernel stacks the pre/post tables and
    switches at the fault cycle).
    """
    import scipy.sparse as sp
    import scipy.sparse.csgraph as csg
    ch = Channels.from_topology(topo)
    n = ch.n_nodes
    dc = _dead_channel_array(dead_channels)
    alive = np.ones(ch.n, bool)
    if dc is not None:
        if (dc < 0).any() or (dc >= ch.n).any():
            bad = dc[(dc < 0) | (dc >= ch.n)]
            raise ValueError(f"unknown channel ids {bad.tolist()} "
                             f"(topology has {ch.n} channels)")
        alive[dc] = False
    a = sp.csr_matrix((np.ones(int(alive.sum()), np.float32),
                       (ch.src[alive], ch.dst[alive])), shape=(n, n))
    # BFS tree from `root`, then all-pairs next hops along the tree:
    # pred[d, u] is u's predecessor on the path d -> u, i.e. the next
    # node from u toward d (tree paths are unique and undirected)
    tree = csg.breadth_first_tree(a, root, directed=False)
    tr, tc = tree.nonzero()
    und = sp.csr_matrix((np.ones(len(tr), np.float32), (tr, tc)),
                        shape=(n, n))
    und = und + und.T
    dist, pred = csg.shortest_path(und, unweighted=True,
                                   return_predecessors=True)
    nxt = pred.T                                 # (u, d) -> next node
    chan_of = np.full((n, n), -1, np.int32)
    chan_of[ch.src[alive], ch.dst[alive]] = \
        np.arange(ch.n, dtype=np.int32)[alive]
    uu = np.repeat(np.arange(n), n)
    vv = np.clip(nxt.ravel(), 0, n - 1)
    esc_next = np.where(nxt.ravel() >= 0, chan_of[uu, vv], -1) \
        .astype(np.int32).reshape(n, n)
    np.fill_diagonal(esc_next, -1)
    # both directions of every tree edge, as channel ids
    fwd = chan_of[tr, tc]
    bwd = chan_of[tc, tr]
    tree_ch = np.concatenate([fwd, bwd])
    tree_ch = np.sort(tree_ch[tree_ch >= 0]).astype(np.int64)
    turns = _tree_turns_array(tree_ch.tolist(), ch)
    connected = bool((dist[root] != np.inf).all()) and len(tr) == n - 1
    return EscapeRoutes(n, tree_ch, esc_next, turns, connected)


def _assign_path(at: ATResult, path, priority: int) -> Optional[List[int]]:
    """DFS over VC choices along a fixed channel sequence; tries the
    priority VC first at every hop. Reference oracle for the vectorised
    lookahead assignment (both return the depth-first-first solution)."""
    n_vc = at.n_vc
    order = [priority] + [v for v in range(n_vc) if v != priority]

    def rec(i: int, v_prev: int) -> Optional[List[int]]:
        if i == len(path):
            return []
        for v in order:
            if i == 0 or at.is_allowed(path[i - 1], v_prev, path[i], v):
                rest = rec(i + 1, v)
                if rest is not None:
                    return [v] + rest
        return None

    return rec(0, -1)


def _turn_vc_table(at: ATResult) -> Tuple[np.ndarray, np.ndarray]:
    """Sorted base-turn keys ``c_in * C + c_out`` plus the per-turn VC
    compatibility table ``vcmat (T + 2, n_vc, n_vc)``.

    Row ``T`` is the all-True pad (positions beyond a flow's length),
    row ``T + 1`` the all-False row for channel pairs that admit no VC
    combination at all. Built once per ATResult and cached.
    """
    cached = getattr(at, "_vcmat_cache", None)
    if cached is not None:
        return cached
    sg = at.state_graph()
    S, n_vc = sg.n_states, sg.n_vc
    a, b = sg.keys // S, sg.keys % S
    C = S // n_vc
    tk = (a // n_vc) * C + (b // n_vc)
    turn_keys = np.unique(tk)
    T = len(turn_keys)
    vcmat = np.zeros((T + 2, n_vc, n_vc), bool)
    vcmat[np.searchsorted(turn_keys, tk), a % n_vc, b % n_vc] = True
    vcmat[T] = True
    at._vcmat_cache = (turn_keys, vcmat)
    return turn_keys, vcmat


def _lookahead_vcs(at: ATResult, P: np.ndarray, lens: np.ndarray,
                   vorder: List[int], stats: Optional[dict] = None,
                   forbid_vc0: bool = False) -> np.ndarray:
    """Exact-lookahead per-hop VC assignment for a block of paths.

    ``P (B, W)`` are channel sequences (< 0 pad), ``lens`` the true hop
    counts. Returns ``V (B, W)`` (garbage beyond each flow's length);
    raises if some flow admits no valid assignment at all.

    ``forbid_vc0`` reserves VC 0 as the adaptive-routing escape lane:
    assignments are restricted to VCs >= 1, and a flow with no viable
    all-adaptive assignment falls back to an all-VC0 marking instead of
    raising (counted in ``stats['escape_fallback_flows']``) -- the
    adaptive kernel treats a VC0 occupant as escape-routed from hop 0,
    which is always deliverable over the escape tree.
    """
    turn_keys, vcmat = _turn_vc_table(at)
    n_vc = at.n_vc
    B, W = P.shape
    C = at.channels.n
    T = len(turn_keys)
    rows = np.arange(B)
    tid = np.full((B, max(W - 1, 1)), T, np.int64)
    if W > 1:
        pairpos = np.arange(W - 1)[None, :] < (lens - 1)[:, None]
        q = P[:, :-1].astype(np.int64) * C + P[:, 1:]
        ti = np.clip(np.searchsorted(turn_keys, np.clip(q, 0, None)),
                     0, max(T - 1, 0))
        found = (turn_keys[ti] == q) if T else np.zeros_like(pairpos)
        tid[pairpos & found] = ti[pairpos & found]
        tid[pairpos & ~found] = T + 1          # no VC combo admits this
    # one bulk compatibility gather for the whole block, then a backward
    # sweep: can the suffix from hop h on VC v still complete?
    M = vcmat[tid].astype(np.uint8)            # (B, W-1, n_vc, n_vc)
    backs = np.ones((B, W, n_vc), np.uint8)
    if forbid_vc0:
        backs[:, :, 0] = 0                     # VC0 is the escape lane
    for h in range(W - 2, -1, -1):
        np.einsum("bij,bj->bi", M[:, h], backs[:, h + 1],
                  out=backs[:, h])
        np.minimum(backs[:, h], 1, out=backs[:, h])
        if forbid_vc0:
            # keep VC0 out of the viability recursion too: a suffix that
            # completes only through VC0 must not count as viable
            backs[:, h, 0] = 0
    # forward sweep: first priority-ordered VC that is edge-compatible
    # with the previous hop and suffix-viable; track alongside what the
    # lookahead-free greedy would have done (its dead-ends are the flows
    # the old implementation sent to the per-flow DFS fallback)
    V = np.zeros((B, W), np.int64)
    choice = np.full(B, -1, np.int64)
    for v in vorder:
        pick = (choice < 0) & (backs[:, 0, v] > 0)
        choice[pick] = v
    ok = choice >= 0
    V[:, 0] = np.where(ok, choice, 0)
    # the old first-fit put the priority VC on hop 0 unconditionally;
    # seed the simulated greedy the same way so the dead-end counter
    # reports what that implementation would actually have hit
    naive = np.full(B, vorder[0], np.int64)
    ndead = ~ok
    for h in range(1, W):
        live = lens > h
        m = M[:, h - 1]
        allowed_next = m[rows, V[:, h - 1]]    # (B, n_vc)
        choice = np.full(B, -1, np.int64)
        nallowed = m[rows, naive]
        nchoice = np.full(B, -1, np.int64)
        for v in vorder:
            pick = (choice < 0) & (allowed_next[:, v] > 0) \
                & (backs[:, h, v] > 0)
            choice[pick] = v
            npick = (nchoice < 0) & (nallowed[:, v] > 0)
            nchoice[npick] = v
        ok &= ~live | (choice >= 0)
        V[:, h] = np.where(live & (choice >= 0), choice, 0)
        ndead |= live & (nchoice < 0)
        naive = np.where(live & (nchoice >= 0), nchoice, naive)
    if not ok.all():
        if forbid_vc0:
            # no all-adaptive assignment exists: mark the whole flow as
            # escape-routed (VC0 from hop 0) -- always deliverable over
            # the escape tree, never deadlocks, just not adaptive
            V[~ok] = 0
            if stats is not None:
                stats["escape_fallback_flows"] = \
                    stats.get("escape_fallback_flows", 0) \
                    + int((~ok).sum())
        else:
            f = int(np.nonzero(~ok)[0][0])
            raise RuntimeError(f"path {P[f, :lens[f]].tolist()} has no "
                               f"valid VC assignment")
    if stats is not None:
        stats["greedy_dead_ends"] = stats.get("greedy_dead_ends", 0) \
            + int((ndead & (lens > 0)).sum())
    return V


def allocate_vcs(at: ATResult, table: Union[PathTable, CSRPathTable],
                 balance: bool = True, block: Optional[int] = None,
                 stats: Optional[dict] = None,
                 reserve_escape: bool = False) -> np.ndarray:
    """Fill the table's VC hops in place for every routed pair; returns
    the hops-per-VC counts ``(n_vc,)``.

    Flows are processed in blocks (row-major ``(s, d)`` order, as
    before); the priority VC is re-derived from the accumulated counts
    between blocks, so balancing tracks the reference policy at block
    granularity while every per-hop choice is one vectorised
    compatibility gather with exact lookahead (identical output to the
    old first-fit + per-flow DFS fallback, with the fallback frequency
    surfaced in ``stats['greedy_dead_ends']`` instead of paid for).

    ``reserve_escape`` keeps VC 0 free for the adaptive simulator's
    escape lane: every assignment uses VCs >= 1 only, and flows with no
    all-adaptive assignment are marked all-VC0 (escape-routed from
    injection; see :func:`_lookahead_vcs`). Requires ``n_vc >= 2``.
    """
    n_vc = at.n_vc
    if reserve_escape and n_vc < 2:
        raise ValueError("reserve_escape needs n_vc >= 2 (VC 0 is the "
                         "escape lane)")
    counts = np.zeros(n_vc, dtype=np.int64)
    csr = isinstance(table, CSRPathTable)
    if csr:
        F = table.n_flows
    else:
        ss, dd = np.nonzero(table.hops > 0)  # row-major == sorted (s, d)
        F = len(ss)
    if F == 0:
        return counts
    if block is None:
        block = max(64, F // 64) if balance else F
    for i in range(0, F, block):
        hi = min(i + block, F)
        if csr:
            P, _, lens = table.block_paths(i, hi)
        else:
            sb, db = ss[i:hi], dd[i:hi]
            lens = table.hops[sb, db].astype(np.int64)
            P = table.path[sb, db, :int(lens.max())].astype(np.int64)
        if reserve_escape:
            pr = 1 + int(np.argmin(counts[1:])) if balance else 1
            vorder = [pr] + [v for v in range(1, n_vc) if v != pr]
        else:
            pr = int(np.argmin(counts)) if balance else 0
            vorder = [pr] + [v for v in range(n_vc) if v != pr]
        V = _lookahead_vcs(at, P, lens, vorder, stats=stats,
                           forbid_vc0=reserve_escape)
        live = np.arange(P.shape[1])[None, :] < lens[:, None]
        if csr:
            table.set_block_vcs(i, hi, V, lens)
        else:
            table.vcs[sb, db, :P.shape[1]] = \
                np.where(live, V, 0).astype(np.int8)
        counts += np.bincount(V[live], minlength=n_vc)
    return counts


def reallocate_vcs(at: ATResult, table: CSRPathTable, flows: np.ndarray,
                   counts: np.ndarray, block: Optional[int] = None,
                   stats: Optional[dict] = None) -> np.ndarray:
    """Streamed VC re-allocation for an arbitrary flow subset.

    The fault-repair pipeline re-routes only the flows whose paths
    crossed dead channels; their old VC hops are stale (new channel
    sequences) while every untouched flow's assignment remains valid
    against the pruned allowed set (pruning only removes turns, never
    changes surviving ones). This re-runs the exact-lookahead assignment
    over just those ``flows`` -- the caller must already have subtracted
    their old hops from ``counts`` (the live hops-per-VC vector) so the
    balanced priority derivation sees the true background. ``counts`` is
    updated in place and returned.
    """
    flows = np.asarray(flows, np.int64)
    # zero-length (lost) flow slots have no hops to assign; tolerate
    # them so degraded-mode callers can pass a raw pool
    flows = flows[(table.hop_indptr[flows + 1]
                   - table.hop_indptr[flows]) > 0]
    n_vc = at.n_vc
    F = len(flows)
    if F == 0:
        return counts
    if block is None:
        block = max(64, F // 64)
    for i in range(0, F, block):
        sub = flows[i:min(i + block, F)]
        P, _, lens = table.gather_paths(sub)
        pr = int(np.argmin(counts))
        vorder = [pr] + [v for v in range(n_vc) if v != pr]
        V = _lookahead_vcs(at, P, lens, vorder, stats=stats)
        live = np.arange(P.shape[1])[None, :] < lens[:, None]
        table.set_flow_vcs(sub, V, lens)
        counts += np.bincount(V[live], minlength=n_vc)
    return counts


def verify_flows_deadlock_free(at: ATResult, table: CSRPathTable,
                               flows: np.ndarray) -> bool:
    """Deadlock-freedom check restricted to ``flows``: every consecutive
    (channel, vc) hop must be an allowed turn. The repair/restore paths
    use it pool-scoped -- untouched flows need no re-check because their
    paths cross no dead channel, so every turn they use survives pruning
    verbatim. Zero-length (lost) flows contribute no hop pairs and pass
    vacuously."""
    sg = at.state_graph()
    P, V, lens = table.gather_paths(flows)
    if P.shape[1] < 2:
        return True
    s = P * at.n_vc + V
    m = np.arange(P.shape[1] - 1)[None, :] < (lens - 1)[:, None]
    return bool(sg.has_edges(s[:, :-1][m], s[:, 1:][m]).all())


def verify_deadlock_free(at: ATResult,
                         table: Union[PathTable, CSRPathTable]) -> bool:
    """Invariant check: every consecutive (channel, vc) hop of every routed
    flow is an allowed turn => the union of dependencies is a subgraph of
    the acyclic allowed-turn CDG => deadlock-free. One batched membership
    test over every hop pair of every flow."""
    sg = at.state_graph()
    n_vc = at.n_vc
    if isinstance(table, CSRPathTable):
        s = table.chan.astype(np.int64) * n_vc + table.vc
        if len(s) < 2:
            return True
        # consecutive positions within one flow: drop the pairs that
        # straddle a flow boundary
        m = np.ones(len(s) - 1, bool)
        starts = table.hop_indptr[1:-1]
        m[starts - 1] = False
        return bool(sg.has_edges(s[:-1][m], s[1:][m]).all())
    from repro.core.pathtable import MAXHOP
    ss, dd = np.nonzero(table.hops > 1)
    if len(ss) == 0:
        return True
    P = table.path[ss, dd].astype(np.int64)
    V = table.vcs[ss, dd].astype(np.int64)
    pair_ok = (np.arange(MAXHOP - 1)[None, :]
               < table.hops[ss, dd][:, None] - 1)
    a = (P[:, :-1] * n_vc + V[:, :-1])[pair_ok]
    b = (P[:, 1:] * n_vc + V[:, 1:])[pair_ok]
    return bool(sg.has_edges(a, b).all())
