"""VC allocation along chosen paths (paper Section 5.4).

Each selected channel-path gets a per-hop VC assignment found by search
over the allowed-turn CDG. The naive policy biases VC 0; TONS's online
load balancer marks the VC with the lowest accumulated hop count as
"priority" before each path and tries it first at every hop.

Assignments are written directly into the packed ``PathTable.vcs`` array
(the same structure the simulator consumes); per-VC hop counts come back
as a vector. Dict-based inputs are not accepted -- convert at the edge
with :meth:`PathTable.from_dicts` if needed.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core.pathtable import PathTable
from repro.core.routing import ATResult


def _assign_path(at: ATResult, path, priority: int) -> Optional[List[int]]:
    """DFS over VC choices along a fixed channel sequence; tries the
    priority VC first at every hop."""
    n_vc = at.n_vc
    order = [priority] + [v for v in range(n_vc) if v != priority]

    def rec(i: int, v_prev: int) -> Optional[List[int]]:
        if i == len(path):
            return []
        for v in order:
            if i == 0 or at.is_allowed(path[i - 1], v_prev, path[i], v):
                rest = rec(i + 1, v)
                if rest is not None:
                    return [v] + rest
        return None

    return rec(0, -1)


def allocate_vcs(at: ATResult, table: PathTable,
                 balance: bool = True) -> np.ndarray:
    """Fill ``table.vcs`` in place for every routed pair; returns the
    hops-per-VC counts ``(n_vc,)``."""
    counts = np.zeros(at.n_vc, dtype=np.int64)
    ss, dd = np.nonzero(table.hops > 0)      # row-major == sorted (s, d)
    for s, d in zip(ss.tolist(), dd.tolist()):
        L = int(table.hops[s, d])
        path = [int(c) for c in table.path[s, d, :L]]
        pr = int(np.argmin(counts)) if balance else 0
        vcs = _assign_path(at, path, pr)
        if vcs is None:  # should not happen: paths came from the state BFS
            vcs = _assign_path(at, path, 0)
        if vcs is None:
            raise RuntimeError(f"path {(s, d)} has no valid VC assignment")
        table.vcs[s, d, :L] = vcs
        counts += np.bincount(vcs, minlength=at.n_vc)
    return counts


def verify_deadlock_free(at: ATResult, table: PathTable) -> bool:
    """Invariant check: every consecutive (channel, vc) hop of every routed
    flow is an allowed turn => the union of dependencies is a subgraph of
    the acyclic allowed-turn CDG => deadlock-free."""
    ss, dd = np.nonzero(table.hops > 1)
    for s, d in zip(ss.tolist(), dd.tolist()):
        L = int(table.hops[s, d])
        p = table.path[s, d, :L]
        v = table.vcs[s, d, :L]
        for i in range(1, L):
            if not at.is_allowed(int(p[i - 1]), int(v[i - 1]),
                                 int(p[i]), int(v[i])):
                return False
    return True
