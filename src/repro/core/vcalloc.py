"""VC allocation along chosen paths (paper Section 5.4).

Each selected channel-path gets a per-hop VC assignment found over the
allowed-turn CDG. The naive policy biases VC 0; TONS's online load
balancer marks the VC with the lowest accumulated hop count as "priority"
before each path and tries it first at every hop.

Assignment is vectorised over flow blocks: every hop of a whole block is
resolved with batched membership tests against the sorted edge keys of the
:class:`~repro.core.routing.StateGraph` (first-fit in priority order, the
same per-hop rule as the reference DFS); the rare flow whose greedy prefix
dead-ends falls back to the per-flow DFS. Assignments are written directly
into the packed ``PathTable.vcs`` array (the structure the simulator
consumes); per-VC hop counts come back as a vector. Dict-based inputs are
not accepted -- convert at the edge with :meth:`PathTable.from_dicts`.

The :class:`~repro.core.routing.ATResult` consumed here is engine-
agnostic: the batched admission engine and the serial reference produce
the identical allowed set, and the ``StateGraph`` they compile to is
canonical, so allocations are bit-identical either way.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core.pathtable import MAXHOP, PathTable
from repro.core.routing import ATResult


def _assign_path(at: ATResult, path, priority: int) -> Optional[List[int]]:
    """DFS over VC choices along a fixed channel sequence; tries the
    priority VC first at every hop. Reference / fallback for the
    vectorised block assignment."""
    n_vc = at.n_vc
    order = [priority] + [v for v in range(n_vc) if v != priority]

    def rec(i: int, v_prev: int) -> Optional[List[int]]:
        if i == len(path):
            return []
        for v in order:
            if i == 0 or at.is_allowed(path[i - 1], v_prev, path[i], v):
                rest = rec(i + 1, v)
                if rest is not None:
                    return [v] + rest
        return None

    return rec(0, -1)


def allocate_vcs(at: ATResult, table: PathTable, balance: bool = True,
                 block: Optional[int] = None) -> np.ndarray:
    """Fill ``table.vcs`` in place for every routed pair; returns the
    hops-per-VC counts ``(n_vc,)``.

    Flows are processed in blocks (row-major ``(s, d)`` order, as before);
    the priority VC is re-derived from the accumulated counts between
    blocks, so balancing tracks the reference policy at block granularity
    while every per-hop choice is one vectorised edge-membership test.
    """
    sg = at.state_graph()
    n_vc = at.n_vc
    counts = np.zeros(n_vc, dtype=np.int64)
    ss, dd = np.nonzero(table.hops > 0)      # row-major == sorted (s, d)
    F = len(ss)
    if F == 0:
        return counts
    if block is None:
        block = max(64, F // 64) if balance else F
    for i in range(0, F, block):
        sb, db = ss[i:i + block], dd[i:i + block]
        B = len(sb)
        lens = table.hops[sb, db].astype(np.int64)
        Lmax = int(lens.max())
        P = table.path[sb, db, :Lmax].astype(np.int64)
        pr = int(np.argmin(counts)) if balance else 0
        vorder = [pr] + [v for v in range(n_vc) if v != pr]
        V = np.full((B, Lmax), -1, np.int64)
        V[:, 0] = pr                       # hop 0 is unconstrained
        okflow = np.ones(B, bool)
        for h in range(1, Lmax):
            live = okflow & (lens > h)
            if not live.any():
                break
            prev_state = P[:, h - 1] * n_vc + V[:, h - 1]
            hop_base = P[:, h] * n_vc
            assigned = np.zeros(B, bool)
            for v in vorder:
                need = live & ~assigned
                if not need.any():
                    break
                ok = need & sg.has_edges(prev_state, hop_base + v)
                V[ok, h] = v
                assigned |= ok
            okflow &= assigned | ~live
        for fi in np.nonzero(~okflow)[0]:  # greedy dead-end: full DFS
            path = [int(c) for c in P[fi, :lens[fi]]]
            vcs = _assign_path(at, path, pr)
            if vcs is None:
                vcs = _assign_path(at, path, 0)
            if vcs is None:
                raise RuntimeError(f"path {(int(sb[fi]), int(db[fi]))} has "
                                   f"no valid VC assignment")
            V[fi, :lens[fi]] = vcs
        live = np.arange(Lmax)[None, :] < lens[:, None]
        table.vcs[sb, db, :Lmax] = np.where(live, V, 0).astype(np.int8)
        counts += np.bincount(V[live], minlength=n_vc)
    return counts


def verify_deadlock_free(at: ATResult, table: PathTable) -> bool:
    """Invariant check: every consecutive (channel, vc) hop of every routed
    flow is an allowed turn => the union of dependencies is a subgraph of
    the acyclic allowed-turn CDG => deadlock-free. One batched membership
    test over every hop pair of every flow."""
    sg = at.state_graph()
    n_vc = at.n_vc
    ss, dd = np.nonzero(table.hops > 1)
    if len(ss) == 0:
        return True
    P = table.path[ss, dd].astype(np.int64)
    V = table.vcs[ss, dd].astype(np.int64)
    pair_ok = (np.arange(MAXHOP - 1)[None, :]
               < table.hops[ss, dd][:, None] - 1)
    a = (P[:, :-1] * n_vc + V[:, :-1])[pair_ok]
    b = (P[:, 1:] * n_vc + V[:, 1:])[pair_ok]
    return bool(sg.has_edges(a, b).all())
