"""VC allocation along chosen paths (paper Section 5.4).

Each selected channel-path gets a per-hop VC assignment found by search
over the allowed-turn CDG. The naive policy biases VC 0; TONS's online
load balancer marks the VC with the lowest accumulated hop count as
"priority" before each path and tries it first at every hop.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.routing import ATResult


def _assign_path(at: ATResult, path: Tuple[int, ...], priority: int
                 ) -> Optional[List[int]]:
    """DFS over VC choices along a fixed channel sequence; tries the
    priority VC first at every hop."""
    n_vc = at.n_vc
    order = [priority] + [v for v in range(n_vc) if v != priority]

    def rec(i: int, v_prev: int) -> Optional[List[int]]:
        if i == len(path):
            return []
        for v in order:
            if i == 0 or at.is_allowed(path[i - 1], v_prev, path[i], v):
                rest = rec(i + 1, v)
                if rest is not None:
                    return [v] + rest
        return None

    return rec(0, -1)


def allocate_vcs(at: ATResult,
                 paths: Dict[Tuple[int, int], Tuple[int, ...]],
                 balance: bool = True
                 ) -> Tuple[Dict[Tuple[int, int], List[int]], np.ndarray]:
    """Returns per-pair VC sequences and hops-per-VC counts."""
    counts = np.zeros(at.n_vc, dtype=np.int64)
    out: Dict[Tuple[int, int], List[int]] = {}
    for sd in sorted(paths.keys()):
        pr = int(np.argmin(counts)) if balance else 0
        vcs = _assign_path(at, paths[sd], pr)
        if vcs is None:  # should not happen: paths came from the state BFS
            vcs = _assign_path(at, paths[sd], 0)
        if vcs is None:
            raise RuntimeError(f"path {sd} has no valid VC assignment")
        out[sd] = vcs
        for v in vcs:
            counts[v] += 1
    return out, counts


def verify_deadlock_free(at: ATResult,
                         paths: Dict[Tuple[int, int], Tuple[int, ...]],
                         vcs: Dict[Tuple[int, int], List[int]]) -> bool:
    """Invariant check: every consecutive (channel, vc) hop of every routed
    flow is an allowed turn => the union of dependencies is a subgraph of
    the acyclic allowed-turn CDG => deadlock-free."""
    for sd, p in paths.items():
        v = vcs[sd]
        for i in range(1, len(p)):
            if not at.is_allowed(p[i - 1], v[i - 1], p[i], v[i]):
                return False
    return True
