"""Deadlock-free routing: allowed turns (AT) on the VC-labeled CDG,
candidate-path enumeration, and min-max-channel-load path selection.

Paper Section 5 / Algorithms 1-2. Deadlock freedom is decoupled from route
selection: a greedy allowed-turn construction keeps the channel dependency
graph acyclic (incremental cycle detection); all shortest deadlock-free
paths are enumerated per pair; a min-max load optimisation then picks one
static path per (src, dst). Turn prioritisation: APL / CPL / Random.

Array layout of the routing engine (PR 2)
-----------------------------------------

The hot path is a packed-array pipeline over *states* ``s = c * n_vc + v``
(channel ``c`` on virtual channel ``v``; ``S = C * n_vc`` states total):

- :class:`StateGraph` compiles ``ATResult.allowed`` once into (a) a CSR
  adjacency used for frontier expansion, (b) a ``(S, D)`` padded reverse
  adjacency (``D`` = max in-degree) for parent walks, and (c) a sorted
  ``a * S + b`` edge-key array for O(log E) membership tests (VC alloc,
  deadlock verification).
- :func:`state_bfs` runs a level-synchronous BFS batched over a block of
  sources: the frontier is a dense ``(B, S)`` boolean, each level is one
  sparse-matrix product with the transposed CSR, and distances land in a
  ``(B, S)`` int16 array (-1 = unreached, seeds at distance 1).
- :func:`enumerate_candidates` turns distances into the packed
  ``(F, K, L)`` candidate tensor (``L`` = longest shortest path, SEN-padded
  channels + per-hop VCs) with a vectorised backward walk over the parent
  DAG: all ``F * K`` walkers step one BFS level per iteration, and each
  walker's mixed-radix "k-code" picks which parent to take so distinct
  codes enumerate distinct shortest paths.
- :func:`select_paths` evaluates the lexicographic ``(l_max, l_sum)`` cost
  of whole flow blocks at once (one gather of channel loads per block) for
  the greedy pass, then runs block-parallel local search with exact
  own-load removal. The per-flow python loops of the seed implementation
  are kept verbatim as ``engine="reference"`` -- the equivalence oracle.

Everything downstream (VC allocation, ``netsim.build_tables``) consumes the
same packed :class:`~repro.core.pathtable.PathTable`; an 8^3 pod (512
chips, ~3k channels) routes end-to-end in seconds.

Batched allowed-turns admission (PR 3)
--------------------------------------

Algorithm 1 admits VC-labeled turns one at a time under an incremental
acyclicity check; the seed ran a python Pearce-Kelly insertion per
attempt, which made ``allowed_turns`` the front-end bottleneck past a few
hundred nodes. :class:`_BatchedDAG` replays the same serial greedy in
blocks and produces the *identical* allowed set:

- attempts consistent with a maintained topological numbering
  (``level``) are accepted wholesale -- a batch of forward edges can
  never create a cycle;
- the backward minority goes through one batched BFS over the accepted
  CSR (level-window pruned): already-reachable heads are definite
  rejections (sticky across both VC passes -- reachability only grows),
  the rest are contested;
- one SCC pass over accepted + candidates splits the contested set into
  independent *tangles* (an edge can conflict only with candidates in
  its own strongly connected component); everything untangled commits
  in bulk, and each tangle is replayed through its interaction graph
  (head-reaches-tail bitsets, built by one scatter-OR sweep over the
  component's level bands) with an incremental transitive closure --
  the exact dead-end fallback, still array-backed;
- levels are repaired by a local gap-spaced relaxation confined to the
  raised region.

``at_engine="reference"`` keeps the seed loop as the equivalence oracle
(the produced sets match bit for bit; ``tests/test_at_engine.py``).
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from collections import defaultdict, deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.pathtable import MAXHOP, CSRPathTable, PathTable
from repro.core.topology import Topology


# ---------------------------------------------------------------------------
# Channels
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Channels:
    """Directed channels of an undirected topology.

    Besides the flat ``src``/``dst``/``color`` arrays, carries an
    out-adjacency CSR (``out_indptr``/``out_chan``) and the opposite
    direction of every channel (``rev``), so per-node queries are O(deg)
    slices instead of O(C) boolean scans.
    """
    src: np.ndarray           # (C,)
    dst: np.ndarray           # (C,)
    color: np.ndarray         # OCS color or -1 (electrical)
    index: Dict[Tuple[int, int], int]
    out_indptr: np.ndarray    # (n_nodes + 1,) CSR offsets into out_chan
    out_chan: np.ndarray      # (C,) channel ids grouped by source node
    rev: np.ndarray           # (C,) channel id of the reverse direction

    @staticmethod
    def from_topology(topo: Topology) -> "Channels":
        """Build (or fetch) the channel arrays of ``topo``.

        The result is cached on the topology object (topologies are
        immutable after construction): ``allowed_turns``, the simulator
        table builders and the collectives all start from the same
        ``Channels``, and fault sweeps used to rebuild it from scratch on
        every re-route.
        """
        cached = topo.__dict__.get("_channels")
        if cached is not None:
            return cached
        e = topo.edges()
        col = topo.edge_colors()
        src = np.concatenate([e[:, 0], e[:, 1]]).astype(np.int32)
        dst = np.concatenate([e[:, 1], e[:, 0]]).astype(np.int32)
        color = np.concatenate([col, col]).astype(np.int32)
        index = {(int(s), int(d)): i for i, (s, d) in
                 enumerate(zip(src, dst))}
        order = np.argsort(src, kind="stable").astype(np.int32)
        out_indptr = np.searchsorted(src[order],
                                     np.arange(topo.n + 1)).astype(np.int64)
        E = len(e)
        rev = np.concatenate([np.arange(E, 2 * E), np.arange(E)]) \
            .astype(np.int32)
        out = Channels(src, dst, color, index, out_indptr, order, rev)
        topo.__dict__["_channels"] = out
        return out

    @property
    def n(self) -> int:
        return len(self.src)

    @property
    def n_nodes(self) -> int:
        return len(self.out_indptr) - 1

    def out_of(self, node: int) -> np.ndarray:
        """Channels leaving ``node`` -- an O(deg) CSR slice."""
        return self.out_chan[self.out_indptr[node]:self.out_indptr[node + 1]]


# ---------------------------------------------------------------------------
# Incremental cycle detection (Pearce-Kelly) on the VC-labeled CDG
# ---------------------------------------------------------------------------


class IncrementalDAG:
    """Maintains a topological order under edge insertions; insertions that
    would create a cycle are rejected."""

    def __init__(self, n_nodes: int):
        self.n = n_nodes
        self.order = np.arange(n_nodes, dtype=np.int64)
        self.pos = np.arange(n_nodes, dtype=np.int64)
        self.adj: List[List[int]] = [[] for _ in range(n_nodes)]
        self.radj: List[List[int]] = [[] for _ in range(n_nodes)]

    def try_add(self, u: int, v: int) -> bool:
        if u == v:
            return False
        lb, ub = self.pos[v], self.pos[u]
        if lb > ub:                 # already consistent
            self.adj[u].append(v)
            self.radj[v].append(u)
            return True
        # discover affected region
        visited_f: List[int] = []
        seen_f = {v}
        stack = [v]
        ok = True
        while stack:
            x = stack.pop()
            visited_f.append(x)
            for y in self.adj[x]:
                if y == u:
                    ok = False
                    stack = []
                    break
                if self.pos[y] <= ub and y not in seen_f:
                    seen_f.add(y)
                    stack.append(y)
        if not ok:
            return False
        visited_b: List[int] = []
        seen_b = {u}
        stack = [u]
        while stack:
            x = stack.pop()
            visited_b.append(x)
            for y in self.radj[x]:
                if self.pos[y] >= lb and y not in seen_b:
                    seen_b.add(y)
                    stack.append(y)
        # reorder: backward region then forward region into the merged slots
        region = sorted(visited_b, key=lambda x: self.pos[x]) + \
            sorted(visited_f, key=lambda x: self.pos[x])
        slots = np.sort(self.pos[np.array(region)])
        for node, slot in zip(region, slots):
            self.pos[node] = slot
            self.order[slot] = node
        self.adj[u].append(v)
        self.radj[v].append(u)
        return True


# ---------------------------------------------------------------------------
# State graph: packed CSR over (channel, vc) states
# ---------------------------------------------------------------------------


def _state(c: int, v: int, n_vc: int) -> int:
    return c * n_vc + v


@dataclasses.dataclass
class StateGraph:
    """CSR forms of the allowed-turn DAG over ``c * n_vc + v`` states,
    compiled once per :class:`ATResult` and shared by the batched BFS,
    candidate enumeration and vectorised VC allocation."""
    n_states: int
    n_vc: int
    keys: np.ndarray          # (E,) sorted a * n_states + b edge keys
    fwd_T: object             # scipy CSR of the transposed adjacency
    rev_pad: np.ndarray       # (S, D) int32 parents of each state, -1 pad
    dst_node: np.ndarray      # (S,) arrival node of each state's channel
    node_order: np.ndarray    # (S,) state ids sorted by dst_node
    node_starts: np.ndarray   # (n_nodes + 1,) segment offsets in node_order

    def has_edges(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        """Vectorised membership test for state edges a -> b."""
        q = a.astype(np.int64) * self.n_states + b.astype(np.int64)
        if len(self.keys) == 0:
            return np.zeros(q.shape, bool)
        i = np.clip(np.searchsorted(self.keys, q), 0, len(self.keys) - 1)
        return self.keys[i] == q


def _build_state_graph(at: "ATResult") -> StateGraph:
    import scipy.sparse as sp
    ch = at.channels
    n_vc = at.n_vc
    S = ch.n * n_vc
    if at._edges is not None:
        a, b = at._edges[:, 0].astype(np.int64), at._edges[:, 1].astype(
            np.int64)
    elif at.allowed:
        ab = np.array([(ci * n_vc + v0, co * n_vc + v1)
                       for ((ci, v0), (co, v1)) in at.allowed], np.int64)
        a, b = ab[:, 0], ab[:, 1]
    else:
        a = b = np.zeros(0, np.int64)
    # canonical edge order: the padded reverse adjacency below decides
    # which parents the candidate walkers see first, so both admission
    # engines (any insertion order) must compile to the same StateGraph
    canon = np.argsort(a * S + b, kind="stable")
    a, b = a[canon], b[canon]
    keys = a * S + b
    adj = sp.csr_matrix((np.ones(len(a), np.float32), (a, b)), shape=(S, S))
    fwd_T = adj.T.tocsr()
    order = np.argsort(b, kind="stable")
    bs, as_ = b[order], a[order]
    deg = np.bincount(bs, minlength=S)
    D = max(int(deg.max()) if len(a) else 0, 1)
    rev_pad = np.full((S, D), -1, np.int32)
    starts = np.searchsorted(bs, np.arange(S))
    rev_pad[bs, np.arange(len(bs)) - starts[bs]] = as_
    dst_node = ch.dst[np.arange(S) // n_vc].astype(np.int64)
    node_order = np.argsort(dst_node, kind="stable")
    node_starts = np.searchsorted(dst_node[node_order],
                                  np.arange(ch.n_nodes + 1))
    return StateGraph(S, n_vc, keys, fwd_T, rev_pad, dst_node,
                      node_order, node_starts)


# ---------------------------------------------------------------------------
# Allowed-turn construction (Algorithms 1 & 2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ATResult:
    channels: Channels
    n_vc: int
    allowed: set                       # ((c_in, v0), (c_out, v1))
    trees: List[List[int]]             # robust spanning trees (channel lists)
    stats: Optional[dict] = None       # admission-engine counters
    _sg: Optional[StateGraph] = dataclasses.field(
        default=None, repr=False, compare=False)
    _edges: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False, compare=False)   # (E, 2) state edges
    _by_in: Optional[Dict] = dataclasses.field(
        default=None, repr=False, compare=False)
    _admission: Optional[Dict] = dataclasses.field(
        default=None, repr=False, compare=False)
    # ^ batched-engine admission snapshot (final topological levels, the
    #   (T, n_vo) accepted grid, base turns, VC-order pairs, priority
    #   permutation, per-state slot capacities, cumulative dead-turn
    #   mask). The fault-repair pipeline (repro.core.repair) patches it
    #   in place of replaying the full turn admission.

    def is_allowed(self, cin, v0, cout, v1) -> bool:
        return ((cin, v0), (cout, v1)) in self.allowed

    @property
    def allowed_by_in(self) -> Dict[Tuple[int, int], List[Tuple[int, int]]]:
        """Out-turns per (channel, vc) state, built lazily (the reference
        enumerator is the only consumer; the hot path uses
        :meth:`state_graph`). Canonically sorted so both admission engines
        drive the python oracle identically."""
        if self._by_in is None:
            by_in: Dict[Tuple[int, int], List[Tuple[int, int]]] = \
                defaultdict(list)
            for (a, b) in sorted(self.allowed):
                by_in[a].append(b)
            self._by_in = dict(by_in)
        return self._by_in

    def state_graph(self) -> StateGraph:
        """Packed CSR of ``allowed`` (built once, then cached)."""
        if self._sg is None:
            self._sg = _build_state_graph(self)
        return self._sg


def spanning_tree_channels(topo: Topology, ch: Channels, root: int,
                           forbidden_colors: Optional[set] = None,
                           rng=None) -> Tuple[List[int], set]:
    """BFS tree; returns both directions of each tree edge + used colors."""
    n = topo.n
    seen = np.zeros(n, bool)
    seen[root] = True
    q = deque([root])
    chans: List[int] = []
    used_colors: set = set()
    forbidden = forbidden_colors or set()
    while q:
        u = q.popleft()
        outs = ch.out_of(u)
        if rng is not None:
            outs = outs.copy()
            rng.shuffle(outs)
        for c in outs:
            v = int(ch.dst[c])
            if seen[v]:
                continue
            col = int(ch.color[c])
            if col >= 0 and col in forbidden:
                continue
            seen[v] = True
            if col >= 0:
                used_colors.add(col)
            chans.append(int(c))
            chans.append(int(ch.rev[c]))
            q.append(v)
    if not seen.all():
        return [], used_colors
    return chans, used_colors


def ocs_disjoint_spanning_trees(topo: Topology, ch: Channels
                                ) -> Optional[Tuple[List[int], List[int]]]:
    """Two spanning trees using disjoint OCS color sets (electrical edges
    may be shared -- they cannot fault). Concurrent BFS from hop-distance
    antipodes (paper 5.2)."""
    from repro.core.topology import bfs_all_pairs
    d = bfs_all_pairs(topo, sources=np.array([0]))[0]
    far = int(np.argmax(d))
    t0, colors0 = spanning_tree_channels(topo, ch, 0)
    if not t0:
        return None
    t1, colors1 = spanning_tree_channels(topo, ch, far,
                                         forbidden_colors=colors0)
    if not t1:
        # retry with a few random tie-breaks
        rng = np.random.default_rng(0)
        for _ in range(8):
            t0, colors0 = spanning_tree_channels(topo, ch, 0, rng=rng)
            t1, colors1 = spanning_tree_channels(
                topo, ch, far, forbidden_colors=colors0, rng=rng)
            if t1:
                break
    if not t1:
        return None
    return t0, t1


def _tree_turns_array(chans, ch: Channels) -> np.ndarray:
    """All non-reversing turns among a tree's channels, as a ``(K, 2)``
    ``(cin, cout)`` array (the set is acyclic together).

    Vectorised ragged cross-product, order-identical to the seed's dict
    loops (mid nodes by first occurrence as a destination in ``chans``,
    in/out channels in ``chans`` order) -- the emitted order feeds the
    admission sequence, which must match ``at_engine="reference"``.
    """
    A = np.asarray(chans, np.int64)
    if len(A) == 0:
        return np.zeros((0, 2), np.int32)
    dstA = ch.dst[A].astype(np.int64)
    srcA = ch.src[A].astype(np.int64)
    # mid nodes ranked by first occurrence as a dst
    du, di = np.unique(dstA, return_index=True)
    mids = du[np.argsort(di, kind="stable")]
    rank = np.full(ch.n_nodes, -1, np.int64)
    rank[mids] = np.arange(len(mids))
    ins = A[np.argsort(rank[dstA], kind="stable")]        # grouped by mid
    icnt = np.bincount(rank[dstA], minlength=len(mids)).astype(np.int64)
    omask = rank[srcA] >= 0
    osel = A[omask]
    og = rank[srcA[omask]]
    outs = osel[np.argsort(og, kind="stable")]
    ocnt = np.bincount(og, minlength=len(mids)).astype(np.int64)
    # per group g: icnt[g] * ocnt[g] (cin-major) pairs
    cin = np.repeat(ins, np.repeat(ocnt, icnt))
    tot = icnt * ocnt
    if int(tot.sum()) == 0:
        return np.zeros((0, 2), np.int32)
    ostart = np.cumsum(ocnt) - ocnt
    gstart = np.cumsum(tot) - tot
    within = np.arange(int(tot.sum())) - np.repeat(gstart, tot)
    cout = outs[np.repeat(ostart, tot) + within % np.repeat(ocnt, tot)]
    keep = ch.dst[cout] != ch.src[cin]                    # no u-turn
    return np.stack([cin[keep], cout[keep]], axis=1).astype(np.int32)


def _tree_turns(chans: List[int], ch: Channels) -> List[Tuple[int, int]]:
    """List-of-tuples view of :func:`_tree_turns_array` (API edge)."""
    return list(map(tuple, _tree_turns_array(chans, ch).tolist()))


def base_turns_array(ch: Channels) -> np.ndarray:
    """All non-reversing ``(cin, cout)`` turns as a ``(T, 2)`` array.

    One ragged gather over the out-adjacency CSR: for every channel
    ``cin`` the out-channels of its arrival node, minus u-turns. Order is
    ``cin``-major with ``cout`` ascending -- identical to the seed's dict
    loop, so turn-priority permutations line up exactly.
    """
    mid = ch.dst.astype(np.int64)                         # (C,)
    deg = (ch.out_indptr[mid + 1] - ch.out_indptr[mid]).astype(np.int64)
    total = int(deg.sum())
    cin = np.repeat(np.arange(ch.n, dtype=np.int64), deg)
    within = np.arange(total) - np.repeat(np.cumsum(deg) - deg, deg)
    cout = ch.out_chan[ch.out_indptr[mid[cin]] + within].astype(np.int64)
    keep = ch.dst[cout] != ch.src[cin]
    return np.stack([cin[keep], cout[keep]], axis=1).astype(np.int32)


def base_turns(ch: Channels) -> List[Tuple[int, int]]:
    """List-of-tuples view of :func:`base_turns_array` (API edge)."""
    return list(map(tuple, base_turns_array(ch).tolist()))


def _apl_turn_frequencies(t: np.ndarray, topo: Topology,
                          ch: Channels) -> np.ndarray:
    """APL frequency of each turn in ``t`` ((T, 2) int) over the
    all-shortest-path sets.

    Batched over the BFS level structure: per-source path multiplicities
    come from level-masked sparse matrix products, and each turn's
    frequency is one masked reduction over all sources at once (the
    seed's per-source parent/grandparent triple loop was O(n deg^2)
    python and dominated ``allowed_turns`` beyond ~200 nodes).
    """
    import scipy.sparse as sp
    from repro.core.topology import bfs_all_pairs
    n = topo.n
    d = bfs_all_pairs(topo)                       # (n, n) float, inf = cut
    finite = np.isfinite(d)
    maxd = int(d[finite].max()) if finite.any() else 0
    d32 = np.where(finite, d, -2.0).astype(np.float32)
    adj_T = sp.csr_matrix((np.ones(ch.n, np.float32),
                           (ch.dst.astype(np.int64),
                            ch.src.astype(np.int64))), shape=(n, n))
    # npaths[s, v]: shortest-path multiplicities, filled level by level
    npaths = np.zeros((n, n), np.float32)
    npaths[np.arange(n), np.arange(n)] = 1.0
    for lvl in range(1, maxd + 1):
        prev = np.where(d32 == lvl - 1, npaths, np.float32(0.0))
        contrib = adj_T.dot(prev.T).T             # sum over in-neighbors
        npaths = np.where(d32 == lvl, contrib, npaths)
    cin, cout = t[:, 0], t[:, 1]
    gp = ch.src[cin].astype(np.int64)
    mid = ch.dst[cin].astype(np.int64)
    vv = ch.dst[cout].astype(np.int64)
    freq = np.zeros(len(t))
    chunk = max(1, (1 << 24) // max(len(t), 1))
    for s0 in range(0, n, chunk):
        D = d32[s0:s0 + chunk]
        dm = D[:, mid]
        on_dag = (D[:, gp] + 1 == dm) & (dm + 1 == D[:, vv])
        freq += (on_dag * npaths[s0:s0 + chunk][:, gp]).sum(axis=0,
                                                           dtype=np.float64)
    return freq


def prioritize_turns(turns, mode: str, topo: Topology, ch: Channels,
                     seed: int = 0, sym_perms: Optional[np.ndarray] = None):
    """APL: by frequency over all-shortest-path sets; CPL needs a chosen
    routing (caller re-invokes); Random: shuffled. List API edge over
    :func:`_priority_permutation` (the engines consume the permutation)."""
    rng = np.random.default_rng(seed)
    if mode == "random":
        turns = list(turns)
        rng.shuffle(turns)
        return turns
    turns = list(turns)
    if not turns:
        return turns
    freq = _apl_turn_frequencies(np.asarray(turns, np.int64), topo, ch)
    order = np.argsort(-freq, kind="stable")
    return [turns[i] for i in order]


def _priority_permutation(turns_arr: np.ndarray, priority: str,
                          topo: Topology, ch: Channels, seed: int,
                          chosen_loads: Optional[Dict] = None) -> np.ndarray:
    """Shared turn ordering of both admission engines, as indices into
    ``turns_arr``. Must replay the seed's list-based ordering exactly:
    stable descending sorts, and ``random`` via a python-list shuffle
    (the Fisher-Yates draw sequence depends only on the length)."""
    T = len(turns_arr)
    if T == 0:
        return np.zeros(0, np.int64)
    if chosen_loads is not None:
        vals = np.fromiter((chosen_loads.get((int(a), int(b)), 0.0)
                            for a, b in turns_arr), np.float64, T)
        return np.argsort(-vals, kind="stable")
    if priority == "random":
        idx = list(range(T))
        np.random.default_rng(seed).shuffle(idx)
        return np.asarray(idx, np.int64)
    freq = _apl_turn_frequencies(turns_arr.astype(np.int64), topo, ch)
    return np.argsort(-freq, kind="stable")


def _vc_order_pairs(n_vc: int) -> np.ndarray:
    """The seed's VC-assignment try order: same-VC diagonals first, then
    the cross assignments in double-loop order. ``(n_vc^2, 2)`` int."""
    vo = [(v, v) for v in range(n_vc)] + \
        [(v0, v1) for v0 in range(n_vc) for v1 in range(n_vc) if v0 != v1]
    return np.asarray(vo, np.int64)


class _BatchedDAG:
    """Array-native incremental-cycle-detection engine for turn admission.

    Replays the serial greedy (one ``IncrementalDAG.try_add`` per
    VC-labeled turn) exactly, but in blocks:

    - ``level`` is a topological numbering of the accepted DAG (every
      edge strictly increases it). Any attempt consistent with it
      (``level[u] < level[v]``) cannot close a cycle, and a whole batch
      of such *forward* edges stays acyclic together -- accepted
      wholesale with no renumbering.
    - Backward attempts are resolved by one batched BFS over the
      accepted out-adjacency (:meth:`reach`), pruned to each row's level
      window: rows whose head already reaches their tail are definite
      rejections (reachability only grows, so the serial run rejects
      them too -- and the rejection is sticky across both VC passes);
      the rest are *contested*.
    - One SCC pass over accepted + candidates (:meth:`_cycle_edges`)
      localises conflicts exactly: a candidate can be invalidated only
      by candidates inside its own non-trivial strongly connected
      component. If no component exists, every candidate is admissible
      at its serial position and the block's winners commit in one
      bulk accept.
    - Otherwise the *tangled* minority is replayed in serial order over
      per-component interaction graphs (:meth:`_h_graph`; a CDG cycle
      alternates candidate edges with pure-G paths, which never leave
      the component) using an incremental bit-packed transitive closure
      -- the exact, still array-native, dead-end fallback. Components
      bigger than ``tangle_cap`` first split the block in half, which
      shrinks them geometrically.
    - Levels are repaired by :meth:`_relax`, a gap-spaced frontier
      relaxation confined to the raised region.

    The out-adjacency is a capacity-preallocated CSR: every candidate
    edge's slot is known from the turn grid ahead of time, so accepting
    a batch is O(batch) array writes and the BFS passes never rebuild
    anything.
    """

    def __init__(self, n_states: int, cap_out: np.ndarray, stats: dict):
        S = int(n_states)
        self.S = S
        self.level = np.zeros(S, np.int64)
        self.cap_start = np.zeros(S + 1, np.int64)
        np.cumsum(cap_out, out=self.cap_start[1:])
        self.buf = np.zeros(int(self.cap_start[-1]), np.int32)
        self.fill = np.zeros(S, np.int64)          # == out-degree
        self.n_edges = 0
        self._log: List[Tuple[np.ndarray, np.ndarray]] = []
        self.gap = 8            # level-raise headroom (see _relax)
        self.tangle_cap = 1024  # biggest tangle resolved without a split
        self.stats = stats

    # -- accepted-graph storage --------------------------------------------

    def accept(self, u: np.ndarray, v: np.ndarray) -> None:
        """Append accepted edges (caller guarantees acyclicity)."""
        if not len(u):
            return
        order = np.argsort(u, kind="stable")
        us, vs = u[order], v[order]
        ku, ui, cnt = np.unique(us, return_index=True, return_counts=True)
        rank = np.arange(len(us)) - np.repeat(ui, cnt)
        self.buf[self.cap_start[us] + self.fill[us] + rank] = vs
        self.fill[ku] += cnt
        self._log.append((us, vs))
        self.n_edges += len(us)

    def _edge_arrays(self):
        """All accepted edges as two flat arrays (log consolidation)."""
        if len(self._log) > 1:
            self._log = [(np.concatenate([e[0] for e in self._log]),
                          np.concatenate([e[1] for e in self._log]))]
        if not self._log:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        return self._log[0]

    def _expand(self, states: np.ndarray):
        """Out-neighbors of ``states``: (index-into-states, neighbor)."""
        cnt = self.fill[states]
        total = int(cnt.sum())
        if total == 0:
            return (np.zeros(0, np.int64), np.zeros(0, np.int64))
        rep = np.repeat(np.arange(len(states)), cnt)
        inner = np.arange(total) - np.repeat(np.cumsum(cnt) - cnt, cnt)
        nbr = self.buf[self.cap_start[states[rep]] + inner].astype(np.int64)
        return rep, nbr

    # -- batched reachability ----------------------------------------------

    def reach(self, src: np.ndarray, tgt: np.ndarray) -> np.ndarray:
        """``out[i]`` = can ``src[i]`` reach ``tgt[i]`` in the accepted
        DAG. Frontier BFS batched over rows, each pruned to its own
        level window: any path into ``tgt`` stays strictly below the
        target's level, so most windows are a handful of states."""
        B = len(src)
        reached = np.zeros(B, bool)
        if B == 0 or self.n_edges == 0:
            return reached
        self.stats["bfs_rows"] += B
        S = self.S
        CH = 1024
        for i in range(0, B, CH):
            s, t = src[i:i + CH], tgt[i:i + CH]
            b = len(s)
            cap = self.level[t]
            visited = np.zeros((b, S), bool)
            rows = np.arange(b)
            cur = s.astype(np.int64)
            visited[rows, cur] = True
            got = np.zeros(b, bool)
            while len(rows):
                rep, nbr = self._expand(cur)
                r2 = rows[rep]
                hit = nbr == t[r2]
                if hit.any():
                    got[r2[hit]] = True
                keep = ~hit & ~got[r2] & (self.level[nbr] < cap[r2]) & \
                    ~visited[r2, nbr]
                r2, nbr = r2[keep], nbr[keep]
                if len(r2):
                    _, first = np.unique(r2 * S + nbr, return_index=True)
                    r2, nbr = r2[first], nbr[first]
                    visited[r2, nbr] = True
                rows, cur = r2, nbr
            reached[i:i + b] = got
        return reached

    def commit(self, eu: np.ndarray, ev: np.ndarray,
               n_backward: int) -> None:
        """Accept a verified-acyclic batch, relaxing levels first when
        it contains backward edges (forward-only batches keep the
        current numbering valid as-is)."""
        if n_backward:
            lv = self._relax(eu, ev)
            assert lv is not None, "committed batches are acyclic"
            self.level = lv
        self.accept(eu, ev)

    # -- bulk commit (local level relaxation) ------------------------------

    def _relax(self, bu: np.ndarray, bv: np.ndarray
               ) -> Optional[np.ndarray]:
        """Raise a copy of ``level`` until every accepted + batch edge
        strictly increases it, touching only the affected region (the
        descendants of raised batch heads). The ``gap`` headroom above
        the strict minimum means most future raises land below their
        descendants and stop immediately. Returns the new levels, or
        ``None`` when a level exceeds the acyclic bound (certain
        cycle -- callers only pass verified-acyclic batches, so this
        is an internal invariant check)."""
        GAP = np.int64(self.gap)              # headroom absorbs future
        lv = self.level.copy()                # raises, cutting cascades
        if not len(bu):
            return lv
        bound = int(lv.max()) + (self.S + 1) * int(GAP)
        order = np.argsort(bu, kind="stable")
        sbu, sbv = bu[order], bv[order]
        cur, val = sbv, lv[sbu] + GAP
        keep = val > lv[cur]
        cur, val = cur[keep], val[keep]
        while len(cur):
            if len(cur) > 1:                  # per-node max proposal
                o = np.lexsort((-val, cur))
                cur, val = cur[o], val[o]
                first = np.ones(len(cur), bool)
                first[1:] = cur[1:] != cur[:-1]
                cur, val = cur[first], val[first]
            lv[cur] = val
            if int(val.max()) > bound:
                return None
            rep, nbr = self._expand(cur)
            nv = lv[cur[rep]] + GAP
            lo = np.searchsorted(sbu, cur)    # batch out-edges of cur
            cnt2 = np.searchsorted(sbu, cur, side="right") - lo
            if cnt2.any():
                rep2 = np.repeat(np.arange(len(cur)), cnt2)
                inner = np.arange(int(cnt2.sum())) - \
                    np.repeat(np.cumsum(cnt2) - cnt2, cnt2)
                nbr = np.concatenate([nbr, sbv[lo[rep2] + inner]])
                nv = np.concatenate([nv, lv[cur[rep2]] + GAP])
            keep = nv > lv[nbr]
            cur, val = nbr[keep], nv[keep]
        return lv

    def _cycle_edges(self, bu: np.ndarray, bv: np.ndarray):
        """``out[k]`` = batch edge k lies on some cycle of accepted +
        batch; also returns the per-node SCC labels. Exact: an edge is
        on a cycle iff its endpoints share a non-trivial strongly
        connected component of the union."""
        import scipy.sparse as sp
        from scipy.sparse.csgraph import connected_components
        gu, gv = self._edge_arrays()
        rows = np.concatenate([gu, bu])
        cols = np.concatenate([gv, bv])
        m = sp.csr_matrix((np.ones(len(rows), np.int8), (rows, cols)),
                          shape=(self.S, self.S))
        ncomp, labels = connected_components(m, directed=True,
                                             connection="strong")
        sizes = np.bincount(labels, minlength=ncomp)
        return (labels[bu] == labels[bv]) & (sizes[labels[bu]] > 1), labels


    # -- tangle interaction graphs -----------------------------------------

    def _h_graph(self, members: np.ndarray, srcs: np.ndarray,
                 tails: np.ndarray):
        """Interaction bitsets of one conflict component: bit ``j`` of
        ``hout[i]`` iff ``srcs[i]`` reaches ``tails[j]`` through the
        accepted DAG (the empty path counts: ``srcs[i] == tails[j]``).
        A union-cycle's pure-G segments never leave its strongly
        connected component, so reachability is computed inside the
        member subgraph only -- and since the accepted graph is a DAG,
        one scatter-OR sweep over its level bands (reverse topological
        order) closes all tail bitsets at once, with no per-source
        BFS."""
        m, c = len(members), len(srcs)
        W = (c + 63) // 64
        comp = np.full(self.S, -1, np.int64)
        comp[members] = np.arange(m)
        word = (np.arange(c) >> 6).astype(np.int64)
        bit = np.uint64(1) << (np.arange(c) & 63).astype(np.uint64)
        R = np.zeros((m, W), np.uint64)       # tails reachable from node
        ct = comp[tails]
        np.bitwise_or.at(R, (ct, word), bit)  # a tail reaches itself
        gu, gv = self._edge_arrays()
        eu, ev = comp[gu], comp[gv]
        keep = (eu >= 0) & (ev >= 0)
        eu, ev = eu[keep], ev[keep]
        if len(eu):
            lv = self.level[members[ev]]
            order = np.argsort(-lv, kind="stable")
            eu, ev, lv = eu[order], ev[order], lv[order]
            bands = np.nonzero(np.diff(lv))[0] + 1
            for lo, hi in zip(np.r_[0, bands], np.r_[bands, len(eu)]):
                np.bitwise_or.at(R, eu[lo:hi], R[ev[lo:hi]])
        hout = R[comp[srcs]]
        # no self interactions (reachability back to the own tail was
        # ruled out by the classification BFS)
        hout[np.arange(c), word] &= ~bit
        bools = np.unpackbits(hout.view(np.uint8), axis=1,
                              bitorder="little")[:, :c].astype(bool)
        packed = np.packbits(bools.T, axis=1, bitorder="little")
        hin = np.zeros((c, W * 8), np.uint8)
        hin[:, :packed.shape[1]] = packed
        return hout, hin.view(np.uint64)

    # -- exact grid admission ----------------------------------------------

    def admit_grid(self, u: np.ndarray, v: np.ndarray, skip: np.ndarray,
                   rej: np.ndarray, first_only: bool):
        """Admit a ``(B, n_vo)`` grid of VC-labeled attempts in serial
        (row-major) order; ``skip`` marks already-allowed edges (trivial
        successes), ``rej`` previously confirmed rejections (sticky --
        reachability only grows). Returns the newly accepted and newly
        rejected grid masks; the result is identical to per-attempt
        serial admission. ``first_only`` replays pass 1 of Algorithm 1,
        where each row stops at its first success.

        One pass per block: the forward test plus one batched BFS
        classifies every attempt into forward / rejected / contested;
        one SCC pass over accepted + candidates localises the conflict
        tangles exactly (an edge is on a union cycle iff its endpoints
        share a non-trivial component). Untangled candidates commit
        wholesale -- nothing can invalidate them. For each tangle the
        interaction graph H (head-reaches-tail through the accepted
        DAG, confined to the component -- a CDG cycle alternates
        candidate edges with pure-G paths, which is exactly an
        H-cycle) comes from :meth:`_h_graph`, and the serial greedy is
        replayed over it with an incremental bit-packed transitive
        closure: rejects are one bitset AND, accepts one vectorized
        ancestor scan. All accepted edges then land in one bulk accept
        + level repair. Components larger than ``tangle_cap`` halve
        the block instead (sequential halves stay exact and tangles
        shrink geometrically with block size)."""
        B, n_vo = u.shape
        acc = np.zeros((B, n_vo), bool)
        new_rej = np.zeros((B, n_vo), bool)
        undecided = ~skip & ~rej
        fwd = np.zeros_like(undecided)
        ur, uc = np.nonzero(undecided)
        fwd[ur, uc] = self.level[u[ur, uc]] < self.level[v[ur, uc]]
        need = undecided & ~fwd
        contested = np.zeros_like(need)
        nr, nc = np.nonzero(need)
        if len(nr):
            reached = self.reach(v[nr, nc], u[nr, nc])
            contested[nr, nc] = ~reached
            new_rej[nr, nc] = reached
        cand = fwd | contested
        if not cand.any():
            return acc, new_rej
        cr, cc = np.nonzero(cand)             # row-major == serial order
        cu, cv = u[cr, cc], v[cr, cc]
        dirty = np.zeros(len(cr), bool)
        if contested[cr, cc].any():
            self.stats["scc_checks"] += 1
            dirty, labels = self._cycle_edges(cu, cv)
        if not dirty.any():
            if first_only:                    # winner = first success col
                okg = skip | cand
                rows = np.nonzero(okg.any(axis=1))[0]
                wcol = okg.argmax(axis=1)[rows]
                keep = ~skip[rows, wcol]
                erow, ecol = rows[keep], wcol[keep]
            else:
                erow, ecol = cr, cc
            eu, ev = u[erow, ecol], v[erow, ecol]
            n_cont = int(contested[erow, ecol].sum())
            self.commit(eu, ev, n_cont)
            acc[erow, ecol] = True
            self.stats["contested_bulk"] += n_cont
            self.stats["fwd_bulk"] += len(eu) - n_cont
            return acc, new_rej
        # tangled block: build the interaction bitsets per conflict
        # component, then replay the serial decisions over a transitively
        # closed "reaches-which-accepted" bitset per attempt
        self.stats["conflict_rounds"] += 1
        c = len(cr)
        dk = np.nonzero(dirty)[0]
        glab = labels[cu[dk]]
        _, gcounts = np.unique(glab, return_counts=True)
        if B > 1 and int(gcounts.max()) > self.tangle_cap:
            # a tangle this big makes the closure quadratic: halve the
            # block (sequential halves stay exact; the sticky rejections
            # discovered above carry over, so no reachability is redone)
            mid = B // 2
            half_rej = rej | new_rej
            a1, r1 = self.admit_grid(u[:mid], v[:mid], skip[:mid],
                                     half_rej[:mid], first_only)
            acc[:mid] |= a1
            new_rej[:mid] |= r1
            a2, r2 = self.admit_grid(u[mid:], v[mid:], skip[mid:],
                                     half_rej[mid:], first_only)
            acc[mid:] |= a2
            new_rej[mid:] |= r2
            return acc, new_rej
        grp_of = np.full(c, -1, np.int64)     # cand idx -> group id
        loc_of = np.full(c, -1, np.int64)     # cand idx -> group-local idx
        groups = []
        for g, lab in enumerate(np.unique(glab)):
            idx = dk[glab == lab]
            grp_of[idx] = g
            loc_of[idx] = np.arange(len(idx))
            members = np.nonzero(labels == lab)[0]
            hout, hin = self._h_graph(members, cv[idx], cu[idx])
            ct = len(idx)
            Wt = hout.shape[1]
            groups.append({
                "hout": hout, "hin": hin,
                "word": (np.arange(ct) >> 6).astype(np.int64),
                "bit": np.uint64(1) << (np.arange(ct) & 63).astype(
                    np.uint64),
                "D": np.zeros((ct, Wt), np.uint64),  # reachable accepted
                "flag_w": np.zeros(Wt, np.uint64),
            })
        commit = np.zeros(c, bool)

        def try_insert(k: int) -> bool:
            """Insert attempt k into its component's accepted subgraph
            unless that closes an H-cycle (== a CDG cycle through k): the
            accepted attempts reachable from k must avoid its accepted
            in-neighbors. ``D`` rows are transitively closed, so the
            test is one bitset AND; an accept updates the closure with
            one vectorized ancestor scan."""
            G = groups[grp_of[k]]
            p = int(loc_of[k])
            inw = G["hin"][p] & G["flag_w"]
            D = G["D"]
            if (D[p] & inw).any():
                return False
            pw, pb = G["word"][p], G["bit"][p]
            anc = ((G["hout"][:, pw] & pb) != 0) | \
                (D & inw[None, :]).any(axis=1)
            newbits = D[p].copy()
            newbits[pw] |= pb
            ai = np.nonzero(anc)[0]
            if len(ai):                       # everything reaching p
                D[ai] |= newbits              # inherits its closure
            G["flag_w"][pw] |= pb
            return True

        kgrid = np.full((B, n_vo), -1, np.int64)
        kgrid[cr, cc] = np.arange(len(cr))
        if first_only:
            rlist = np.nonzero(cand.any(axis=1) | skip.any(axis=1))[0]
        else:
            rlist = np.nonzero(cand.any(axis=1))[0]
        for r in rlist.tolist():
            for j in range(n_vo):
                if skip[r, j]:
                    if first_only:
                        break
                    continue
                k = kgrid[r, j]
                if k < 0:
                    continue                  # rejected or not undecided
                k = int(k)
                if not dirty[k] or try_insert(k):
                    commit[k] = True
                else:
                    new_rej[r, j] = True
                    continue
                if first_only:
                    break
        eu, ev = cu[commit], cv[commit]
        n_cont = int(contested[cr[commit], cc[commit]].sum())
        self.commit(eu, ev, n_cont)
        acc[cr[commit], cc[commit]] = True
        nd = commit & ~dirty
        nd_cont = int(contested[cr[nd], cc[nd]].sum())
        self.stats["tangle_commits"] += int((commit & dirty).sum())
        self.stats["contested_bulk"] += nd_cont
        self.stats["fwd_bulk"] += int(nd.sum()) - nd_cont
        return acc, new_rej


def _allowed_turns_batched(topo: Topology, n_vc: int, priority: str,
                           robust: bool, seed: int,
                           chosen_loads: Optional[Dict],
                           block: int = 1024) -> ATResult:
    """Algorithm 1 via the batched admission engine (see
    :class:`_BatchedDAG`); produces the exact allowed set of
    ``at_engine="reference"``."""
    ch = Channels.from_topology(topo)
    S = ch.n * n_vc
    turns = base_turns_array(ch)                      # (T, 2)
    T = len(turns)
    vo = _vc_order_pairs(n_vc)                        # (n_vo, 2)
    n_vo = len(vo)
    cin = turns[:, 0].astype(np.int64)
    cout = turns[:, 1].astype(np.int64)
    U = cin[:, None] * n_vc + vo[None, :, 0]          # (T, n_vo) tails
    V = cout[:, None] * n_vc + vo[None, :, 1]         # (T, n_vo) heads
    # per-state slot capacity = candidate attempts with that tail state:
    # every possible edge has a reserved CSR slot
    cap_out = np.repeat(np.bincount(cin, minlength=ch.n), n_vc) * n_vc
    stats = {"blocks": 0, "fwd_bulk": 0, "contested_bulk": 0,
             "bfs_rows": 0, "scc_checks": 0, "conflict_rounds": 0,
             "tangle_commits": 0, "admitted_per_block": []}
    eng = _BatchedDAG(S, cap_out, stats)
    acc = np.zeros((T, n_vo), bool)                   # == the allowed set
    rej = np.zeros((T, n_vo), bool)                   # sticky rejections
    keys = cin * ch.n + cout                          # ascending by build
    trees: List[List[int]] = []

    def admit_block(b: np.ndarray, j: slice, first_only: bool) -> None:
        res, res_rej = eng.admit_grid(U[b, j], V[b, j], acc[b, j],
                                      rej[b, j], first_only)
        acc[b, j] |= res
        rej[b, j] |= res_rej
        stats["blocks"] += 1
        stats["admitted_per_block"].append(int(res.sum()))

    def admit_stream(tt: np.ndarray, vc: int) -> None:
        """Seeding stream: same-VC turns admitted in sequence (each its
        own group, like the serial add_turn loop)."""
        if not len(tt):
            return
        ti = np.searchsorted(keys, tt[:, 0].astype(np.int64) * ch.n
                             + tt[:, 1])
        j = slice(int(vc), int(vc) + 1)               # diagonal (vc, vc)
        for i in range(0, len(ti), block):
            admit_block(ti[i:i + block], j, first_only=False)

    if robust:
        pair = ocs_disjoint_spanning_trees(topo, ch)
        if pair is not None:
            for vc, tree in zip((0, min(1, n_vc - 1)), pair):
                trees.append(tree)
                admit_stream(_tree_turns_array(tree, ch), vc)

    # routability seed: spanning tree on VC0 (Alg. 1 lines 9-10)
    t0, _ = spanning_tree_channels(topo, ch, 0)
    admit_stream(_tree_turns_array(t0, ch), 0)

    perm = _priority_permutation(turns, priority, topo, ch, seed,
                                 chosen_loads)
    # pass 1 (first success per turn), then pass 2 (every admissible VC
    # assignment), in per-VC-layer block admissions
    for first_only in (True, False):
        for i in range(0, T, block):
            admit_block(perm[i:i + block], slice(None), first_only)

    tr, tv = np.nonzero(acc)
    edges = np.stack([U[tr, tv], V[tr, tv]], axis=1)
    allowed = set(zip(zip(cin[tr].tolist(), vo[tv, 0].tolist()),
                      zip(cout[tr].tolist(), vo[tv, 1].tolist())))
    stats["allowed"] = len(allowed)
    stats["engine"] = "batched"
    admission = {"level": eng.level, "acc": acc, "turns": turns, "vo": vo,
                 "perm": perm, "cap_out": cap_out,
                 "dead_turn": np.zeros(T, bool)}
    return ATResult(ch, n_vc, allowed, trees, stats=stats, _edges=edges,
                    _admission=admission)


def _allowed_turns_reference(topo: Topology, n_vc: int, priority: str,
                             robust: bool, seed: int,
                             chosen_loads: Optional[Dict]) -> ATResult:
    """The seed implementation: one python Pearce-Kelly insertion per
    VC-labeled turn. Kept as the equivalence oracle of the batched
    engine (identical allowed set, bit for bit)."""
    ch = Channels.from_topology(topo)
    n_states = ch.n * n_vc
    dag = IncrementalDAG(n_states)
    allowed: set = set()
    trees: List[List[int]] = []

    def add_turn(cin, v0, cout, v1) -> bool:
        key = ((cin, v0), (cout, v1))
        if key in allowed:
            return True
        if dag.try_add(_state(cin, v0, n_vc), _state(cout, v1, n_vc)):
            allowed.add(key)
            return True
        return False

    if robust:
        pair = ocs_disjoint_spanning_trees(topo, ch)
        if pair is not None:
            for vc, tree in zip((0, min(1, n_vc - 1)), pair):
                trees.append(tree)
                for (cin, cout) in _tree_turns(tree, ch):
                    add_turn(cin, vc, cout, vc)

    # routability seed: spanning tree on VC0 (Alg. 1 lines 9-10)
    t0, _ = spanning_tree_channels(topo, ch, 0)
    for (cin, cout) in _tree_turns(t0, ch):
        add_turn(cin, 0, cout, 0)

    turns_arr = base_turns_array(ch)
    perm = _priority_permutation(turns_arr, priority, topo, ch, seed,
                                 chosen_loads)
    turns = [(int(a), int(b)) for a, b in turns_arr[perm]]

    vc_orders = [tuple(p) for p in _vc_order_pairs(n_vc).tolist()]
    # first pass: at most one VC-labeled instance per base turn
    for (cin, cout) in turns:
        for (v0, v1) in vc_orders:
            if add_turn(cin, v0, cout, v1):
                break
    # second pass: all admissible VC assignments
    for (cin, cout) in turns:
        for (v0, v1) in vc_orders:
            add_turn(cin, v0, cout, v1)

    return ATResult(ch, n_vc, allowed, trees,
                    stats={"engine": "reference"})


def allowed_turns(topo: Topology, n_vc: int = 2, priority: str = "apl",
                  robust: bool = False, seed: int = 0,
                  chosen_loads: Optional[Dict[Tuple[int, int], float]] = None,
                  at_engine: str = "batched") -> ATResult:
    """Algorithm 1. ``chosen_loads`` (turn -> frequency in a chosen routing)
    enables the CPL variant on a second invocation.

    ``at_engine="batched"`` (default) runs the array-native admission
    engine -- forward-edge blocks accepted wholesale against the current
    topological order, batched BFS over the accepted CSR for the
    contested backward minority, Kahn bulk commits with bisection
    fallback. ``at_engine="reference"`` is the seed's serial
    Pearce-Kelly loop; both produce the identical allowed set.
    """
    if at_engine == "reference":
        return _allowed_turns_reference(topo, n_vc, priority, robust, seed,
                                        chosen_loads)
    if at_engine != "batched":
        raise ValueError(f"unknown at_engine {at_engine!r}")
    return _allowed_turns_batched(topo, n_vc, priority, robust, seed,
                                  chosen_loads)


def _dead_channel_array(dead_channels) -> Optional[np.ndarray]:
    """Normalise a dead-channel collection (python set, list, or int
    array -- :func:`repro.core.fault.dead_channels_for_color` returns a
    sorted array) to a sorted int64 array, or ``None`` when empty."""
    if dead_channels is None:
        return None
    if isinstance(dead_channels, np.ndarray):
        dc = dead_channels.astype(np.int64, copy=False)
    else:
        dc = np.fromiter(dead_channels, np.int64, len(dead_channels))
    if not len(dc):
        return None
    return np.unique(dc)


# ---------------------------------------------------------------------------
# Minimal-alternate export for the adaptive simulator kernel
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AdaptiveRouteTable:
    """Per-(node, destination) minimal next-hop alternates, packed for the
    adaptive netsim kernel.

    The candidate enumerator walks exactly these minimal parents when it
    builds the (F, K, L) path tensor, then keeps only the K winning
    chains; this exports what it throws away, collapsed to the per-hop
    decision the simulator needs: from node ``u`` toward destination
    ``d``, bit ``j`` of ``minmask[u, d]`` says whether the ``j``-th
    outgoing channel of ``u`` (``outch[u, j]``) lies on *some* minimal
    path (``dist[dst(c), d] == dist[u, d] - 1`` over surviving
    channels). A packet holding the table can therefore pick among every
    minimal alternate by live downstream occupancy instead of replaying
    one frozen choice. Distances are plain channel-hop BFS (VC-free):
    the adaptive VCs place no turn restriction -- deadlock freedom comes
    from the reserved escape sub-network, not from the adaptive lanes.
    """
    n: int
    outch: np.ndarray       # (n, D) int32 out-channels per node, -1 pad
    minmask: np.ndarray     # (n, n) uint8: bit j <=> outch[u, j] minimal
    dist: np.ndarray        # (n, n) int16 surviving hop distance, -1 pad

    @property
    def D(self) -> int:
        return self.outch.shape[1]


def adaptive_route(topo: Topology, dead_channels=None
                   ) -> AdaptiveRouteTable:
    """Build the minimal-alternate table over the surviving channels.

    ``outch`` slots are fixed by the topology (CSR out-adjacency order),
    independent of the fault set, so a pre-fault and a post-fault table
    share slot indexing and the kernel can swap ``minmask`` mid-sweep
    without re-indexing queues. Dead channels simply never set their
    minimal bit (and contribute no edge to the distance field).
    """
    import scipy.sparse as sp
    import scipy.sparse.csgraph as csg
    ch = Channels.from_topology(topo)
    n = ch.n_nodes
    dc = _dead_channel_array(dead_channels)
    alive = np.ones(ch.n, bool)
    if dc is not None:
        if (dc < 0).any() or (dc >= ch.n).any():
            bad = dc[(dc < 0) | (dc >= ch.n)]
            raise ValueError(f"unknown channel ids {bad.tolist()} "
                             f"(topology has {ch.n} channels)")
        alive[dc] = False
    deg = np.diff(ch.out_indptr).astype(np.int64)
    D = int(deg.max()) if n else 1
    if D > 8:
        raise ValueError(f"adaptive minmask packs at most 8 out-channels "
                         f"per node (got degree {D})")
    outch = np.full((n, D), -1, np.int32)
    slot = np.arange(int(deg.sum()), dtype=np.int64) \
        - np.repeat(ch.out_indptr[:-1].astype(np.int64), deg)
    outch[np.repeat(np.arange(n), deg), slot] = ch.out_chan
    a = sp.csr_matrix((np.ones(int(alive.sum()), np.float32),
                       (ch.src[alive], ch.dst[alive])), shape=(n, n))
    d = csg.shortest_path(a, method="D", unweighted=True)
    dist = np.where(np.isinf(d), -1, d).astype(np.int16)
    minmask = np.zeros((n, n), np.uint8)
    for j in range(D):
        c = outch[:, j]
        ok = (c >= 0) & alive[np.clip(c, 0, ch.n - 1)]
        nd = ch.dst[np.clip(c, 0, ch.n - 1)].astype(np.int64)
        # (n, n): hop u -> dst(c) is on a minimal path toward every d
        # with dist[u, d] == dist[dst(c), d] + 1 (both sides reachable)
        dn = dist[nd]
        cond = ok[:, None] & (dn >= 0) & (dist == dn + 1)
        minmask |= (cond.astype(np.uint8) << j)
    return AdaptiveRouteTable(n, outch, minmask, dist)


# ---------------------------------------------------------------------------
# Reference enumerator (per-source python BFS) -- kept as the equivalence
# oracle for the array engine below; not on the hot path.
# ---------------------------------------------------------------------------


def shortest_path_states(at: ATResult, source: int,
                         dead_channels: Optional[set] = None):
    """BFS over (channel, vc) states from `source`; returns dist + parents
    per state and best distance per destination node. Reference oracle."""
    n_vc = at.n_vc
    dc = _dead_channel_array(dead_channels)
    dead = set() if dc is None else set(dc.tolist())
    dist: Dict[Tuple[int, int], int] = {}
    parents: Dict[Tuple[int, int], List[Tuple[int, int]]] = defaultdict(list)
    q = deque()
    for c in at.channels.out_of(source):
        c = int(c)
        if c in dead:
            continue
        for v in range(n_vc):
            st = (c, v)
            if st not in dist:
                dist[st] = 1
                q.append(st)
    while q:
        st = q.popleft()
        for (c2, v2) in at.allowed_by_in.get(st, []):
            if c2 in dead:
                continue
            st2 = (c2, v2)
            if st2 not in dist:
                dist[st2] = dist[st] + 1
                parents[st2].append(st)
                q.append(st2)
            elif dist[st2] == dist[st] + 1:
                parents[st2].append(st)
    return dist, parents


def candidate_paths(at: ATResult, source: int, K: int = 8,
                    dead_channels: Optional[set] = None
                    ) -> Dict[int, List[Tuple[int, ...]]]:
    """Up to K shortest deadlock-free channel-paths per destination.
    Reference oracle (per-source python DFS over the parent DAG)."""
    ch = at.channels
    dist, parents = shortest_path_states(at, source, dead_channels)
    best: Dict[int, int] = {}
    endstates: Dict[int, List[Tuple[int, int]]] = defaultdict(list)
    for (c, v), d in dist.items():
        node = int(ch.dst[c])
        if node == source:
            continue
        if node not in best or d < best[node]:
            best[node] = d
            endstates[node] = [(c, v)]
        elif d == best[node]:
            endstates[node].append((c, v))
    out: Dict[int, List[Tuple[int, ...]]] = {}
    for dest, sts in endstates.items():
        paths = []
        seen = set()
        stack = [(st, (st[0],)) for st in sts]
        while stack and len(paths) < K * 3:
            st, suffix = stack.pop()
            if dist[st] == 1:
                if suffix not in seen:
                    seen.add(suffix)
                    paths.append(suffix)
                continue
            for p in parents[st]:
                stack.append((p, (p[0],) + suffix))
        uniq = []
        useen = set()
        for p in paths:
            if p not in useen:
                useen.add(p)
                uniq.append(p)
            if len(uniq) >= K:
                break
        out[dest] = uniq
    return out


# ---------------------------------------------------------------------------
# Array engine: batched frontier BFS + packed candidate enumeration
# ---------------------------------------------------------------------------


def state_bfs(at: ATResult, sources: Sequence[int],
              dead_channels: Optional[set] = None) -> np.ndarray:
    """Level-synchronous BFS over (channel, vc) states, batched over
    ``sources``. Returns ``(B, S)`` int16 distances (-1 = unreached; the
    out-channels of each source seed at distance 1)."""
    sg = at.state_graph()
    ch = at.channels
    S, n_vc = sg.n_states, at.n_vc
    sources = np.asarray(sources, np.int64)
    B = len(sources)
    dead_state = np.zeros(S, bool)
    dc = _dead_channel_array(dead_channels)
    if dc is not None:
        dead_state[(dc[:, None] * n_vc + np.arange(n_vc)).ravel()] = True
    dist = np.full((B, S), -1, np.int16)
    frontier = np.zeros((B, S), bool)
    deg = (ch.out_indptr[sources + 1] - ch.out_indptr[sources]).astype(int)
    rows = np.repeat(np.arange(B), deg * n_vc)
    seed_ch = np.concatenate(
        [ch.out_of(int(s)) for s in sources]) if B else np.zeros(0, int)
    seed_st = (seed_ch.astype(np.int64)[:, None] * n_vc
               + np.arange(n_vc)).ravel()
    frontier[rows, seed_st] = True
    frontier &= ~dead_state
    level = 1
    while frontier.any():
        dist[frontier] = level
        nxt = sg.fwd_T.dot(frontier.T.astype(np.float32)) > 0
        frontier = nxt.T & (dist < 0) & ~dead_state
        level += 1
        if level > S:                        # defensive: cannot recur
            break
    return dist


def node_distances(at: ATResult, sources: Sequence[int],
                   dead_channels: Optional[set] = None,
                   dist: Optional[np.ndarray] = None) -> np.ndarray:
    """``(B, n)`` shortest deadlock-free hop distance from each source to
    every node: min over that node's arrival states. -1 = unreachable,
    0 = self. Matches the reference enumerator's distances exactly."""
    sg = at.state_graph()
    if dist is None:
        dist = state_bfs(at, sources, dead_channels)
    B = dist.shape[0]
    UNREACH = np.int32(sg.n_states + 1)
    dd = np.where(dist < 0, UNREACH, dist.astype(np.int32))[:, sg.node_order]
    best = np.minimum.reduceat(dd, sg.node_starts[:-1], axis=1)
    empty = sg.node_starts[:-1] == sg.node_starts[1:]
    best[:, empty] = UNREACH
    best = np.where(best >= UNREACH, -1, best)
    best[np.arange(B), np.asarray(sources, np.int64)] = 0
    return best


@dataclasses.dataclass
class CandidateSet:
    """Packed shortest-path candidates: ``chan``/``vc`` are ``(F, K, L)``
    (``L`` = longest shortest path this round; channels SEN-padded with
    ``n_ch``), ``length[f]`` is every candidate's hop count (all candidates
    of a flow are shortest), ``k_valid`` masks deduplicated slots."""
    flow_src: np.ndarray
    flow_dst: np.ndarray
    chan: np.ndarray
    vc: np.ndarray
    length: np.ndarray
    k_valid: np.ndarray
    n_ch: int
    unreachable: int


def _unique_channel_flows(sg: StateGraph, dist: np.ndarray,
                          best: np.ndarray, n: int) -> np.ndarray:
    """(B, n) bool: flows whose BFS distance field admits a *single
    shortest channel path* (every shortest state path projects onto the
    same channel sequence, whatever its VC labeling). Such flows get a
    one-walker budget and skip the mixed-radix slot machinery in
    :func:`_walk_flows` (the ``kcap=1`` fast lane): all their candidates
    would use the same channels, so the min-max greedy could never
    distinguish them anyway -- and ties break to slot 0, the slot the
    single walker produces.

    Forward DP over the BFS levels: each state carries a flag ("all
    shortest state paths to me share one channel projection") plus the
    64-bit polynomial hash of that canonical projection; a state stays
    unique iff every valid parent is unique with the *same* projection
    hash. A flow is unique iff its arrival states at the best distance
    all agree likewise. (Hash collisions could flag a two-path flow as
    unique -- same 2^-64 risk the walk's dedup hash already accepts; the
    consequence is a valid-but-unoptimised path choice, never an invalid
    route.) Costs one sort of the reached states plus one ``rev_pad``
    gather per level -- the same access pattern as a single extra
    walker, amortised over the whole shard.
    """
    B, S = dist.shape
    mul = np.uint64(0x9E3779B97F4A7C15)
    st_chan = (np.arange(S, dtype=np.uint64) // np.uint64(sg.n_vc)
               + np.uint64(1))
    ucp = np.zeros((B, S), np.uint8)       # 0 unreached, 1 unique, 2 multi
    hproj = np.zeros((B, S), np.uint64)
    m1 = dist == 1
    ucp[m1] = 1
    hproj[m1] = np.broadcast_to(st_chan, (B, S))[m1]
    bb, vv = np.nonzero(dist >= 2)
    if len(bb):
        lv = dist[bb, vv].astype(np.int64)
        order = np.argsort(lv, kind="stable")
        bb, vv, lv = bb[order], vv[order], lv[order]
        lmax = int(lv[-1])
        starts = np.searchsorted(lv, np.arange(2, lmax + 2))
        for l in range(2, lmax + 1):
            a, b = starts[l - 2], starts[l - 1]
            if a == b:
                continue
            rb, rv = bb[a:b], vv[a:b]
            par = sg.rev_pad[rv].astype(np.int64)
            pc = np.clip(par, 0, S - 1)
            okp = (par >= 0) & (dist[rb[:, None], pc] == l - 1)
            pu = ucp[rb[:, None], pc]
            ph = hproj[rb[:, None], pc]
            ref = ph[np.arange(len(rv)), okp.argmax(axis=1)]
            u = (((pu == 1) | ~okp).all(axis=1)
                 & (np.where(okp, ph, ref[:, None])
                    == ref[:, None]).all(axis=1))
            ucp[rb, rv] = np.where(u, 1, 2).astype(np.uint8)
            hproj[rb, rv] = np.where(u, ref * mul + st_chan[rv], 0)
    # flow level: all arrival states unique with one shared projection
    tgt = best[:, sg.dst_node]
    ab, st = np.nonzero((dist == tgt) & (dist > 0))
    nd = sg.dst_node[st]
    bad = np.zeros((B, n), np.int64)
    np.add.at(bad, (ab, nd), (ucp[ab, st] != 1).astype(np.int64))
    hmin = np.full((B, n), np.iinfo(np.uint64).max, np.uint64)
    hmax = np.zeros((B, n), np.uint64)
    np.minimum.at(hmin, (ab, nd), hproj[ab, st])
    np.maximum.at(hmax, (ab, nd), hproj[ab, st])
    return (bad == 0) & (hmin == hmax)


def _walk_flows(sg: StateGraph, n: int, n_vc: int, SEN: int,
                dist: np.ndarray, best: np.ndarray, src_ids: np.ndarray,
                fb: np.ndarray, fd: np.ndarray, flen: np.ndarray,
                kcap: np.ndarray, K: int,
                uniq: Optional[np.ndarray] = None
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorised backward parent walk for the flows ``(fb, fd)`` of one
    source chunk (``dist``/``best`` rows indexed by ``fb``; ``src_ids``
    maps rows to global source ids).

    ``kcap`` is the per-flow walker budget: slot ``k`` of a flow is
    walked iff ``k < kcap[f]``, and every walked slot is *identical* to
    the corresponding slot of a full-``K`` walk (the budget truncates the
    slot range, it never changes a walker's hash rotation or code), so
    re-walking a flow with a larger budget reproduces its earlier slots
    -- the property the streaming engine's refinement sweep relies on.

    ``uniq`` (optional per-flow bool, from
    :func:`_unique_channel_flows`) marks flows whose shortest state
    paths all share one channel projection: their single walker takes
    the first valid parent at every level directly (an ``argmax`` over
    the parent mask) and skips the hash-rotation / mixed-radix code
    arithmetic entirely. Every candidate such a flow could enumerate
    uses the same channels, so its load contribution -- the only thing
    the greedy and refinement stages compare -- is independent of which
    VC labeling the walker lands on. The uniq lane is deterministic
    (same start state, first-parent rule), so re-walking a uniq flow in
    the refinement sweep reproduces its round-loop candidate exactly.

    K walkers per flow, round-robin over end states; each walker's
    mixed-radix code picks parents so distinct codes -> distinct paths.
    Raw codes always favour parent 0, which correlates every flow's
    candidates onto the same low-id channels and skews the loads the
    min-max selector has to balance -- so both the end-state round-robin
    and each parent digit are rotated by a hash of (flow, decision
    point). Walkers of one flow at the same decision point share the
    rotation, so distinctness is unaffected.

    The walk tolerates *stale* distance fields (the fault-repair path
    re-walks against distances stored before channels died, with the
    dead states masked to -1): a walker whose frontier has no valid
    parent -- or a flow with no live arrival state at its recorded
    length -- is marked dead and its slot dropped from ``k_valid``
    instead of asserting. Every *completed* chain is still a real edge
    path of the claimed length, so stale fields only cost completeness,
    never soundness. With a BFS-consistent ``dist`` (every other
    caller) no walker can die and the output is unchanged.

    Returns SEN-padded ``chan (F_c, K, Lmax)``, ``vc`` and ``k_valid``
    (budget mask minus dead walkers and within-flow duplicates).
    """
    S = sg.n_states
    Lmax = int(flen.max())
    # arrival states achieving the per-destination best distance
    tgt = best[:, sg.dst_node]                           # (B, S)
    bb, st = np.nonzero((dist == tgt) & (dist > 0))
    key = bb * n + sg.dst_node[st]
    grp = np.argsort(key, kind="stable")
    st_sorted, key_sorted = st[grp], key[grp]
    fkey = fb * n + fd
    off = np.searchsorted(key_sorted, fkey)
    cnt = np.searchsorted(key_sorted, fkey, side="right") - off
    fhash = ((src_ids[fb].astype(np.uint64) * np.uint64(0x9E3779B1)
              + fd.astype(np.uint64) * np.uint64(0x85EBCA77))
             >> np.uint64(7))
    Fc = len(fb)
    kcap = np.asarray(kcap, np.int64)
    wstart = np.cumsum(kcap) - kcap
    Wr = int(kcap.sum())
    wflow = np.repeat(np.arange(Fc), kcap)
    wk = np.arange(Wr) - np.repeat(wstart, kcap)         # slot per walker
    alive = np.ones(Wr, bool)
    cnt_safe = np.maximum(cnt, 1)
    if len(st_sorted):
        sidx = off[wflow] + ((wk + fhash[wflow]) % cnt_safe[wflow]) \
            .astype(np.int64)
        start = st_sorted[np.minimum(sidx, len(st_sorted) - 1)]
    else:
        start = np.zeros(Wr, np.int64)
    code = (wk // cnt_safe[wflow]).astype(np.int64)
    cur = start.astype(np.int64)
    wrow = fb[wflow]
    wlen = flen[wflow].copy()
    whash = fhash[wflow]
    dead0 = cnt[wflow] == 0          # no live arrival state at this length
    if dead0.any():
        alive[dead0] = False
        wlen[dead0] = 0
    chan_buf = np.full((Wr, Lmax), SEN, np.int32)
    vc_buf = np.zeros((Wr, Lmax), np.int8)
    chan_buf[np.arange(Wr), wlen - 1] = cur // n_vc
    vc_buf[np.arange(Wr), wlen - 1] = (cur % n_vc).astype(np.int8)
    wuniq = uniq[wflow] if uniq is not None else None
    for lvl in range(Lmax, 1, -1):
        act = np.nonzero(wlen >= lvl)[0]
        par = sg.rev_pad[cur[act]].astype(np.int64)      # (A, D)
        ok = (par >= 0) & (dist[wrow[act][:, None],
                                np.clip(par, 0, S - 1)] == lvl - 1)
        if wuniq is not None and wuniq[act].any():
            ua = wuniq[act]
            au = np.nonzero(ua)[0]
            oku = ok[au]
            ubad = ~oku.any(axis=1)
            if ubad.any():                   # stale dist: walker is stuck
                alive[act[au[ubad]]] = False
                wlen[act[au[ubad]]] = 0
                au, oku = au[~ubad], oku[~ubad]
            # unique flows: the only valid parent, no slot arithmetic
            cur[act[au]] = par[au, oku.argmax(axis=1)]
            ga = np.nonzero(~ua)[0]
        else:
            ga = np.arange(len(act))
        if len(ga):
            ag = act[ga]
            okg = ok[ga]
            npar = okg.sum(axis=1)           # >= 1 with consistent dist
            bad = npar == 0
            if bad.any():                    # stale dist: walker is stuck
                alive[ag[bad]] = False
                wlen[ag[bad]] = 0
                ga, ag = ga[~bad], ag[~bad]
                okg, npar = okg[~bad], npar[~bad]
        if len(ga):
            rot = ((whash[ag] + cur[ag].astype(np.uint64)
                    * np.uint64(0x9E3779B9)
                    + np.uint64(lvl) * np.uint64(0xC2B2AE35))
                   % npar.astype(np.uint64)).astype(np.int64)
            pick = (code[ag] + rot) % npar
            code[ag] //= npar
            sel = okg & (np.cumsum(okg, axis=1) == (pick + 1)[:, None])
            cur[ag] = par[ga, sel.argmax(axis=1)]
        act = act[alive[act]]
        chan_buf[act, lvl - 2] = (cur[act] // n_vc).astype(np.int32)
        vc_buf[act, lvl - 2] = (cur[act] % n_vc).astype(np.int8)
    # dedupe within each flow's slots (64-bit polynomial path hash;
    # padding is identical across a flow's slots so it cancels out)
    h = np.zeros(Wr, np.uint64)
    mul = np.uint64(0x9E3779B97F4A7C15)
    for pos in range(Lmax):
        stcol = (chan_buf[:, pos].astype(np.uint64) * np.uint64(n_vc)
                 + vc_buf[:, pos].astype(np.uint64))
        h = h * mul + stcol + np.uint64(1)
    chan = np.full((Fc, K, Lmax), SEN, np.int32)
    vc = np.zeros((Fc, K, Lmax), np.int8)
    chan[wflow, wk] = chan_buf
    vc[wflow, wk] = vc_buf
    hh = np.zeros((Fc, K), np.uint64)
    hh[wflow, wk] = h
    valid_slot = np.zeros((Fc, K), bool)
    valid_slot[wflow, wk] = alive
    k_valid = valid_slot.copy()
    for k in range(1, K):
        dup = (hh[:, k:k + 1] == hh[:, :k]) & valid_slot[:, :k] \
            & valid_slot[:, k:k + 1]
        k_valid[:, k] &= ~dup.any(axis=1)
    return chan, vc, k_valid


def enumerate_candidates(at: ATResult, K: int = 8,
                         dead_channels: Optional[set] = None,
                         source_chunk: int = 64) -> CandidateSet:
    """Packed ``(F, K, L)`` candidate tensor for all (src, dst) pairs via
    the batched state BFS + a vectorised backward parent walk."""
    ch = at.channels
    sg = at.state_graph()
    n, n_vc = ch.n_nodes, at.n_vc
    SEN = ch.n
    pieces: List[Tuple] = []
    unreachable = 0
    width = 1
    for s0 in range(0, n, source_chunk):
        srcs = np.arange(s0, min(s0 + source_chunk, n))
        dist = state_bfs(at, srcs, dead_channels)
        best = node_distances(at, srcs, dist=dist)           # (B, n)
        unreachable += int((best < 0).sum())
        fb, fd = np.nonzero(best > 0)
        if not len(fb):
            continue
        flen = best[fb, fd].astype(np.int64)                 # (F_c,)
        Lmax = int(flen.max())
        if Lmax > MAXHOP:
            raise ValueError(f"shortest path of {Lmax} hops exceeds "
                             f"MAXHOP={MAXHOP}")
        kcap = np.full(len(fb), K, np.int64)
        chan_c, vc_c, k_valid = _walk_flows(sg, n, n_vc, SEN, dist, best,
                                            srcs, fb, fd, flen, kcap, K)
        pieces.append((srcs[fb], fd, chan_c, vc_c, flen, k_valid))
        width = max(width, Lmax)
    if not pieces:
        z = np.zeros(0, np.int64)
        return CandidateSet(z, z, np.full((0, K, width), SEN, np.int32),
                            np.zeros((0, K, width), np.int8), z,
                            np.zeros((0, K), bool), SEN, unreachable)

    def pad(a, fill, dt):
        if a.shape[2] == width:
            return a
        out = np.full(a.shape[:2] + (width,), fill, dt)
        out[:, :, :a.shape[2]] = a
        return out

    return CandidateSet(
        np.concatenate([p[0] for p in pieces]).astype(np.int64),
        np.concatenate([p[1] for p in pieces]).astype(np.int64),
        np.concatenate([pad(p[2], SEN, np.int32) for p in pieces]),
        np.concatenate([pad(p[3], 0, np.int8) for p in pieces]),
        np.concatenate([p[4] for p in pieces]),
        np.concatenate([p[5] for p in pieces]),
        SEN, unreachable)


# ---------------------------------------------------------------------------
# Min-max channel-load path selection
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RoutingResult:
    table: PathTable                       # packed (s, d) routes (dense
    loads: np.ndarray                      # or CSR); per-channel load
    l_max: float
    avg_hops: float
    unreachable: int
    stats: Optional[dict] = None           # per-stage timings / counters

    @property
    def paths(self) -> Dict[Tuple[int, int], Tuple[int, ...]]:
        """Dict view, materialised on demand (API edge only -- the
        routing -> VC alloc -> simulation pipeline uses ``table``).

        .. deprecated:: PR 10 -- use ``table`` (packed arrays) instead.
        """
        warnings.warn(
            "RoutingResult.paths is deprecated for internal use; read "
            "the packed RoutingResult.table instead.",
            DeprecationWarning, stacklevel=2)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            return self.table.as_dicts()[0]


def select_paths(at: ATResult, K: int = 8, seed: int = 0,
                 dead_channels: Optional[set] = None,
                 local_search_rounds: int = 3,
                 engine: str = "array", block: Optional[int] = None,
                 shard_sources: int = 64, rounds: int = 4,
                 k_min: Optional[int] = None,
                 refine_cap: Optional[int] = None,
                 uniq_dp="auto",
                 dist_out: Optional[np.ndarray] = None,
                 best_out: Optional[np.ndarray] = None,
                 pair_weight: Optional[np.ndarray] = None
                 ) -> RoutingResult:
    """Min-max channel load selection: greedy + local search (the paper
    solves an ILP with Gurobi; we report the achieved L_max against the
    lower bound so the optimality gap is visible).

    ``engine="array"`` (default) runs the batched state-CSR pipeline:
    candidates come from :func:`enumerate_candidates` and cost evaluation
    is blocked over whole flow groups -- the greedy pass gathers channel
    loads for ``block`` flows at once, and local search re-evaluates
    blocks with each flow's own contribution removed exactly. The winning
    candidate's per-hop VCs (from its BFS state path) are written into the
    table alongside the channels. ``engine="reference"`` is the seed's
    per-flow python loop, kept as the equivalence/benchmark oracle.

    ``engine="sharded"`` is the streaming per-source-shard engine for
    large pods (:func:`_select_sharded`): flows are processed shard-at-a-
    time through a fused candidate-walk -> damped greedy pass coordinated
    by a persistent global load vector, with adaptive per-flow walker
    budgets (``k_min`` for cold flows, full ``K`` for flows touching the
    running hot set, a single machinery-free walker for flows with a
    unique shortest path) and a bounded cross-shard refinement sweep
    over the hottest channels (``refine_cap=None`` scales the pool with
    the flow count: ``max(300_000, F // 24)``). It emits a packed
    :class:`~repro.core.pathtable.CSRPathTable` (memory scales with total
    hops, not ``n^2 * MAXHOP``), which the rest of the pipeline consumes
    directly.

    ``uniq_dp`` gates the sharded engine's kcap=1 unique-shortest-path
    DP: ``"auto"`` (default) enables it only on faulted fabrics or pods
    up to 512 nodes, where it pays for itself (at 16^3 it costs ~100s
    against smaller walk savings). ``dist_out (n, S) / best_out (n, n)``
    accept preallocated arrays that the sharded engine fills with every
    source's BFS state-distance and node-distance fields -- the
    fault-repair pipeline (:mod:`repro.core.repair`) stores these at
    build time so repairs can re-walk pooled flows without re-running
    the BFS.

    ``pair_weight`` (array engine only) is an ``(n, n)`` matrix of
    non-negative integer demand multiplicities: every load counter
    treats flow ``(s, d)`` as ``pair_weight[s, d]`` unit flows, so the
    min-max objective becomes demand-weighted channel load -- routing
    co-designed with the workload the fabric was synthesized for. An
    all-ones matrix is bit-identical to the unweighted path (the
    weighted arithmetic degenerates to today's exactly).
    """
    if pair_weight is not None and engine != "array":
        raise ValueError("pair_weight requires engine='array' (the "
                         "sharded/reference engines are unweighted)")
    if engine == "reference":
        return _select_paths_reference(at, K=K, seed=seed,
                                       dead_channels=dead_channels,
                                       local_search_rounds=local_search_rounds)
    if engine == "sharded":
        return _select_sharded(at, K=K, seed=seed,
                               dead_channels=dead_channels,
                               local_search_rounds=local_search_rounds,
                               block=block or 512,
                               shard_sources=shard_sources,
                               rounds=rounds, k_min=k_min,
                               refine_cap=refine_cap, uniq_dp=uniq_dp,
                               dist_out=dist_out, best_out=best_out)
    if engine != "array":
        raise ValueError(f"unknown engine {engine!r}")
    t0 = time.time()
    cs = enumerate_candidates(at, K=K, dead_channels=dead_channels)
    t_enum = time.time() - t0
    out = _select_array(at, cs, seed=seed,
                        local_search_rounds=local_search_rounds,
                        block=block or 1024, pair_weight=pair_weight)
    out.stats["enumerate_s"] = round(t_enum, 3)
    return out


def _select_array(at: ATResult, cs: CandidateSet, seed: int = 0,
                  local_search_rounds: int = 3,
                  block: int = 1024,
                  pair_weight: Optional[np.ndarray] = None
                  ) -> RoutingResult:
    ch = at.channels
    n = ch.n_nodes
    SEN = cs.n_ch
    table = PathTable.empty(n, ch.n, at.n_vc)
    F, K, L = cs.chan.shape
    if F == 0:
        return RoutingResult(table, np.zeros(ch.n), 0.0, 0.0,
                             cs.unreachable, stats={})
    cand = cs.chan
    loads = np.zeros(SEN + 1, np.int64)
    if pair_weight is None:
        w = np.ones(F, np.int64)
    else:
        pw = np.asarray(pair_weight)
        if pw.shape != (n, n):
            raise ValueError(f"pair_weight shape {pw.shape} != ({n}, {n})")
        if (pw < 0).any():
            raise ValueError("pair_weight must be non-negative")
        w = np.maximum(np.rint(pw[cs.flow_src, cs.flow_dst]), 1) \
            .astype(np.int64)
    BIG = np.int64(w.sum()) * L + 1
    INF = np.iinfo(np.int64).max
    rng = np.random.default_rng(seed)
    order = rng.permutation(F)
    chosen = np.zeros(F, np.int64)
    ar = np.arange
    stats: dict = {}
    t0 = time.time()

    # greedy pass: whole flow blocks against the running load vector
    for i in range(0, F, block):
        b = order[i:i + block]
        l = loads[cand[b]]                                   # (B, K, L)
        cost = l.max(axis=2) * BIG + l.sum(axis=2)
        cost[~cs.k_valid[b]] = INF
        c = cost.argmin(axis=1)
        chosen[b] = c
        np.add.at(loads, cand[b, c].ravel(), np.repeat(w[b], L))
        loads[SEN] = 0
    stats["greedy_s"] = round(time.time() - t0, 3)
    t0 = time.time()

    # local search: block-parallel re-assignment with exact own-load
    # removal (candidate loads minus the flow's current path multiplicity)
    for _ in range(local_search_rounds):
        changed = 0
        for i in range(0, F, block):
            b = order[i:i + block]
            B = len(b)
            bc = cand[b]                                     # (B, K, L)
            cur = bc[ar(B), chosen[b]]                       # (B, L)
            ladj = loads[bc] - (bc[:, :, :, None]
                                == cur[:, None, None, :]).sum(axis=3) \
                * w[b][:, None, None]
            ladj = np.where(bc == SEN, 0, ladj)
            cost = ladj.max(axis=2) * BIG + ladj.sum(axis=2)
            cost[~cs.k_valid[b]] = INF
            newc = cost.argmin(axis=1)
            better = cost[ar(B), newc] < cost[ar(B), chosen[b]]
            if better.any():
                mv = np.nonzero(better)[0]
                np.add.at(loads, cur[mv].ravel(),
                          np.repeat(-w[b[mv]], cur.shape[1]))
                np.add.at(loads, bc[mv, newc[mv]].ravel(),
                          np.repeat(w[b[mv]], cur.shape[1]))
                loads[SEN] = 0
                chosen[b[mv]] = newc[mv]
                changed += len(mv)
        if changed == 0:
            break
    stats["local_search_s"] = round(time.time() - t0, 3)
    t0 = time.time()

    # hot-set peel: vectorised replacement for the reference's sequential
    # hot-channel walk. Each round takes every flow crossing a channel at
    # the current max load and moves the ones with a *safe* alternative --
    # a candidate whose own-removed loads all sit <= max - 2, so a single
    # move can never mint a new max. Concurrent accepted moves can still
    # collide on an lmax-2 channel, so the best (loads, chosen) snapshot
    # by achieved l_max is kept and restored at the end.
    best_snap = (loads.copy(), chosen.copy(), loads[:SEN].max())
    stall = 0
    for _ in range(0 if local_search_rounds == 0 else 64):
        lm = int(loads[:SEN].max())
        if lm <= 1:
            break
        hot_mask = np.zeros(SEN + 1, bool)
        hot_mask[:SEN][loads[:SEN] == lm] = True
        sel = cand[ar(F), chosen]
        hf = np.nonzero(hot_mask[sel].any(axis=1))[0]
        bc = cand[hf]                                        # (H, K, L)
        cur = sel[hf]
        ladj = loads[bc] - (bc[:, :, :, None]
                            == cur[:, None, None, :]).sum(axis=3) \
            * w[hf][:, None, None]
        ladj = np.where(bc == SEN, 0, ladj)
        # landing at ladj + w must stay < lm: ladj <= lm - 1 - w
        # (the unweighted lm - 2 rule, generalised per flow weight)
        safe = (ladj <= lm - 1 - w[hf][:, None, None]).all(axis=2) \
            & cs.k_valid[hf]
        cost = ladj.max(axis=2) * BIG + ladj.sum(axis=2)
        cost[~safe] = INF
        newc = cost.argmin(axis=1)
        mv = np.nonzero(safe[ar(len(hf)), newc])[0]
        if len(mv) == 0:
            break
        np.add.at(loads, cur[mv].ravel(),
                  np.repeat(-w[hf[mv]], cur.shape[1]))
        np.add.at(loads, bc[mv, newc[mv]].ravel(),
                  np.repeat(w[hf[mv]], cur.shape[1]))
        loads[SEN] = 0
        chosen[hf[mv]] = newc[mv]
        lm_now = loads[:SEN].max()
        if lm_now < best_snap[2]:
            best_snap = (loads.copy(), chosen.copy(), lm_now)
            stall = 0
        else:
            stall += 1
            if stall >= 4:
                break
    if best_snap[2] < loads[:SEN].max():
        loads, chosen = best_snap[0], best_snap[1]
    stats["hot_peel_s"] = round(time.time() - t0, 3)
    t0 = time.time()

    # final sequential hot-channel walk (the reference's exact move rule):
    # the peel above leaves only moves that require cascading through
    # lmax-1 channels, which are few -- a handful of cheap rounds. Rounds
    # stop once l_max stops dropping (plateau churn still counts as
    # "improved" under the reference rule, so a stall counter bounds it).
    stall = 0
    best_walk = int(loads[:SEN].max())
    for _ in range(0 if local_search_rounds == 0 else 24):
        improved = False
        hot = int(np.argmax(loads[:SEN]))
        hot_flows = np.nonzero(
            (cand[ar(F), chosen] == hot).any(axis=1))[0]
        rng.shuffle(hot_flows)
        for f in hot_flows:
            np.add.at(loads, cand[f, chosen[f]], -int(w[f]))
            loads[SEN] = 0
            l = loads[cand[f]]
            cost = l.max(axis=1) * BIG + l.sum(axis=1)
            cost = np.where(cs.k_valid[f], cost, INF)
            best = int(np.argmin(cost))
            if cost[best] >= cost[chosen[f]]:
                best = int(chosen[f])
            if best != chosen[f]:
                improved = True
            chosen[f] = best
            np.add.at(loads, cand[f, best], int(w[f]))
            loads[SEN] = 0
            if loads[:SEN].max() < loads[hot]:
                break
        lm_now = int(loads[:SEN].max())
        if lm_now < best_walk:
            best_walk, stall = lm_now, 0
        else:
            stall += 1
        if not improved or stall >= 6:
            break
    stats["hot_walk_s"] = round(time.time() - t0, 3)

    sel = cand[ar(F), chosen]
    selvc = cs.vc[ar(F), chosen]
    table.set_paths_batch(cs.flow_src, cs.flow_dst,
                          np.where(sel == SEN, -1, sel),
                          cs.length.astype(np.int32), vcs=selvc)
    loads_final = loads[:SEN].astype(np.float64)
    return RoutingResult(table, loads_final,
                         float(loads_final.max()) if F else 0.0,
                         float(cs.length.mean()) if F else 0.0,
                         cs.unreachable, stats=stats)


def _hot_pool(loads: np.ndarray, chan_flat: np.ndarray,
              flow_of_hop: np.ndarray, cap: int, SEN: int
              ) -> Tuple[np.ndarray, int]:
    """Flows crossing the hottest channels, bounded by ``cap``.

    The threshold is the lowest load such that the summed loads of all
    channels at or above it stay within ``cap`` -- the sum bounds the
    pool size from above (a flow crossing j hot channels is counted j
    times), so the re-walked candidate pool is memory-bounded no matter
    how flat the load distribution is.
    """
    l = loads[:SEN]
    live = np.nonzero(l > 1)[0]
    if not len(live):
        return np.zeros(0, np.int64), 0
    order = live[np.argsort(-l[live], kind="stable")]
    k = int(np.searchsorted(np.cumsum(l[order]), cap, side="right"))
    hotc = order[:max(k, 1)]        # top-k channels, not a threshold --
    thresh = int(l[hotc].min())     # load ties can't overshoot the cap
    hot = np.zeros(SEN + 1, bool)
    hot[hotc] = True
    return np.unique(flow_of_hop[hot[chan_flat]]).astype(np.int64), thresh


def _refine_candidates(loads: np.ndarray, candP: np.ndarray,
                       kvP: np.ndarray, pchosen: np.ndarray, rng,
                       SEN: int, BIG: np.int64,
                       local_search_rounds: int, refine_block: int,
                       lm_before: int
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Exact own-load-removal local search + safe hot-set peel + bounded
    sequential hot-channel walk over a re-walked candidate pool
    ``candP (P, K, L)`` with slot choices ``pchosen``, snapshot-guarded
    so the achieved ``l_max`` never regresses past ``lm_before``.

    This is the sharded engine's cross-shard refinement primitive,
    shared verbatim with the fault-repair re-route
    (:func:`repro.core.repair.repair_fault`): the repair pool's flows
    are refined against the live load vector exactly like a hot-pool
    sweep. ``loads`` includes every flow outside the pool as fixed
    background. Returns the (possibly snapshot-restored) ``loads`` and
    ``pchosen``; the caller writes moved flows back into its table.
    """
    ar = np.arange
    P = len(pchosen)
    snap = (loads.copy(), pchosen.copy(), lm_before)
    # exact own-load-removal local search over the pool (small
    # blocks: concurrent same-block moves collide on the same
    # cold channels, and the churn costs ~5% l_max at 1024)
    for _ in range(local_search_rounds):
        changed = 0
        for i in range(0, P, refine_block):
            b = slice(i, min(i + refine_block, P))
            B2 = b.stop - b.start
            bc = candP[b]
            cur = bc[ar(B2), pchosen[b]]
            ladj = loads[bc] - (bc[:, :, :, None]
                                == cur[:, None, None, :]).sum(axis=3)
            ladj = np.where(bc == SEN, 0, ladj)
            cost = ladj.max(axis=2) * BIG + ladj.sum(axis=2)
            cost[~kvP[b]] = np.iinfo(np.int64).max
            newc = cost.argmin(axis=1)
            better = cost[ar(B2), newc] < cost[ar(B2), pchosen[b]]
            mv = np.nonzero(better)[0]
            if len(mv):
                np.add.at(loads, cur[mv].ravel(), -1)
                np.add.at(loads, bc[mv, newc[mv]].ravel(), 1)
                loads[SEN] = 0
                pchosen[i + mv] = newc[mv]
                changed += len(mv)
        lm_now = int(loads[:SEN].max())
        if lm_now < snap[2]:
            snap = (loads.copy(), pchosen.copy(), lm_now)
        if changed == 0:
            break
    # safe hot-set peel (single moves can never mint a new max)
    stall = 0
    for _ in range(64):
        lm = int(loads[:SEN].max())
        if lm <= 1:
            break
        hot_mask = np.zeros(SEN + 1, bool)
        hot_mask[:SEN][loads[:SEN] == lm] = True
        sel = candP[ar(P), pchosen]
        hf = np.nonzero(hot_mask[sel].any(axis=1))[0]
        if not len(hf):
            break
        bc = candP[hf]
        cur = sel[hf]
        ladj = loads[bc] - (bc[:, :, :, None]
                            == cur[:, None, None, :]).sum(axis=3)
        ladj = np.where(bc == SEN, 0, ladj)
        safe = (ladj <= lm - 2).all(axis=2) & kvP[hf]
        cost = ladj.max(axis=2) * BIG + ladj.sum(axis=2)
        cost[~safe] = np.iinfo(np.int64).max
        newc = cost.argmin(axis=1)
        mv = np.nonzero(safe[ar(len(hf)), newc])[0]
        if len(mv) == 0:
            break
        np.add.at(loads, cur[mv].ravel(), -1)
        np.add.at(loads, bc[mv, newc[mv]].ravel(), 1)
        loads[SEN] = 0
        pchosen[hf[mv]] = newc[mv]
        lm_now = loads[:SEN].max()
        if lm_now < snap[2]:
            snap = (loads.copy(), pchosen.copy(), int(lm_now))
            stall = 0
        else:
            stall += 1
            if stall >= 4:
                break
    if snap[2] < loads[:SEN].max():
        loads, pchosen = snap[0].copy(), snap[1].copy()
    # short sequential hot-channel walk (exact reference rule)
    stall = 0
    best_walk = int(loads[:SEN].max())
    for _ in range(8):
        improved = False
        hot = int(np.argmax(loads[:SEN]))
        hot_flows = np.nonzero(
            (candP[ar(P), pchosen] == hot).any(axis=1))[0]
        rng.shuffle(hot_flows)
        for f in hot_flows[:4096]:
            np.add.at(loads, candP[f, pchosen[f]], -1)
            loads[SEN] = 0
            l = loads[candP[f]]
            cost = l.max(axis=1) * BIG + l.sum(axis=1)
            cost = np.where(kvP[f], cost, np.iinfo(np.int64).max)
            bestk = int(np.argmin(cost))
            if cost[bestk] >= cost[pchosen[f]]:
                bestk = int(pchosen[f])
            if bestk != pchosen[f]:
                improved = True
            pchosen[f] = bestk
            np.add.at(loads, candP[f, bestk], 1)
            loads[SEN] = 0
            if loads[:SEN].max() < loads[hot]:
                break
        lm_now = int(loads[:SEN].max())
        if lm_now < best_walk:
            best_walk, stall = lm_now, 0
        else:
            stall += 1
        if not improved or stall >= 3:
            break
    return loads, pchosen


def _select_sharded(at: ATResult, K: int = 8, seed: int = 0,
                    dead_channels: Optional[set] = None,
                    local_search_rounds: int = 3, block: int = 512,
                    shard_sources: int = 64, rounds: int = 4,
                    k_min: Optional[int] = None,
                    refine_cap: Optional[int] = None, damp: float = 1.0,
                    hot_load_frac: float = 0.97,
                    refine_iters: int = 2,
                    refine_block: int = 192,
                    uniq_dp="auto",
                    dist_out: Optional[np.ndarray] = None,
                    best_out: Optional[np.ndarray] = None
                    ) -> RoutingResult:
    """Streaming per-source-shard path selection (the large-pod engine).

    The whole-array engine materialises every flow's candidates at once
    (``F = n (n-1)`` rows), which dominates wall-clock and memory past
    ~10^3 nodes. Here the flow problem is decomposed into coordinated
    per-source shards:

    - **Phase 0** runs the batched state BFS shard-at-a-time and keeps
      only the ``(B, S)`` distance fields plus the per-flow lengths --
      enough to rebuild any flow's candidates on demand -- and lays out
      the packed :class:`CSRPathTable` skeleton (per-source offsets +
      concatenated hop arrays) that selection writes into in place.
    - **Streaming rounds**: each round walks and greedily assigns a
      random 1/``rounds`` slice of every shard's flows against the
      *persistent global load vector*, so later decisions see an
      unbiased sample of the final landscape (a single source-ordered
      pass is ~20% worse: early shards dump load geographically).
      Residual-load damping adds the expected remaining demand -- a
      prior bootstrapped from the candidate densities walked so far,
      scaled to the unprocessed flow fraction -- which stops early
      slices from herding onto currently-cold channels.
    - **Adaptive walker budgets**: flows touching the running hot set
      (endpoints of near-``l_max`` channels) walk the full ``K``
      candidates; short or uncontested flows walk ``k_min``, and flows
      whose BFS field admits a *single shortest channel path*
      (:func:`_unique_channel_flows`) walk exactly one candidate with
      the slot machinery skipped. Budgeted slots are bit-identical to
      the full walk's slots, so the refinement sweep can re-walk any
      flow at full ``K`` and recover its current choice exactly.
    - **Cross-shard refinement**: a bounded sweep over the hottest
      channels -- flows crossing them (capped by ``refine_cap``;
      ``None`` auto-scales to ``max(300_000, F // 24)`` so the pool
      stays ~4% of the flows at 16^3 instead of a fixed 1.2%) are
      re-walked at full ``K`` and re-optimised with the array engine's
      exact own-load-removal local search, safe hot-set peel and
      sequential hot-channel walk, all snapshot-guarded so ``l_max``
      never regresses.

    Emits a :class:`CSRPathTable` whose VC hops are the winning
    candidates' BFS state paths (valid by construction); the balanced
    re-allocation stays in :func:`repro.core.vcalloc.allocate_vcs`.
    """
    ch = at.channels
    sg = at.state_graph()
    n, n_vc = ch.n_nodes, at.n_vc
    SEN = ch.n
    if k_min is None:
        k_min = max(2, K // 2)
    k_min = max(1, min(k_min, K))
    stats: dict = {"engine": "sharded", "rounds": rounds,
                   "shard_sources": shard_sources, "k_min": k_min}
    ar = np.arange
    if uniq_dp == "auto":
        # the kcap=1 uniq-flow DP pays off on faulted/irregular fabrics
        # (broken symmetry leaves many single-shortest-path flows) and
        # on small pods where its cost is trivial; on large healthy
        # tori it costs far more than the walk time it saves (101.6s
        # at 16^3 -- ROADMAP PR 6 note)
        has_dead = dead_channels is not None and len(dead_channels) > 0
        uniq_dp = bool(has_dead or n <= 512)
    stats["uniq_dp"] = bool(uniq_dp)

    # ---- phase 0: per-shard BFS + CSR skeleton ---------------------------
    t0 = time.time()
    n_shards = (n + shard_sources - 1) // shard_sources
    shard_dist: List[np.ndarray] = []
    shard_best: List[np.ndarray] = []
    shard_fb: List[np.ndarray] = []
    shard_fd: List[np.ndarray] = []
    shard_flen: List[np.ndarray] = []
    shard_uniq: List[np.ndarray] = []
    gid0 = np.zeros(n_shards + 1, np.int64)
    src_flow_counts = np.zeros(n, np.int64)
    unreachable = 0
    uniq_flows = 0
    t_nsp = 0.0
    for si in range(n_shards):
        s0 = si * shard_sources
        srcs = np.arange(s0, min(s0 + shard_sources, n))
        dist = state_bfs(at, srcs, dead_channels)
        best = node_distances(at, srcs, dist=dist)
        if dist_out is not None:
            dist_out[srcs] = dist.astype(dist_out.dtype)
        if best_out is not None:
            best_out[srcs] = best.astype(best_out.dtype)
        unreachable += int((best < 0).sum())
        fb, fd = np.nonzero(best > 0)
        flen = best[fb, fd].astype(np.int64)
        if len(flen) and int(flen.max()) > MAXHOP:
            raise ValueError(f"shortest path of {int(flen.max())} hops "
                             f"exceeds MAXHOP={MAXHOP}")
        if uniq_dp:
            t1 = time.time()
            uniq = _unique_channel_flows(sg, dist, best, n)[fb, fd]
            t_nsp += time.time() - t1
            uniq_flows += int(uniq.sum())
        else:
            uniq = np.zeros(len(fb), bool)
        shard_dist.append(dist)
        shard_best.append(best.astype(np.int16))
        shard_fb.append(fb.astype(np.int64))
        shard_fd.append(fd.astype(np.int64))
        shard_flen.append(flen)
        shard_uniq.append(uniq)
        gid0[si + 1] = gid0[si] + len(fb)
        src_flow_counts[srcs] = np.bincount(fb, minlength=len(srcs))
    F = int(gid0[-1])
    if refine_cap is None:
        refine_cap = max(300_000, F // 24)
    stats["refine_cap"] = int(refine_cap)
    stats["uniq_flows"] = uniq_flows
    stats["uniq_s"] = round(t_nsp, 3)
    flen_all = (np.concatenate(shard_flen) if F else
                np.zeros(0, np.int64)).astype(np.int64)
    dst_all = (np.concatenate(shard_fd) if F else
               np.zeros(0, np.int64)).astype(np.int32)
    src_indptr = np.zeros(n + 1, np.int64)
    np.cumsum(src_flow_counts, out=src_indptr[1:])
    hop_indptr = np.zeros(F + 1, np.int64)
    np.cumsum(flen_all, out=hop_indptr[1:])
    chan_flat = np.zeros(int(hop_indptr[-1]), np.int32)
    vc_flat = np.zeros(int(hop_indptr[-1]), np.int8)
    chosen_k = np.zeros(F, np.int8)
    stats["bfs_s"] = round(time.time() - t0, 3)
    csr = CSRPathTable(n, SEN, n_vc, src_indptr, dst_all, hop_indptr,
                       chan_flat, vc_flat)
    if F == 0:
        return RoutingResult(csr, np.zeros(SEN), 0.0, 0.0, unreachable,
                             stats=stats)

    # ---- streaming rounds: fused walk -> damped greedy -------------------
    loads = np.zeros(SEN + 1, np.int64)
    ehat = np.zeros(SEN + 1, np.float64)   # bootstrapped expected load
    ehat_flows = 0
    rng = np.random.default_rng(seed)
    perms = [rng.permutation(len(fb)) for fb in shard_fb]
    BIGF = float(np.int64(F) * max(int(flen_all.max()), 1) + 1)
    t_walk = t_greedy = 0.0
    done = 0
    k_full_flows = 0
    for r in range(rounds):
        for si in range(n_shards):
            fb, fd, flen = shard_fb[si], shard_fd[si], shard_flen[si]
            Fc = len(fb)
            idx = perms[si][Fc * r // rounds:Fc * (r + 1) // rounds]
            if not len(idx):
                continue
            t1 = time.time()
            s0 = si * shard_sources
            srcs = np.arange(s0, min(s0 + shard_sources, n))
            fl = flen[idx]
            # adaptive budget: full K for flows touching the hot set
            lm_run = int(loads[:SEN].max())
            if lm_run > 1:
                hotc = np.nonzero(
                    loads[:SEN] >= max(2, int(hot_load_frac * lm_run)))[0]
                hot_nodes = np.zeros(n, bool)
                hot_nodes[ch.src[hotc]] = True
                hot_nodes[ch.dst[hotc]] = True
                hot_f = hot_nodes[s0 + fb[idx]] | hot_nodes[fd[idx]]
            else:
                hot_f = np.zeros(len(idx), bool)
            uq = shard_uniq[si][idx]
            kcap = np.where(hot_f, K, k_min)
            kcap = np.minimum(kcap, np.where(fl == 1, 1,
                                             np.where(fl == 2, 2, K)))
            kcap = np.where(uq, 1, kcap)
            k_full_flows += int((kcap >= K).sum())
            chan_c, vc_c, kv = _walk_flows(sg, n, n_vc, SEN,
                                           shard_dist[si], shard_best[si],
                                           srcs, fb[idx], fd[idx], fl,
                                           kcap, K, uniq=uq)
            t_walk += time.time() - t1
            t1 = time.time()
            B, _, Lc = chan_c.shape
            # fold this slice into the expected-load prior (uniform over
            # each flow's valid slots), then damp the greedy with the
            # scaled unprocessed remainder. Round 1 alone is an unbiased
            # sample of every shard, so later rounds skip the scatter
            # (it costs ~F*K*L adds) and reuse the round-1 estimate.
            if r == 0 and damp > 0.0:
                w = kv / kv.sum(axis=1)[:, None]
                np.add.at(ehat, chan_c.ravel(),
                          np.repeat(w.ravel(), Lc))
                ehat[SEN] = 0.0
                ehat_flows += B
            scale = damp * (1.0 - done / F) * (F / max(ehat_flows, 1)) \
                if ehat_flows else 0.0
            chosen_local = np.zeros(B, np.int64)
            for j in range(0, B, block):
                bc = chan_c[j:j + block]
                l = loads[bc].astype(np.float64)
                if scale > 0.0:
                    l += scale * ehat[bc]
                cost = l.max(axis=2) * BIGF + l.sum(axis=2)
                cost[~kv[j:j + block]] = np.inf
                c = np.argmin(cost, axis=1)
                chosen_local[j:j + block] = c
                np.add.at(loads, bc[ar(len(c)), c].ravel(), 1)
                loads[SEN] = 0
            done += B
            # write winners straight into the CSR skeleton
            gid = gid0[si] + idx
            sel = chan_c[ar(B), chosen_local]
            selvc = vc_c[ar(B), chosen_local]
            pos = ar(Lc)[None, :]
            live = pos < fl[:, None]
            flat = (hop_indptr[gid][:, None] + pos)[live]
            chan_flat[flat] = sel[live]
            vc_flat[flat] = selvc[live]
            chosen_k[gid] = chosen_local
            t_greedy += time.time() - t1
    stats["walk_s"] = round(t_walk, 3)
    stats["greedy_s"] = round(t_greedy, 3)
    stats["k_full_flows"] = k_full_flows
    stats["greedy_l_max"] = int(loads[:SEN].max())

    # ---- cross-shard refinement over the hottest channels ----------------
    t0 = time.time()
    stats.update({"refine_pool": 0, "refine_moved": 0, "refine_iters": 0,
                  "refine_thresh": 0})
    if local_search_rounds > 0:
        flow_of_hop = np.repeat(ar(F, dtype=np.int64), flen_all)
        for _ in range(refine_iters):
            lm_before = int(loads[:SEN].max())
            pool, thresh = _hot_pool(loads, chan_flat, flow_of_hop,
                                     refine_cap, SEN)
            if not len(pool):
                break
            stats["refine_iters"] += 1
            stats["refine_pool"] = max(stats["refine_pool"], len(pool))
            stats["refine_thresh"] = thresh
            # re-walk the pool at full K (cached distances; budgeted
            # slots reproduce, so chosen_k still indexes correctly)
            seg = np.searchsorted(pool, gid0)
            parts = []
            Lp = 1
            for si in range(n_shards):
                a, b = seg[si], seg[si + 1]
                if a == b:
                    continue
                loc = pool[a:b] - gid0[si]
                s0 = si * shard_sources
                srcs = np.arange(s0, min(s0 + shard_sources, n))
                fl = shard_flen[si][loc]
                uq = shard_uniq[si][loc]
                cc, vv, kvp = _walk_flows(
                    sg, n, n_vc, SEN, shard_dist[si], shard_best[si],
                    srcs, shard_fb[si][loc], shard_fd[si][loc], fl,
                    np.where(uq, 1, K).astype(np.int64), K, uniq=uq)
                parts.append((cc, vv, kvp))
                Lp = max(Lp, cc.shape[2])

            def padc(a, fill):
                if a.shape[2] == Lp:
                    return a
                out = np.full(a.shape[:2] + (Lp,), fill, a.dtype)
                out[:, :, :a.shape[2]] = a
                return out

            candP = np.concatenate([padc(p[0], SEN) for p in parts])
            vcP = np.concatenate([padc(p[1], 0) for p in parts])
            kvP = np.concatenate([p[2] for p in parts])
            P = len(pool)
            pchosen = chosen_k[pool].astype(np.int64)
            old_pchosen = pchosen.copy()
            loads, pchosen = _refine_candidates(
                loads, candP, kvP, pchosen, rng, SEN, np.int64(BIGF),
                local_search_rounds, refine_block, lm_before)
            # write the moved flows back into the CSR arrays
            moved = np.nonzero(pchosen != old_pchosen)[0]
            stats["refine_moved"] += len(moved)
            if len(moved):
                mg = pool[moved]
                lens = flen_all[mg]
                sel = candP[moved, pchosen[moved]]
                selvc = vcP[moved, pchosen[moved]]
                pos = ar(Lp)[None, :]
                live = pos < lens[:, None]
                flat = (hop_indptr[mg][:, None] + pos)[live]
                chan_flat[flat] = sel[live]
                vc_flat[flat] = selvc[live]
                chosen_k[mg] = pchosen[moved]
            if int(loads[:SEN].max()) >= lm_before:
                break
    stats["refine_s"] = round(time.time() - t0, 3)

    loads_final = loads[:SEN].astype(np.float64)
    return RoutingResult(csr, loads_final, float(loads_final.max()),
                         float(flen_all.mean()), unreachable, stats=stats)


def _select_paths_reference(at: ATResult, K: int = 8, seed: int = 0,
                            dead_channels: Optional[set] = None,
                            local_search_rounds: int = 3) -> RoutingResult:
    """The seed's per-flow python greedy + hot-channel local search, driven
    by the per-source python BFS enumerator. Equivalence/benchmark oracle
    for the array engine."""
    ch = at.channels
    n = ch.n_nodes
    SEN = ch.n                      # sentinel channel id; its load stays 0
    f_cap = n * (n - 1)
    cand = np.full((f_cap, K, MAXHOP), SEN, np.int32)
    cand_len = np.zeros((f_cap, K), np.int32)
    cand_k = np.zeros(f_cap, np.int32)
    flow_src = np.zeros(f_cap, np.int32)
    flow_dst = np.zeros(f_cap, np.int32)
    F = 0
    unreachable = 0
    for s in range(n):
        per_dest = candidate_paths(at, s, K=K, dead_channels=dead_channels)
        for d in range(n):
            if d == s:
                continue
            plist = per_dest.get(d)
            if not plist:
                unreachable += 1
                continue
            flow_src[F] = s
            flow_dst[F] = d
            for i, p in enumerate(plist[:K]):
                L = min(len(p), MAXHOP)
                cand[F, i, :L] = p[:L]
                cand_len[F, i] = L
            cand_k[F] = len(plist[:K])
            F += 1
    cand = cand[:F]
    cand_len = cand_len[:F]
    cand_k = cand_k[:F]
    flow_src = flow_src[:F]
    flow_dst = flow_dst[:F]

    loads = np.zeros(SEN + 1, np.int64)
    chosen = np.zeros(F, np.int32)
    rng = np.random.default_rng(seed)
    valid = np.arange(K)[None, :] < cand_k[:, None]      # (F, K)
    BIG = np.int64(F) * MAXHOP + 1
    INF = np.iinfo(np.int64).max

    def flow_costs(f: int) -> np.ndarray:
        """Lexicographic (l_max, l_sum) per candidate, packed in one int."""
        l = loads[cand[f]]                               # (K, MAXHOP)
        cost = l.max(axis=1) * BIG + l.sum(axis=1)
        return np.where(valid[f], cost, INF)

    def add_path(f: int, i: int, sign: int) -> None:
        np.add.at(loads, cand[f, i], sign)
        loads[SEN] = 0

    order = np.arange(F)
    rng.shuffle(order)
    for f in order:
        best = int(np.argmin(flow_costs(f)))
        chosen[f] = best
        add_path(f, best, +1)

    for _ in range(local_search_rounds):
        improved = False
        hot = int(np.argmax(loads[:SEN]))
        sel = cand[np.arange(F), chosen]                 # (F, MAXHOP)
        hot_flows = np.nonzero((sel == hot).any(axis=1))[0]
        rng.shuffle(hot_flows)
        for f in hot_flows:
            add_path(f, chosen[f], -1)
            costs = flow_costs(f)
            best = int(np.argmin(costs))
            if costs[best] >= costs[chosen[f]]:
                best = int(chosen[f])
            if best != chosen[f]:
                improved = True
            chosen[f] = best
            add_path(f, best, +1)
            if loads[:SEN].max() < loads[hot]:
                break
        if not improved:
            break

    table = PathTable.empty(n, ch.n, at.n_vc)
    sel = cand[np.arange(F), chosen]                     # (F, MAXHOP)
    lengths = cand_len[np.arange(F), chosen]
    table.set_paths_batch(flow_src, flow_dst,
                          np.where(sel == SEN, -1, sel), lengths)
    loads_final = loads[:SEN].astype(np.float64)
    avg_hops = float(lengths.mean()) if F else 0.0
    return RoutingResult(table, loads_final, float(loads_final.max())
                         if F else 0.0, avg_hops, unreachable)


def load_lower_bound(topo: Topology) -> float:
    """L_max >= total shortest-path channel-visits / #channels."""
    from repro.core.topology import bfs_all_pairs
    d = bfs_all_pairs(topo)
    total = d[np.isfinite(d)].sum()
    return total / (2 * len(topo.edges()))


def turn_frequencies(table: PathTable) -> Dict[Tuple[int, int], float]:
    """Turn usage of a chosen routing (for the CPL prioritisation).

    Vectorised bigram count over the packed path array; the returned dict
    is keyed by turn (not by flow) and only feeds synthesis-time turn
    prioritisation -- an API edge, not the simulation hot path.
    """
    a = table.path[..., :-1].astype(np.int64)
    b = table.path[..., 1:].astype(np.int64)
    ok = (a >= 0) & (b >= 0)
    keys = a[ok] * table.n_ch + b[ok]
    uniq, counts = np.unique(keys, return_counts=True)
    return {(int(k // table.n_ch), int(k % table.n_ch)): float(c)
            for k, c in zip(uniq, counts)}
