"""Deadlock-free routing: allowed turns (AT) on the VC-labeled CDG,
candidate-path enumeration, and min-max-channel-load path selection.

Paper Section 5 / Algorithms 1-2. Deadlock freedom is decoupled from route
selection: a greedy allowed-turn construction keeps the channel dependency
graph acyclic (incremental cycle detection); all shortest deadlock-free
paths are enumerated per pair; a min-max load optimisation then picks one
static path per (src, dst). Turn prioritisation: APL / CPL / Random.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict, deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.pathtable import MAXHOP, PathTable
from repro.core.topology import Topology


# ---------------------------------------------------------------------------
# Channels
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Channels:
    """Directed channels of an undirected topology."""
    src: np.ndarray           # (C,)
    dst: np.ndarray           # (C,)
    color: np.ndarray         # OCS color or -1 (electrical)
    index: Dict[Tuple[int, int], int]

    @staticmethod
    def from_topology(topo: Topology) -> "Channels":
        e = topo.edges()
        col = topo.edge_colors()
        src = np.concatenate([e[:, 0], e[:, 1]])
        dst = np.concatenate([e[:, 1], e[:, 0]])
        color = np.concatenate([col, col])
        index = {(int(s), int(d)): i for i, (s, d) in
                 enumerate(zip(src, dst))}
        return Channels(src.astype(np.int32), dst.astype(np.int32),
                        color.astype(np.int32), index)

    @property
    def n(self) -> int:
        return len(self.src)

    def out_of(self, node: int) -> List[int]:
        return [self.index[(node, d)] for d in
                self.dst[self.src == node].tolist()]


# ---------------------------------------------------------------------------
# Incremental cycle detection (Pearce-Kelly) on the VC-labeled CDG
# ---------------------------------------------------------------------------


class IncrementalDAG:
    """Maintains a topological order under edge insertions; insertions that
    would create a cycle are rejected."""

    def __init__(self, n_nodes: int):
        self.n = n_nodes
        self.order = np.arange(n_nodes, dtype=np.int64)
        self.pos = np.arange(n_nodes, dtype=np.int64)
        self.adj: List[List[int]] = [[] for _ in range(n_nodes)]
        self.radj: List[List[int]] = [[] for _ in range(n_nodes)]

    def try_add(self, u: int, v: int) -> bool:
        if u == v:
            return False
        lb, ub = self.pos[v], self.pos[u]
        if lb > ub:                 # already consistent
            self.adj[u].append(v)
            self.radj[v].append(u)
            return True
        # discover affected region
        visited_f: List[int] = []
        seen_f = {v}
        stack = [v]
        ok = True
        while stack:
            x = stack.pop()
            visited_f.append(x)
            for y in self.adj[x]:
                if y == u:
                    ok = False
                    stack = []
                    break
                if self.pos[y] <= ub and y not in seen_f:
                    seen_f.add(y)
                    stack.append(y)
        if not ok:
            return False
        visited_b: List[int] = []
        seen_b = {u}
        stack = [u]
        while stack:
            x = stack.pop()
            visited_b.append(x)
            for y in self.radj[x]:
                if self.pos[y] >= lb and y not in seen_b:
                    seen_b.add(y)
                    stack.append(y)
        # reorder: backward region then forward region into the merged slots
        region = sorted(visited_b, key=lambda x: self.pos[x]) + \
            sorted(visited_f, key=lambda x: self.pos[x])
        slots = np.sort(self.pos[np.array(region)])
        for node, slot in zip(region, slots):
            self.pos[node] = slot
            self.order[slot] = node
        self.adj[u].append(v)
        self.radj[v].append(u)
        return True


# ---------------------------------------------------------------------------
# Allowed-turn construction (Algorithms 1 & 2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ATResult:
    channels: Channels
    n_vc: int
    allowed: set                       # ((c_in, v0), (c_out, v1))
    allowed_by_in: Dict[Tuple[int, int], List[Tuple[int, int]]]
    trees: List[List[int]]             # robust spanning trees (channel lists)

    def is_allowed(self, cin, v0, cout, v1) -> bool:
        return ((cin, v0), (cout, v1)) in self.allowed


def _state(c: int, v: int, n_vc: int) -> int:
    return c * n_vc + v


def spanning_tree_channels(topo: Topology, ch: Channels, root: int,
                           forbidden_colors: Optional[set] = None,
                           rng=None) -> Tuple[List[int], set]:
    """BFS tree; returns both directions of each tree edge + used colors."""
    adj = topo.adjacency()
    n = topo.n
    seen = np.zeros(n, bool)
    seen[root] = True
    q = deque([root])
    chans: List[int] = []
    used_colors: set = set()
    forbidden = forbidden_colors or set()
    while q:
        u = q.popleft()
        nbrs = list(adj[u])
        if rng is not None:
            rng.shuffle(nbrs)
        for v in nbrs:
            if seen[v]:
                continue
            c = ch.index[(u, v)]
            col = int(ch.color[c])
            if col >= 0 and col in forbidden:
                continue
            seen[v] = True
            used_colors.add(col) if col >= 0 else None
            chans.append(c)
            chans.append(ch.index[(v, u)])
            q.append(v)
    if not seen.all():
        return [], used_colors
    return chans, used_colors


def ocs_disjoint_spanning_trees(topo: Topology, ch: Channels
                                ) -> Optional[Tuple[List[int], List[int]]]:
    """Two spanning trees using disjoint OCS color sets (electrical edges
    may be shared -- they cannot fault). Concurrent BFS from hop-distance
    antipodes (paper 5.2)."""
    from repro.core.topology import bfs_all_pairs
    d = bfs_all_pairs(topo, sources=np.array([0]))[0]
    far = int(np.argmax(d))
    t0, colors0 = spanning_tree_channels(topo, ch, 0)
    if not t0:
        return None
    t1, colors1 = spanning_tree_channels(topo, ch, far,
                                         forbidden_colors=colors0)
    if not t1:
        # retry with a few random tie-breaks
        rng = np.random.default_rng(0)
        for _ in range(8):
            t0, colors0 = spanning_tree_channels(topo, ch, 0, rng=rng)
            t1, colors1 = spanning_tree_channels(
                topo, ch, far, forbidden_colors=colors0, rng=rng)
            if t1:
                break
    if not t1:
        return None
    return t0, t1


def _tree_turns(chans: List[int], ch: Channels) -> List[Tuple[int, int]]:
    """All non-reversing turns among a tree's channels (acyclic together)."""
    inset = set(chans)
    by_node = defaultdict(list)
    for c in chans:
        by_node[int(ch.dst[c])].append(c)
    out_by_node = defaultdict(list)
    for c in chans:
        out_by_node[int(ch.src[c])].append(c)
    turns = []
    for mid, ins in by_node.items():
        for cin in ins:
            for cout in out_by_node.get(mid, []):
                if ch.dst[cout] != ch.src[cin]:      # no u-turn
                    turns.append((cin, cout))
    return turns


def base_turns(ch: Channels) -> List[Tuple[int, int]]:
    out_by_node = defaultdict(list)
    for c in range(ch.n):
        out_by_node[int(ch.src[c])].append(c)
    turns = []
    for cin in range(ch.n):
        mid = int(ch.dst[cin])
        for cout in out_by_node[mid]:
            if int(ch.dst[cout]) != int(ch.src[cin]):
                turns.append((cin, cout))
    return turns


def prioritize_turns(turns, mode: str, topo: Topology, ch: Channels,
                     seed: int = 0, sym_perms: Optional[np.ndarray] = None):
    """APL: by frequency over all-shortest-path sets; CPL needs a chosen
    routing (caller re-invokes); Random: shuffled."""
    rng = np.random.default_rng(seed)
    if mode == "random":
        turns = list(turns)
        rng.shuffle(turns)
        return turns
    # count turn frequency across all shortest paths (APL) via BFS DAGs
    n = topo.n
    adj = topo.adjacency()
    freq = defaultdict(float)
    for s in range(n):
        dist = np.full(n, -1)
        dist[s] = 0
        q = deque([s])
        parents = defaultdict(list)
        while q:
            u = q.popleft()
            for v in adj[u]:
                if dist[v] < 0:
                    dist[v] = dist[u] + 1
                    q.append(v)
                if dist[v] == dist[u] + 1:
                    parents[v].append(u)
        # count path multiplicities through each turn
        npaths = np.zeros(n)
        npaths[s] = 1
        for u in np.argsort(dist):
            if dist[u] <= 0:
                continue
            for p in parents[u]:
                npaths[u] += npaths[p]
        for v in range(n):
            for p in parents[v]:
                for gp in parents[p]:
                    cin = ch.index[(gp, p)]
                    cout = ch.index[(p, v)]
                    freq[(cin, cout)] += npaths[gp]
    turns = sorted(turns, key=lambda t: -freq.get(t, 0.0))
    return turns


def allowed_turns(topo: Topology, n_vc: int = 2, priority: str = "apl",
                  robust: bool = False, seed: int = 0,
                  chosen_loads: Optional[Dict[Tuple[int, int], float]] = None
                  ) -> ATResult:
    """Algorithm 1. ``chosen_loads`` (turn -> frequency in a chosen routing)
    enables the CPL variant on a second invocation."""
    ch = Channels.from_topology(topo)
    n_states = ch.n * n_vc
    dag = IncrementalDAG(n_states)
    allowed: set = set()
    trees: List[List[int]] = []

    def add_turn(cin, v0, cout, v1) -> bool:
        key = ((cin, v0), (cout, v1))
        if key in allowed:
            return True
        if dag.try_add(_state(cin, v0, n_vc), _state(cout, v1, n_vc)):
            allowed.add(key)
            return True
        return False

    if robust:
        pair = ocs_disjoint_spanning_trees(topo, ch)
        if pair is not None:
            for vc, tree in zip((0, min(1, n_vc - 1)), pair):
                trees.append(tree)
                for (cin, cout) in _tree_turns(tree, ch):
                    add_turn(cin, vc, cout, vc)

    # routability seed: spanning tree on VC0 (Alg. 1 lines 9-10)
    t0, _ = spanning_tree_channels(topo, ch, 0)
    for (cin, cout) in _tree_turns(t0, ch):
        add_turn(cin, 0, cout, 0)

    turns = base_turns(ch)
    if chosen_loads is not None:
        turns = sorted(turns, key=lambda t: -chosen_loads.get(t, 0.0))
    else:
        turns = prioritize_turns(turns, priority, topo, ch, seed=seed)

    vc_orders = [(v, v) for v in range(n_vc)] + \
        [(v0, v1) for v0 in range(n_vc) for v1 in range(n_vc) if v0 != v1]
    # first pass: at most one VC-labeled instance per base turn
    for (cin, cout) in turns:
        for (v0, v1) in vc_orders:
            if add_turn(cin, v0, cout, v1):
                break
    # second pass: all admissible VC assignments
    for (cin, cout) in turns:
        for (v0, v1) in vc_orders:
            add_turn(cin, v0, cout, v1)

    by_in: Dict[Tuple[int, int], List[Tuple[int, int]]] = defaultdict(list)
    for (a, b) in allowed:
        by_in[a].append(b)
    return ATResult(ch, n_vc, allowed, dict(by_in), trees)


# ---------------------------------------------------------------------------
# Deadlock-free path enumeration + selection
# ---------------------------------------------------------------------------


def shortest_path_states(at: ATResult, source: int,
                         dead_channels: Optional[set] = None):
    """BFS over (channel, vc) states from `source`; returns dist + parents
    per state and best distance per destination node."""
    ch = at.channels
    n_vc = at.n_vc
    dead = dead_channels or set()
    dist: Dict[Tuple[int, int], int] = {}
    parents: Dict[Tuple[int, int], List[Tuple[int, int]]] = defaultdict(list)
    q = deque()
    for c in at.channels.out_of(source):
        if c in dead:
            continue
        for v in range(n_vc):
            st = (c, v)
            if st not in dist:
                dist[st] = 1
                q.append(st)
    while q:
        st = q.popleft()
        c, v = st
        for (c2, v2) in at.allowed_by_in.get(st, []):
            if c2 in dead:
                continue
            st2 = (c2, v2)
            if st2 not in dist:
                dist[st2] = dist[st] + 1
                parents[st2].append(st)
                q.append(st2)
            elif dist[st2] == dist[st] + 1:
                parents[st2].append(st)
    return dist, parents


def candidate_paths(at: ATResult, source: int, K: int = 8,
                    dead_channels: Optional[set] = None
                    ) -> Dict[int, List[Tuple[int, ...]]]:
    """Up to K shortest deadlock-free channel-paths per destination."""
    ch = at.channels
    dist, parents = shortest_path_states(at, source, dead_channels)
    best: Dict[int, int] = {}
    endstates: Dict[int, List[Tuple[int, int]]] = defaultdict(list)
    for (c, v), d in dist.items():
        node = int(ch.dst[c])
        if node == source:
            continue
        if node not in best or d < best[node]:
            best[node] = d
            endstates[node] = [(c, v)]
        elif d == best[node]:
            endstates[node].append((c, v))
    out: Dict[int, List[Tuple[int, ...]]] = {}
    for dest, sts in endstates.items():
        paths = []
        seen = set()
        stack = [(st, (st[0],)) for st in sts]
        while stack and len(paths) < K * 3:
            st, suffix = stack.pop()
            if dist[st] == 1:
                if suffix not in seen:
                    seen.add(suffix)
                    paths.append(suffix)
                continue
            for p in parents[st]:
                stack.append((p, (p[0],) + suffix))
        uniq = []
        useen = set()
        for p in paths:
            if p not in useen:
                useen.add(p)
                uniq.append(p)
            if len(uniq) >= K:
                break
        out[dest] = uniq
    return out


@dataclasses.dataclass
class RoutingResult:
    table: PathTable                                # packed (s, d) routes
    loads: np.ndarray                               # per-channel load
    l_max: float
    avg_hops: float
    unreachable: int

    @property
    def paths(self) -> Dict[Tuple[int, int], Tuple[int, ...]]:
        """Dict view, materialised on demand (API edge only -- the
        routing -> VC alloc -> simulation pipeline uses ``table``)."""
        return self.table.as_dicts()[0]


def select_paths(at: ATResult, K: int = 8, seed: int = 0,
                 dead_channels: Optional[set] = None,
                 local_search_rounds: int = 3) -> RoutingResult:
    """Min-max channel load selection: greedy + local search (the paper
    solves an ILP with Gurobi; we report the achieved L_max against the
    lower bound so the optimality gap is visible).

    Candidates are packed into flat ``(F, K, MAXHOP)`` arrays as they are
    enumerated; cost evaluation (max / sum of channel loads over each
    candidate) is a vectorised numpy gather, and the result is written
    straight into a :class:`PathTable` -- no per-pair dicts anywhere.
    """
    ch = at.channels
    n = int(max(ch.src.max(), ch.dst.max())) + 1
    SEN = ch.n                      # sentinel channel id; its load stays 0
    f_cap = n * (n - 1)
    cand = np.full((f_cap, K, MAXHOP), SEN, np.int32)
    cand_len = np.zeros((f_cap, K), np.int32)
    cand_k = np.zeros(f_cap, np.int32)
    flow_src = np.zeros(f_cap, np.int32)
    flow_dst = np.zeros(f_cap, np.int32)
    F = 0
    unreachable = 0
    for s in range(n):
        per_dest = candidate_paths(at, s, K=K, dead_channels=dead_channels)
        for d in range(n):
            if d == s:
                continue
            plist = per_dest.get(d)
            if not plist:
                unreachable += 1
                continue
            flow_src[F] = s
            flow_dst[F] = d
            for i, p in enumerate(plist[:K]):
                L = min(len(p), MAXHOP)
                cand[F, i, :L] = p[:L]
                cand_len[F, i] = L
            cand_k[F] = len(plist[:K])
            F += 1
    cand = cand[:F]
    cand_len = cand_len[:F]
    cand_k = cand_k[:F]
    flow_src = flow_src[:F]
    flow_dst = flow_dst[:F]

    loads = np.zeros(SEN + 1, np.int64)
    chosen = np.zeros(F, np.int32)
    rng = np.random.default_rng(seed)
    valid = np.arange(K)[None, :] < cand_k[:, None]      # (F, K)
    BIG = np.int64(F) * MAXHOP + 1
    INF = np.iinfo(np.int64).max

    def flow_costs(f: int) -> np.ndarray:
        """Lexicographic (l_max, l_sum) per candidate, packed in one int."""
        l = loads[cand[f]]                               # (K, MAXHOP)
        cost = l.max(axis=1) * BIG + l.sum(axis=1)
        return np.where(valid[f], cost, INF)

    def add_path(f: int, i: int, sign: int) -> None:
        np.add.at(loads, cand[f, i], sign)
        loads[SEN] = 0

    order = np.arange(F)
    rng.shuffle(order)
    for f in order:
        best = int(np.argmin(flow_costs(f)))
        chosen[f] = best
        add_path(f, best, +1)

    for _ in range(local_search_rounds):
        improved = False
        hot = int(np.argmax(loads[:SEN]))
        sel = cand[np.arange(F), chosen]                 # (F, MAXHOP)
        hot_flows = np.nonzero((sel == hot).any(axis=1))[0]
        rng.shuffle(hot_flows)
        for f in hot_flows:
            add_path(f, chosen[f], -1)
            costs = flow_costs(f)
            best = int(np.argmin(costs))
            if costs[best] >= costs[chosen[f]]:
                best = int(chosen[f])
            if best != chosen[f]:
                improved = True
            chosen[f] = best
            add_path(f, best, +1)
            if loads[:SEN].max() < loads[hot]:
                break
        if not improved:
            break

    table = PathTable.empty(n, ch.n, at.n_vc)
    sel = cand[np.arange(F), chosen]                     # (F, MAXHOP)
    lengths = cand_len[np.arange(F), chosen]
    table.set_paths_batch(flow_src, flow_dst,
                          np.where(sel == SEN, -1, sel), lengths)
    loads_final = loads[:SEN].astype(np.float64)
    avg_hops = float(lengths.mean()) if F else 0.0
    return RoutingResult(table, loads_final, float(loads_final.max())
                         if F else 0.0, avg_hops, unreachable)


def load_lower_bound(topo: Topology) -> float:
    """L_max >= total shortest-path channel-visits / #channels."""
    from repro.core.topology import bfs_all_pairs
    d = bfs_all_pairs(topo)
    total = d[np.isfinite(d)].sum()
    return total / (2 * len(topo.edges()))


def turn_frequencies(table: PathTable) -> Dict[Tuple[int, int], float]:
    """Turn usage of a chosen routing (for the CPL prioritisation).

    Vectorised bigram count over the packed path array; the returned dict
    is keyed by turn (not by flow) and only feeds synthesis-time turn
    prioritisation -- an API edge, not the simulation hot path.
    """
    a = table.path[..., :-1].astype(np.int64)
    b = table.path[..., 1:].astype(np.int64)
    ok = (a >= 0) & (b >= 0)
    keys = a[ok] * table.n_ch + b[ok]
    uniq, counts = np.unique(keys, return_counts=True)
    return {(int(k // table.n_ch), int(k % table.n_ch)): float(c)
            for k, c in zip(uniq, counts)}
