"""Core neural layers: norms, RoPE, GQA attention, MLPs, MoE, Mamba2-SSD.

Everything is written as pure functions over parameter pytrees so that layer
stacks can be scanned (params stacked on a leading layer axis) and the whole
model stays compile-friendly for the 512-device dry-run.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.parallel.api import wsc

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# Initialisation helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype=jnp.bfloat16, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x, weight, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def layernorm(x, weight, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def apply_norm(kind, x, weight):
    return rmsnorm(x, weight) if kind == "rmsnorm" else layernorm(x, weight)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, hd), positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, causal, flash-style blocked online softmax in pure jnp).
# The Pallas kernel in repro.kernels.flash_attention targets the same math;
# the jnp path is what the dry-run lowers (CPU container, TPU is the target).
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def gqa_attention(q, k, v, *, causal: bool = True, q_offset=0, kv_len=None,
                  block: int = 1024, unroll: bool = False):
    """Blocked causal GQA attention.

    q: (B, Sq, Hq, hd); k, v: (B, Skv, Hkv, hd); Hq % Hkv == 0.
    q_offset: absolute position of q[0] (for decode/chunked prefill).
    kv_len: number of valid kv positions (<= Skv), static or traced scalar.
    """
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    qg = (q * scale).reshape(B, Sq, Hkv, rep, hd)

    blk = min(block, Skv)
    while Skv % blk:
        blk //= 2
    nb = Skv // blk
    kb = k.reshape(B, nb, blk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, blk, Hkv, hd).transpose(1, 0, 2, 3, 4)

    qpos = q_offset + jnp.arange(Sq)
    valid_len = Skv if kv_len is None else kv_len

    def body(carry, inp):
        o, m, l = carry
        kblk, vblk, bidx = inp
        kpos = bidx * blk + jnp.arange(blk)
        s = jnp.einsum("bqgrd,bkgd->bgrqk", qg, kblk.astype(qg.dtype),
                       preferred_element_type=jnp.float32)
        mask = kpos[None, :] < valid_len
        if causal:
            mask = mask & (qpos[:, None] >= kpos[None, :])
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bgrqk,bkgd->bqgrd", p.astype(vblk.dtype), vblk,
                        preferred_element_type=jnp.float32)
        o = o * corr.transpose(0, 3, 1, 2)[..., None] + pv
        return (o, m_new, l), None

    o0 = jnp.zeros((B, Sq, Hkv, rep, hd), jnp.float32)
    m0 = jnp.full((B, Hkv, rep, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, rep, Sq), jnp.float32)
    if unroll:  # loop-free lowering for dry-run flop accounting
        carry = (o0, m0, l0)
        for i in range(nb):
            carry, _ = body(carry, (kb[i], vb[i], jnp.int32(i)))
        o, m, l = carry
    else:
        (o, m, l), _ = jax.lax.scan(body, (o0, m0, l0),
                                    (kb, vb, jnp.arange(nb)))
    o = o / jnp.maximum(l.transpose(0, 3, 1, 2), 1e-30)[..., None]
    return o.reshape(B, Sq, Hq, hd).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, pos):
    """One-step decode. q: (B, 1, Hq, hd); caches: (B, S, Hkv, hd); pos: () int."""
    B, _, Hq, hd = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    rep = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    qg = (q * scale).reshape(B, Hkv, rep, hd)
    s = jnp.einsum("bgrd,bkgd->bgrk", qg, k_cache.astype(qg.dtype),
                   preferred_element_type=jnp.float32)
    mask = jnp.arange(S)[None, None, None, :] <= pos
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrk,bkgd->bgrd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, Hq, hd).astype(q.dtype)


# --- attention block params -------------------------------------------------


def init_attention(key, cfg) -> Params:
    D, Hq, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 5)
    p = {
        "wq": dense_init(ks[0], (D, Hq * hd)),
        "wk": dense_init(ks[1], (D, Hkv * hd)),
        "wv": dense_init(ks[2], (D, Hkv * hd)),
        "wo": dense_init(ks[3], (Hq * hd, D)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hq * hd,), jnp.bfloat16)
        p["bk"] = jnp.zeros((Hkv * hd,), jnp.bfloat16)
        p["bv"] = jnp.zeros((Hkv * hd,), jnp.bfloat16)
    return p


def attn_qkv(p, x, cfg, positions):
    B, S, _ = x.shape
    Hq, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, Hq, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_block(p, x, cfg, positions):
    """Full (training/prefill) attention sub-layer, returns (out, (k, v))."""
    q, k, v = attn_qkv(p, x, cfg, positions)
    o = gqa_attention(q, k, v, causal=cfg.causal, block=cfg.attn_block,
                      unroll=cfg.unroll)
    B, S, _ = x.shape
    return o.reshape(B, S, -1) @ p["wo"], (k, v)


def attention_decode(p, x, cfg, cache, pos):
    """x: (B, 1, D). cache: dict(k, v) with (B, S, Hkv, hd). Returns out, cache."""
    q, k, v = attn_qkv(p, x, cfg, positions=pos[None] if jnp.ndim(pos) == 0 else pos)
    z = jnp.zeros_like(pos)
    kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (z, pos, z, z))
    vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (z, pos, z, z))
    o = decode_attention(q, kc, vc, pos)
    B = x.shape[0]
    return o.reshape(B, 1, -1) @ p["wo"], {"k": kc, "v": vc}


def cross_attention(p, x, enc_kv, cfg):
    """Encoder-decoder cross attention (non-causal over encoder states)."""
    B, S, _ = x.shape
    Hq, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, Hq, hd)
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(Hq, hd)
    k, v = enc_kv
    o = gqa_attention(q, k, v, causal=False, block=cfg.attn_block,
                      unroll=cfg.unroll)
    return o.reshape(B, S, -1) @ p["wo"]


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w1": dense_init(ks[0], (d_model, d_ff)),
        "w3": dense_init(ks[1], (d_model, d_ff)),
        "w2": dense_init(ks[2], (d_ff, d_model)),
    }


def glu_mlp(p, x, act: str = "silu"):
    h = x @ p["w1"]
    g = x @ p["w3"]
    h = (jax.nn.gelu(h) if act == "gelu" else jax.nn.silu(h)) * g
    return h @ p["w2"]


# ---------------------------------------------------------------------------
# Mixture of Experts (token-dropping, capacity-based, EP-shardable)
# ---------------------------------------------------------------------------


def init_moe(key, cfg) -> Params:
    D, F = cfg.d_model, cfg.moe_d_ff
    E = cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (D, E), dtype=jnp.float32),
        "w1": dense_init(ks[1], (E, D, F)),
        "w3": dense_init(ks[2], (E, D, F)),
        "w2": dense_init(ks[3], (E, F, D)),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], D, F * cfg.n_shared_experts)
    return p


def _dp_shards() -> int:
    from repro.parallel.api import get_mesh
    mesh = get_mesh()
    if mesh is None:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("pod", 1) * sizes.get("data", 1)


def moe_ffn_local(p, x, cfg):
    """Hierarchical MoE dispatch (perf iteration H1, EXPERIMENTS section
    Perf): token sort / capacity scatter are performed *per data shard* so
    no global argsort or cross-shard scatter is lowered; the only
    cross-device movement is the (dp, E, C, D) buffer resharding from
    batch-major to expert-major -- a clean all-to-all, exactly the traffic
    TONS optimizes the fabric for."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    dp = _dp_shards()
    if dp <= 1 or T % dp or (T // dp) % 1:
        return moe_ffn(p, x, cfg)
    Tl = T // dp
    TKl = Tl * K
    cf = 1.0 if cfg.opt_moe_cf1 else cfg.capacity_factor
    C = max(8, int(Tl * K * cf / E))

    xf = x.reshape(dp, Tl, D)
    xf = wsc(xf, ("pod", "data"), None, None)
    logits = jnp.einsum("gtd,de->gte", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)                     # (dp, Tl, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    fe = eidx.reshape(dp, TKl)
    ft = jnp.broadcast_to(jnp.repeat(jnp.arange(Tl), K), (dp, TKl))
    fg = gate.reshape(dp, TKl)
    order = jnp.argsort(fe, axis=1)                          # local sorts
    se = jnp.take_along_axis(fe, order, 1)
    st = jnp.take_along_axis(ft, order, 1)
    sg = jnp.take_along_axis(fg, order, 1)
    starts = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(E)))(se)
    pos = jnp.arange(TKl)[None, :] - jnp.take_along_axis(starts, se, 1)
    keep = pos < C
    posc = jnp.where(keep, pos, 0)
    gidx = jax.lax.broadcasted_iota(jnp.int32, (dp, TKl), 0)

    buf = jnp.zeros((dp, E, C, D), x.dtype)
    upd = jnp.where(keep[..., None],
                    jnp.take_along_axis(xf, st[..., None], axis=1), 0)
    buf = buf.at[gidx, se, posc].add(upd)
    buf = wsc(buf, ("pod", "data"), "model", None, None)

    h = jnp.einsum("gecd,edf->gecf", buf, p["w1"])
    g = jnp.einsum("gecd,edf->gecf", buf, p["w3"])
    h = (jax.nn.gelu(h) if cfg.act == "gelu" else jax.nn.silu(h)) * g
    out = jnp.einsum("gecf,efd->gecd", h, p["w2"])
    out = wsc(out, ("pod", "data"), "model", None, None)

    tok = out[gidx, se, posc]
    tok = jnp.where(keep[..., None], tok, 0) * sg[..., None].astype(x.dtype)
    # bf16 combine: <= top_k summands per token, safe at half precision
    y = jnp.zeros((dp, Tl, D), x.dtype)
    y = y.at[gidx, st].add(tok)
    y = y.reshape(B, S, D)

    if cfg.n_shared_experts:
        y = y + glu_mlp(p["shared"], x.reshape(B, S, D), cfg.act)

    me = probs.mean((0, 1))
    ce = jnp.zeros((E,), jnp.float32).at[fe.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)
    return y, aux


def moe_ffn(p, x, cfg):
    """Top-k capacity-based MoE. x: (B, S, D) -> (B, S, D).

    Tokens are sorted by expert assignment, scattered into a per-expert
    capacity buffer (E, C, D) that is sharding-constrained onto the expert-
    parallel mesh axis -- under pjit this induces the all-to-all dispatch the
    paper's all-to-all traffic analysis targets.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    TK = T * K
    C = max(8, int(T * K * cfg.capacity_factor / E))

    xf = x.reshape(T, D)
    logits = (xf.astype(jnp.float32)) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)                      # (T, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    fe = eidx.reshape(TK)
    ft = jnp.repeat(jnp.arange(T), K)
    fg = gate.reshape(TK)
    order = jnp.argsort(fe)
    se, st, sg = fe[order], ft[order], fg[order]
    starts = jnp.searchsorted(se, jnp.arange(E))              # (E,)
    pos_in_e = jnp.arange(TK) - starts[se]
    keep = pos_in_e < C

    buf = jnp.zeros((E, C, D), x.dtype)
    buf = buf.at[se, jnp.where(keep, pos_in_e, 0)].add(
        jnp.where(keep[:, None], xf[st], 0))
    buf = wsc(buf, "model", ("pod", "data"), None)

    h = jnp.einsum("ecd,edf->ecf", buf, p["w1"])
    g = jnp.einsum("ecd,edf->ecf", buf, p["w3"])
    h = (jax.nn.gelu(h) if cfg.act == "gelu" else jax.nn.silu(h)) * g
    out = jnp.einsum("ecf,efd->ecd", h, p["w2"])
    out = wsc(out, "model", ("pod", "data"), None)

    tok_out = out[se, jnp.where(keep, pos_in_e, 0)]
    tok_out = jnp.where(keep[:, None], tok_out, 0) * sg[:, None].astype(x.dtype)
    y = jnp.zeros((T, D), jnp.float32).at[st].add(tok_out.astype(jnp.float32))
    y = y.astype(x.dtype)

    if cfg.n_shared_experts:
        y = y + glu_mlp(p["shared"], xf, cfg.act)

    # load-balancing aux loss (Switch-style), returned via side channel
    me = probs.mean(0)
    ce = jnp.zeros((E,), jnp.float32).at[fe].add(1.0) / TK
    aux = E * jnp.sum(me * ce)
    return y.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# Mamba2 (SSD) -- chunked training form + O(1) recurrent decode form.
# Adapted to TPU: the chunked algorithm is pure matmuls (MXU-friendly);
# chunk size defaults to 128 to match MXU tiling.
# ---------------------------------------------------------------------------


def init_mamba(key, cfg) -> Params:
    D = cfg.d_model
    d_in = cfg.ssm_expand * D
    H = d_in // cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state
    conv_dim = d_in + 2 * G * N
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], (D, 2 * d_in + 2 * G * N + H)),
        "conv_w": dense_init(ks[1], (conv_dim, cfg.ssm_conv), scale=0.5),
        "conv_b": jnp.zeros((conv_dim,), jnp.bfloat16),
        "dt_bias": jnp.log(jnp.exp(
            jnp.linspace(1e-3, 0.1, H).astype(jnp.float32)) - 1.0 + 1e-9),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "norm_w": jnp.zeros((d_in,), jnp.bfloat16),
        "out_proj": dense_init(ks[5], (d_in, D)),
    }


def _causal_conv(u, w, b):
    """Depthwise causal conv in f32. u: (B, S, C); w: (C, K).

    The accumulation is kept in float32 so that the prefill (full-sequence)
    and decode (single-step window) lowerings agree bitwise-closely; in bf16
    the two orderings drift ~0.5% per layer, which compounds across deep
    hybrid stacks and flips MoE expert selections during decode.
    """
    K = w.shape[1]
    u = u.astype(jnp.float32)
    w = w.astype(jnp.float32)
    acc = u * w[:, K - 1]
    for i in range(1, K):
        shifted = jnp.pad(u, ((0, 0), (i, 0), (0, 0)))[:, :-i or None][:, :u.shape[1]]
        acc = acc + shifted * w[:, K - 1 - i]
    return acc + b.astype(jnp.float32)


def _mamba_proj(p, x, cfg):
    D = cfg.d_model
    d_in = cfg.ssm_expand * D
    G, N = cfg.ssm_groups, cfg.ssm_state
    H = d_in // cfg.ssm_head_dim
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt_raw = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * G * N], axis=-1)
    return z, xbc, dt_raw, (d_in, G, N, H)


def ssd_chunked(xh, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD. xh: (B, L, H, Pd); dt: (B, L, H); A: (H,) (negative);
    Bm, Cm: (B, L, G, N). Returns y: (B, L, H, Pd) and final state (B, H, Pd, N)."""
    b, l_orig, h, pd = xh.shape
    dt = dt.astype(jnp.float32)
    A = A.astype(jnp.float32)
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    pad = (-l_orig) % chunk
    if pad:  # zero-pad: dt=0 makes padded steps identity on the state
        zp = lambda a: jnp.pad(a, [(0, 0), (0, pad)] +
                               [(0, 0)] * (a.ndim - 2))
        xh, dt, Bm, Cm = zp(xh), zp(dt), zp(Bm), zp(Cm)
    l = l_orig + pad
    nc = l // chunk

    xc = xh.reshape(b, nc, chunk, h, pd).astype(jnp.float32)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = Bm.reshape(b, nc, chunk, g, n).astype(jnp.float32)
    Cc = Cm.reshape(b, nc, chunk, g, n).astype(jnp.float32)

    dA = dtc * A  # (b, nc, q, h), negative
    dA_cs = jnp.cumsum(dA, axis=2)

    # intra-chunk: M[i,j] = C_i . B_j * exp(dA_cs[i]-dA_cs[j]) * dt_j  (i>=j)
    Bh = jnp.repeat(Bc, rep, axis=3)  # (b,nc,q,h,n)
    Ch = jnp.repeat(Cc, rep, axis=3)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Ch, Bh)
    ddec = dA_cs[:, :, :, None, :].transpose(0, 1, 4, 2, 3) \
        - dA_cs[:, :, None, :, :].transpose(0, 1, 4, 2, 3)  # (b,nc,h,q,k)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    M = jnp.where(tri, scores * jnp.exp(ddec), 0.0)
    M = M * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]  # multiply dt_k
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", M, xc)

    # chunk-end states: S_c = sum_j exp(dA_cs[-1]-dA_cs[j]) dt_j B_j (x) x_j
    dec_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs) * dtc  # (b,nc,q,h)
    S = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn", dec_end, Bh, xc)

    # inter-chunk recurrence over nc: parallel (log-depth) associative scan
    # -- TPU-native replacement for the sequential chunk loop, and loop-free
    # so HLO cost analysis sees the true op counts.
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])  # (b, nc, h)
    dec = chunk_decay[:, :, :, None, None]

    def combine(a, bseg):
        da, sa = a
        db, sb = bseg
        return da * db, sa * db + sb

    _, s_incl = jax.lax.associative_scan(combine, (dec, S), axis=1)
    s_final = s_incl[:, -1]
    s_prevs = jnp.concatenate(
        [jnp.zeros_like(s_incl[:, :1]), s_incl[:, :-1]], axis=1)

    y_inter = jnp.einsum("bcqhn,bcqh,bchpn->bcqhp",
                         Ch, jnp.exp(dA_cs), s_prevs)
    y = (y_intra + y_inter).reshape(b, l, h, pd)[:, :l_orig]
    return y, s_final


def ssd_sequential(xh, dt, A, Bm, Cm):
    """Step-by-step oracle for tests. Same signature as ssd_chunked."""
    b, l, h, pd = xh.shape
    dt = dt.astype(jnp.float32)
    A = A.astype(jnp.float32)
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g

    def step(state, inp):
        x_t, dt_t, B_t, C_t = inp  # (b,h,p), (b,h), (b,g,n), (b,g,n)
        Bh = jnp.repeat(B_t, rep, axis=1)
        Ch = jnp.repeat(C_t, rep, axis=1)
        decay = jnp.exp(dt_t * A)  # (b,h)
        state = state * decay[:, :, None, None] + \
            (dt_t[:, :, None] * x_t)[..., None] * Bh[:, :, None, :]
        y_t = jnp.einsum("bhpn,bhn->bhp", state, Ch)
        return state, y_t

    s0 = jnp.zeros((b, h, pd, n), jnp.float32)
    xs = (xh.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2),
          Bm.transpose(1, 0, 2, 3).astype(jnp.float32),
          Cm.transpose(1, 0, 2, 3).astype(jnp.float32))
    s_final, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), s_final


def mamba_block(p, x, cfg, return_cache: bool = False):
    """Training/prefill form. x: (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    z, xbc_raw, dt_raw, (d_in, G, N, H) = _mamba_proj(p, x, cfg)
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    xbc = jax.nn.silu(xbc)
    xs, Bm, Cm = jnp.split(xbc, [d_in, d_in + G * N], axis=-1)
    xh = xs.reshape(B, S, H, cfg.ssm_head_dim)
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    chunk = min(cfg.ssm_chunk, S)
    y, s_final = ssd_chunked(xh, dt, A, Bm, Cm, chunk)
    y = y + xh.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(B, S, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"])
    out = y @ p["out_proj"]
    if return_cache:
        K = cfg.ssm_conv
        conv_state = xbc_raw[:, S - (K - 1):, :]
        return out, {"conv": conv_state, "ssm": s_final}
    return out


def mamba_decode(p, x, cfg, cache):
    """One-step decode. x: (B, 1, D); cache: {conv: (B, K-1, C), ssm: (B,H,P,N)}."""
    B = x.shape[0]
    z, xbc, dt_raw, (d_in, G, N, H) = _mamba_proj(p, x, cfg)
    xbc = xbc[:, 0]  # (B, C)
    conv_state = cache["conv"]  # (B, K-1, C)
    window = jnp.concatenate([conv_state, xbc[:, None]], axis=1)  # (B, K, C)
    # f32 to match _causal_conv's prefill accumulation (see note there)
    conv_out = jnp.einsum("bkc,ck->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) \
        + p["conv_b"].astype(jnp.float32)
    conv_out = jax.nn.silu(conv_out)
    new_conv = window[:, 1:]
    xs, Bm, Cm = jnp.split(conv_out, [d_in, d_in + G * N], axis=-1)
    xh = xs.reshape(B, H, cfg.ssm_head_dim).astype(jnp.float32)
    Bm = jnp.repeat(Bm.reshape(B, G, N), H // G, axis=1).astype(jnp.float32)
    Cm = jnp.repeat(Cm.reshape(B, G, N), H // G, axis=1).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A)
    state = cache["ssm"] * decay[:, :, None, None] + \
        (dt[:, :, None] * xh)[..., None] * Bm[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", state, Cm) + xh * p["D"][:, None]
    y = y.reshape(B, 1, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"])
    return y @ p["out_proj"], {"conv": new_conv, "ssm": state}
