"""Encoder-decoder backbone (seamless-m4t style, audio frontend stubbed).

The encoder consumes precomputed frame embeddings (B, S_enc, D) -- per the
assignment the modality frontend is a stub supplied by ``input_specs``.
The decoder is a causal LM with cross-attention into encoder states.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.lm import cross_entropy, scan_blocks
from repro.parallel.api import wsc

Params = Dict[str, Any]


def _init_enc_layer(key, cfg) -> Params:
    ks = jax.random.split(key, 2)
    D = cfg.d_model
    return {
        "ln1": jnp.zeros((D,), jnp.bfloat16),
        "attn": L.init_attention(ks[0], cfg),
        "ln2": jnp.zeros((D,), jnp.bfloat16),
        "mlp": L.init_mlp(ks[1], D, cfg.d_ff),
    }


def _init_dec_layer(key, cfg) -> Params:
    ks = jax.random.split(key, 3)
    D = cfg.d_model
    return {
        "ln1": jnp.zeros((D,), jnp.bfloat16),
        "attn": L.init_attention(ks[0], cfg),
        "ln_x": jnp.zeros((D,), jnp.bfloat16),
        "xattn": L.init_attention(ks[1], cfg),
        "ln2": jnp.zeros((D,), jnp.bfloat16),
        "mlp": L.init_mlp(ks[2], D, cfg.d_ff),
    }


def init_params(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 6)
    D, V = cfg.d_model, cfg.vocab
    enc = [_init_enc_layer(k, cfg)
           for k in jax.random.split(ks[0], cfg.enc_layers)]
    dec = [_init_dec_layer(k, cfg)
           for k in jax.random.split(ks[1], cfg.dec_layers)]
    return {
        "enc_blocks": jax.tree.map(lambda *a: jnp.stack(a), *enc),
        "enc_ln_f": jnp.zeros((D,), jnp.bfloat16),
        "dec_blocks": jax.tree.map(lambda *a: jnp.stack(a), *dec),
        "emb": L.dense_init(ks[2], (V, D), scale=0.02),
        "ln_f": jnp.zeros((D,), jnp.bfloat16),
        "lm_head": L.dense_init(ks[3], (D, V)),
    }


def encode(cfg, params, frames):
    """frames: (B, S_enc, D) -> encoder states."""
    x = frames.astype(jnp.bfloat16)
    x = wsc(x, ("pod", "data"), None, None)
    positions = jnp.arange(x.shape[1])

    def body(x, lp):
        h = L.apply_norm(cfg.norm, x, lp["ln1"])
        q, k, v = L.attn_qkv(lp["attn"], h, cfg, positions)
        a = L.gqa_attention(q, k, v, causal=False, block=cfg.attn_block,
                            unroll=cfg.unroll)
        B, S, _ = x.shape
        x = x + a.reshape(B, S, -1) @ lp["attn"]["wo"]
        h = L.apply_norm(cfg.norm, x, lp["ln2"])
        x = x + L.glu_mlp(lp["mlp"], h, cfg.act)
        return x, None

    x, _ = scan_blocks(body, x, params["enc_blocks"], unroll=cfg.unroll,
                       remat=cfg.remat)
    return L.apply_norm(cfg.norm, x, params["enc_ln_f"])


def _enc_kv(lp, enc_x, cfg):
    B, S, _ = enc_x.shape
    k = (enc_x @ lp["xattn"]["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = (enc_x @ lp["xattn"]["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qkv_bias:
        k = k + lp["xattn"]["bk"].reshape(cfg.n_kv_heads, cfg.head_dim)
        v = v + lp["xattn"]["bv"].reshape(cfg.n_kv_heads, cfg.head_dim)
    return k, v


def _dec_layer(cfg, lp, x, enc_x, positions, want_cache=False):
    h = L.apply_norm(cfg.norm, x, lp["ln1"])
    a, (k, v) = L.attention_block(lp["attn"], h, cfg, positions)
    x = x + a
    h = L.apply_norm(cfg.norm, x, lp["ln_x"])
    ek, ev = _enc_kv(lp, enc_x, cfg)
    x = x + L.cross_attention(lp["xattn"], h, (ek, ev), cfg)
    h = L.apply_norm(cfg.norm, x, lp["ln2"])
    x = x + L.glu_mlp(lp["mlp"], h, cfg.act)
    cache = {"k": k, "v": v, "ek": ek, "ev": ev} if want_cache else None
    return x, cache


def forward(cfg, params, frames, tokens):
    """Teacher-forced decoder logits."""
    enc_x = encode(cfg, params, frames)
    x = jnp.take(params["emb"], tokens, axis=0).astype(jnp.bfloat16)
    x = wsc(x, ("pod", "data"), None, None)
    positions = jnp.arange(tokens.shape[1])

    def body(x, lp):
        x, _ = _dec_layer(cfg, lp, x, enc_x, positions)
        return x, None

    x, _ = scan_blocks(body, x, params["dec_blocks"], unroll=cfg.unroll,
                       remat=cfg.remat)
    x = L.apply_norm(cfg.norm, x, params["ln_f"])
    logits = x @ params["lm_head"]
    return wsc(logits, ("pod", "data"), None, "model")


def loss_fn(cfg, params, batch, aux_weight: float = 0.0):
    logits = forward(cfg, params, batch["frames"], batch["tokens"])
    return cross_entropy(logits, batch["labels"])


def prefill(cfg, params, frames, tokens, cache_len: Optional[int] = None):
    enc_x = encode(cfg, params, frames)
    x = jnp.take(params["emb"], tokens, axis=0).astype(jnp.bfloat16)
    positions = jnp.arange(tokens.shape[1])
    cache_len = cache_len or tokens.shape[1]

    def body(x, lp):
        x, c = _dec_layer(cfg, lp, x, enc_x, positions, want_cache=True)
        pad = cache_len - c["k"].shape[1]
        if pad > 0:
            c["k"] = jnp.pad(c["k"], ((0, 0), (0, pad), (0, 0), (0, 0)))
            c["v"] = jnp.pad(c["v"], ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x, c

    x, caches = scan_blocks(body, x, params["dec_blocks"],
                            unroll=cfg.unroll)
    x = L.apply_norm(cfg.norm, x[:, -1:, :], params["ln_f"])
    return x @ params["lm_head"], {"dec_blocks": caches}


def empty_cache(cfg, B, S_dec, S_enc):
    hd, kv = cfg.head_dim, cfg.n_kv_heads
    one = {
        "k": jnp.zeros((B, S_dec, kv, hd), jnp.bfloat16),
        "v": jnp.zeros((B, S_dec, kv, hd), jnp.bfloat16),
        "ek": jnp.zeros((B, S_enc, kv, hd), jnp.bfloat16),
        "ev": jnp.zeros((B, S_enc, kv, hd), jnp.bfloat16),
    }
    return {"dec_blocks": jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.dec_layers,) + a.shape), one)}


def decode_step(cfg, params, caches, token, pos):
    x = jnp.take(params["emb"], token, axis=0).astype(jnp.bfloat16)

    def body(x, inp):
        lp, cache = inp
        h = L.apply_norm(cfg.norm, x, lp["ln1"])
        a, new_sa = L.attention_decode(lp["attn"], h, cfg,
                                       {"k": cache["k"], "v": cache["v"]},
                                       pos)
        x = x + a
        h = L.apply_norm(cfg.norm, x, lp["ln_x"])
        B = x.shape[0]
        q = (h @ lp["xattn"]["wq"]).reshape(B, 1, cfg.n_heads, cfg.head_dim)
        o = L.decode_attention(q, cache["ek"], cache["ev"],
                               cache["ek"].shape[1] - 1)
        x = x + o.reshape(B, 1, -1) @ lp["xattn"]["wo"]
        h = L.apply_norm(cfg.norm, x, lp["ln2"])
        x = x + L.glu_mlp(lp["mlp"], h, cfg.act)
        new = dict(cache)
        new.update(new_sa)
        return x, new

    x, new_caches = scan_blocks(
        body, x, (params["dec_blocks"], caches["dec_blocks"]),
        unroll=cfg.unroll)
    x = L.apply_norm(cfg.norm, x, params["ln_f"])
    return x @ params["lm_head"], {"dec_blocks": new_caches}
