"""Decoder-only LM assembly (dense / moe / ssm / hybrid families).

Parameters are stacked over a leading layer axis and the stack is applied
with ``lax.scan`` (rematerialised) so that HLO size is independent of depth
-- essential for the 64-compile dry-run sweep.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.parallel.api import wsc

Params = Dict[str, Any]


def _remat_policy(cfg):
    if getattr(cfg, "opt_remat_dots", False):
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return None


# ---------------------------------------------------------------------------
# Per-layer init / structure
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ModelConfig, mixer: str, ffn: Optional[str]) -> Params:
    ks = jax.random.split(key, 4)
    D = cfg.d_model
    p: Params = {"ln1": jnp.zeros((D,), jnp.bfloat16)}
    if mixer == "attn":
        p["attn"] = L.init_attention(ks[0], cfg)
    else:
        p["mamba"] = L.init_mamba(ks[0], cfg)
    if ffn is not None:
        p["ln2"] = jnp.zeros((D,), jnp.bfloat16)
        if ffn == "moe":
            p["moe"] = L.init_moe(ks[1], cfg)
        else:
            p["mlp"] = L.init_mlp(ks[1], D, cfg.d_ff)
    return p


def _layer_fwd(cfg, mixer, ffn, p, x, positions, want_cache=False):
    h = L.apply_norm(cfg.norm, x, p["ln1"])
    cache = None
    if mixer == "attn":
        a, (k, v) = L.attention_block(p["attn"], h, cfg, positions)
        if want_cache:
            cache = {"k": k, "v": v}
    else:
        if want_cache:
            a, cache = L.mamba_block(p["mamba"], h, cfg, return_cache=True)
        else:
            a = L.mamba_block(p["mamba"], h, cfg)
    x = x + a
    aux = jnp.zeros((), jnp.float32)
    if ffn is not None:
        h = L.apply_norm(cfg.norm, x, p["ln2"])
        if ffn == "moe":
            moe = L.moe_ffn_local if cfg.opt_moe_local_dispatch else \
                L.moe_ffn
            f, aux = moe(p["moe"], h, cfg)
        else:
            f = L.glu_mlp(p["mlp"], h, cfg.act)
        x = x + f
    return x, aux, cache


def _layer_decode(cfg, mixer, ffn, p, x, cache, pos):
    h = L.apply_norm(cfg.norm, x, p["ln1"])
    if mixer == "attn":
        a, cache = L.attention_decode(p["attn"], h, cfg, cache, pos)
    else:
        a, cache = L.mamba_decode(p["mamba"], h, cfg, cache)
    x = x + a
    if ffn is not None:
        h = L.apply_norm(cfg.norm, x, p["ln2"])
        if ffn == "moe":
            f, _ = L.moe_ffn(p["moe"], h, cfg)
        else:
            f = L.glu_mlp(p["mlp"], h, cfg.act)
        x = x + f
    return x, cache


def scan_blocks(body, carry, xs, *, unroll: bool = False,
                remat: bool = False, remat_policy=None):
    """lax.scan over stacked layer params -- or an unrolled Python loop when
    ``unroll`` (used by the dry-run's depth-extrapolated flop accounting,
    since HLO cost analysis visits while bodies once)."""
    if remat:
        fn = jax.checkpoint(body, policy=remat_policy) if remat_policy \
            else jax.checkpoint(body)
    else:
        fn = body
    if not unroll:
        return jax.lax.scan(fn, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = fn(carry, xi)
        ys.append(y)
    if all(y is None for y in ys):
        return carry, None
    return carry, jax.tree.map(lambda *a: jnp.stack(a), *ys)


def _empty_attn_cache(cfg, B, S, dtype=jnp.bfloat16):
    return {"k": jnp.zeros((B, S, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((B, S, cfg.n_kv_heads, cfg.head_dim), dtype)}


def _empty_mamba_cache(cfg, B):
    d_in = cfg.d_inner
    conv_dim = d_in + 2 * cfg.ssm_groups * cfg.ssm_state
    return {"conv": jnp.zeros((B, cfg.ssm_conv - 1, conv_dim), jnp.bfloat16),
            "ssm": jnp.zeros((B, cfg.ssm_heads, cfg.ssm_head_dim,
                              cfg.ssm_state), jnp.float32)}


# ---------------------------------------------------------------------------
# Segment plan: contiguous runs of layers sharing (mixer, ffn) structure.
# dense/ssm: one segment; moe: first_k_dense unscanned head + scanned body;
# hybrid: scan over super-blocks of `hybrid_period` heterogeneous sub-layers.
# ---------------------------------------------------------------------------


def _plan(cfg: ModelConfig):
    if cfg.family == "hybrid":
        period = cfg.hybrid_period
        n_super = cfg.n_layers // period
        subs = [(cfg.mixer_kind(i), cfg.ffn_kind(i)) for i in range(period)]
        return {"kind": "hybrid", "n_super": n_super, "subs": subs}
    kinds = [(cfg.mixer_kind(i), cfg.ffn_kind(i)) for i in range(cfg.n_layers)]
    head = kinds[:cfg.first_k_dense]
    body = kinds[cfg.first_k_dense:]
    assert all(k == body[0] for k in body), "body layers must be uniform"
    return {"kind": "flat", "head": head, "body": body[0] if body else None,
            "n_body": len(body)}


def init_params(cfg: ModelConfig, key) -> Params:
    plan = _plan(cfg)
    ks = jax.random.split(key, 8)
    D, V = cfg.d_model, cfg.vocab
    params: Params = {
        "emb": L.dense_init(ks[0], (V, D), scale=0.02),
        "ln_f": jnp.zeros((D,), jnp.bfloat16),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(ks[1], (D, V))
    if cfg.n_vision_tokens:
        params["vis_proj"] = L.dense_init(ks[2], (D, D))

    if plan["kind"] == "hybrid":
        blocks = {}
        for si, (mixer, ffn) in enumerate(plan["subs"]):
            lk = jax.random.split(ks[3 + si % 4], plan["n_super"])
            stacked = [ _init_layer(lk[j], cfg, mixer, ffn)
                        for j in range(plan["n_super"]) ]
            blocks[f"sub{si}"] = jax.tree.map(
                lambda *a: jnp.stack(a), *stacked)
        params["blocks"] = blocks
    else:
        if plan["head"]:
            params["head_blocks"] = [
                _init_layer(k, cfg, m, f) for k, (m, f) in
                zip(jax.random.split(ks[3], len(plan["head"])), plan["head"])]
        if plan["n_body"]:
            mixer, ffn = plan["body"]
            lk = jax.random.split(ks[4], plan["n_body"])
            stacked = [_init_layer(lk[j], cfg, mixer, ffn)
                       for j in range(plan["n_body"])]
            params["blocks"] = jax.tree.map(lambda *a: jnp.stack(a), *stacked)
    return params


# ---------------------------------------------------------------------------
# Forward (training) and loss
# ---------------------------------------------------------------------------


def _embed(cfg, params, tokens, extra_embeds=None):
    x = jnp.take(params["emb"], tokens, axis=0).astype(jnp.bfloat16)
    if cfg.n_vision_tokens and extra_embeds is not None:
        vis = (extra_embeds.astype(jnp.bfloat16) @ params["vis_proj"])
        x = x.at[:, :cfg.n_vision_tokens, :].add(vis)
    return x


def forward(cfg: ModelConfig, params: Params, tokens,
            extra_embeds=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens: (B, S) -> logits (B, S, V), aux loss."""
    plan = _plan(cfg)
    B, S = tokens.shape
    x = _embed(cfg, params, tokens, extra_embeds)
    x = wsc(x, ("pod", "data"), None, None)
    positions = jnp.arange(S)
    aux_total = jnp.zeros((), jnp.float32)

    if plan["kind"] == "hybrid":
        subs = plan["subs"]

        def body(carry, lp):
            x, aux = carry
            for si, (mixer, ffn) in enumerate(subs):
                x, a, _ = _layer_fwd(cfg, mixer, ffn, lp[f"sub{si}"], x,
                                     positions)
                aux = aux + a
            x = wsc(x, ("pod", "data"), None,
                    "model" if cfg.opt_shard_carry else None)
            return (x, aux), None

        (x, aux_total), _ = scan_blocks(body, (x, aux_total),
                                        params["blocks"],
                                        unroll=cfg.unroll, remat=cfg.remat)
    else:
        for lp, (mixer, ffn) in zip(params.get("head_blocks", []),
                                    plan["head"]):
            x, a, _ = _layer_fwd(cfg, mixer, ffn, lp, x, positions)
            aux_total = aux_total + a
        if plan["n_body"]:
            mixer, ffn = plan["body"]

            def body(carry, lp):
                x, aux = carry
                x, a, _ = _layer_fwd(cfg, mixer, ffn, lp, x, positions)
                x = wsc(x, ("pod", "data"), None,
                        "model" if cfg.opt_shard_carry else None)
                return (x, aux + a), None

            (x, aux_total), _ = scan_blocks(
                body, (x, aux_total), params["blocks"], unroll=cfg.unroll,
                remat=cfg.remat, remat_policy=_remat_policy(cfg))

    x = L.apply_norm(cfg.norm, x, params["ln_f"])
    head = params["emb"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    logits = wsc(logits, ("pod", "data"), None, "model")
    return logits, aux_total


def cross_entropy(logits, labels, z_weight: float = 0.0):
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - ll)
    if z_weight:
        loss = loss + z_weight * jnp.mean(lse ** 2)
    return loss


def loss_fn(cfg: ModelConfig, params: Params, batch, aux_weight: float = 0.01):
    logits, aux = forward(cfg, params, batch["tokens"],
                          batch.get("patches"))
    return cross_entropy(logits, batch["labels"]) + aux_weight * aux


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------


def empty_cache(cfg: ModelConfig, B: int, S: int) -> Params:
    """Cache pytree matching the block structure (stacked over layers)."""
    plan = _plan(cfg)

    def one(mixer):
        return _empty_attn_cache(cfg, B, S) if mixer == "attn" \
            else _empty_mamba_cache(cfg, B)

    if plan["kind"] == "hybrid":
        caches = {}
        for si, (mixer, _) in enumerate(plan["subs"]):
            c = one(mixer)
            caches[f"sub{si}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a, (plan["n_super"],) + a.shape), c)
        return {"blocks": caches}
    out = {}
    if plan["head"]:
        out["head_blocks"] = [one(m) for (m, _) in plan["head"]]
    if plan["n_body"]:
        mixer, _ = plan["body"]
        c = one(mixer)
        out["blocks"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (plan["n_body"],) + a.shape), c)
    return out


def _pad_attn_cache(cache, S_total):
    """Grow prefill (k, v) of length S to the full cache length."""
    def pad(a):
        pad_len = S_total - a.shape[1]
        if pad_len <= 0:
            return a
        return jnp.pad(a, ((0, 0), (0, pad_len), (0, 0), (0, 0)))
    return {"k": pad(cache["k"]), "v": pad(cache["v"])}


def prefill(cfg: ModelConfig, params: Params, tokens, extra_embeds=None,
            cache_len: Optional[int] = None):
    """Run the prompt, return (last-token logits, caches)."""
    plan = _plan(cfg)
    B, S = tokens.shape
    cache_len = cache_len or S
    x = _embed(cfg, params, tokens, extra_embeds)
    positions = jnp.arange(S)

    def fix(cache, mixer):
        return _pad_attn_cache(cache, cache_len) if mixer == "attn" else cache

    caches: Params = {}
    if plan["kind"] == "hybrid":
        subs = plan["subs"]

        def body(x, lp):
            outs = {}
            for si, (mixer, ffn) in enumerate(subs):
                x, _, c = _layer_fwd(cfg, mixer, ffn, lp[f"sub{si}"], x,
                                     positions, want_cache=True)
                outs[f"sub{si}"] = fix(c, mixer)
            return x, outs

        x, caches["blocks"] = scan_blocks(body, x, params["blocks"],
                                          unroll=cfg.unroll)
    else:
        if plan["head"]:
            caches["head_blocks"] = []
            for lp, (mixer, ffn) in zip(params["head_blocks"], plan["head"]):
                x, _, c = _layer_fwd(cfg, mixer, ffn, lp, x, positions,
                                     want_cache=True)
                caches["head_blocks"].append(fix(c, mixer))
        if plan["n_body"]:
            mixer, ffn = plan["body"]

            def body(x, lp):
                x, _, c = _layer_fwd(cfg, mixer, ffn, lp, x, positions,
                                     want_cache=True)
                return x, fix(c, mixer)

            x, caches["blocks"] = scan_blocks(body, x, params["blocks"],
                                              unroll=cfg.unroll)

    x = L.apply_norm(cfg.norm, x[:, -1:, :], params["ln_f"])
    head = params["emb"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, caches


def decode_step(cfg: ModelConfig, params: Params, caches: Params, token,
                pos):
    """token: (B, 1) int32; pos: scalar int32 -> (logits (B,1,V), caches)."""
    plan = _plan(cfg)
    x = jnp.take(params["emb"], token, axis=0).astype(jnp.bfloat16)

    new_caches: Params = {}
    if plan["kind"] == "hybrid":
        subs = plan["subs"]

        def body(x, inp):
            lp, cache = inp
            new = {}
            for si, (mixer, ffn) in enumerate(subs):
                x, c = _layer_decode(cfg, mixer, ffn, lp[f"sub{si}"], x,
                                     cache[f"sub{si}"], pos)
                new[f"sub{si}"] = c
            return x, new

        x, new_caches["blocks"] = scan_blocks(
            body, x, (params["blocks"], caches["blocks"]),
            unroll=cfg.unroll)
    else:
        if plan["head"]:
            new_caches["head_blocks"] = []
            for lp, cache, (mixer, ffn) in zip(
                    params["head_blocks"], caches["head_blocks"],
                    plan["head"]):
                x, c = _layer_decode(cfg, mixer, ffn, lp, x, cache, pos)
                new_caches["head_blocks"].append(c)
        if plan["n_body"]:
            mixer, ffn = plan["body"]

            def body(x, inp):
                lp, cache = inp
                x, c = _layer_decode(cfg, mixer, ffn, lp, x, cache, pos)
                return x, c

            x, new_caches["blocks"] = scan_blocks(
                body, x, (params["blocks"], caches["blocks"]),
                unroll=cfg.unroll)

    x = L.apply_norm(cfg.norm, x, params["ln_f"])
    head = params["emb"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, new_caches
