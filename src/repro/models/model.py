"""Family-dispatching model facade: init / loss / prefill / decode."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm, seq2seq


def init_params(cfg: ModelConfig, key):
    if cfg.family == "encdec":
        return seq2seq.init_params(cfg, key)
    return lm.init_params(cfg, key)


def loss_fn(cfg: ModelConfig, params, batch) -> jnp.ndarray:
    if cfg.family == "encdec":
        return seq2seq.loss_fn(cfg, params, batch)
    return lm.loss_fn(cfg, params, batch)


def prefill_fn(cfg: ModelConfig, params, batch, cache_len=None):
    if cfg.family == "encdec":
        return seq2seq.prefill(cfg, params, batch["frames"], batch["tokens"],
                               cache_len=cache_len)
    return lm.prefill(cfg, params, batch["tokens"], batch.get("patches"),
                      cache_len=cache_len)


def decode_fn(cfg: ModelConfig, params, caches, token, pos):
    if cfg.family == "encdec":
        return seq2seq.decode_step(cfg, params, caches, token, pos)
    return lm.decode_step(cfg, params, caches, token, pos)


def empty_cache(cfg: ModelConfig, B: int, S: int, S_enc: Optional[int] = None):
    if cfg.family == "encdec":
        return seq2seq.empty_cache(cfg, B, S, S_enc or S)
    return lm.empty_cache(cfg, B, S)
