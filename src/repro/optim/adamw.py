"""AdamW with decoupled weight decay + cosine LR schedule + global clipping.

Moments are kept in f32 (params may be bf16); the state pytree mirrors the
param tree so the same sharding specs apply leaf-for-leaf.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * \
        (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def update(cfg: OptConfig, grads, state, params):
    step = state["step"] + 1
    lr = schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}
