"""Mesh context + sharding-constraint helpers.

Models call ``wsc(x, spec_elements)`` with *logical* axis names
("pod", "data", "model"); the helper filters names absent from the active
mesh (e.g. "pod" on the single-pod mesh) and no-ops when no mesh is set
(CPU smoke tests).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None

BATCH_AXES = ("pod", "data")
MODEL_AXIS = "model"


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _MESH


class mesh_context:
    def __init__(self, mesh):
        self.mesh = mesh

    def __enter__(self):
        self.prev = get_mesh()
        set_mesh(self.mesh)
        return self.mesh

    def __exit__(self, *exc):
        set_mesh(self.prev)


def filter_spec(spec_elements, mesh: Optional[Mesh] = None,
                shape: Optional[Sequence[int]] = None) -> P:
    """Drop axis names not in the mesh; drop axes whose dim isn't divisible."""
    mesh = mesh or _MESH
    if mesh is None:
        return P()
    names = set(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for i, e in enumerate(spec_elements):
        if e is None:
            out.append(None)
            continue
        axes = e if isinstance(e, tuple) else (e,)
        axes = tuple(a for a in axes if a in names)
        if shape is not None and axes:
            total = 1
            for a in axes:
                total *= sizes[a]
            if shape[i] % total != 0:
                # try dropping trailing axes until divisible
                while axes:
                    total = 1
                    for a in axes:
                        total *= sizes[a]
                    if shape[i] % total == 0:
                        break
                    axes = axes[:-1]
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(axes)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def wsc(x, *spec_elements):
    """with_sharding_constraint against the context mesh (no-op without)."""
    mesh = _MESH
    if mesh is None:
        return x
    spec = filter_spec(spec_elements, mesh, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named(spec_elements, shape=None, mesh=None) -> NamedSharding:
    mesh = mesh or _MESH
    return NamedSharding(mesh, filter_spec(spec_elements, mesh, shape))
