"""Parameter / activation / cache sharding rules.

FSDP over the "data" axis + tensor parallelism over the "model" axis,
pure data parallelism over the "pod" axis. Rules are path-based over the
parameter pytree; non-divisible dimensions gracefully fall back to
replication (handled by ``filter_spec``).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

from repro.parallel.api import filter_spec

# trailing-dims rules keyed by leaf name ---------------------------------
_COL = ("data", "model")          # (D, X): FSDP rows, TP cols
_ROW = ("model", "data")          # (X, D)
_RULES = {
    "emb": ("model", "data"),
    "lm_head": _COL,
    "wq": _COL, "wk": _COL, "wv": _COL, "w1": _COL, "w3": _COL,
    "in_proj": _COL, "router": ("data", None),
    "wo": _ROW, "w2": _ROW, "out_proj": _ROW,
    "conv_w": ("model", None),
}
_MOE_RULES = {  # expert-parallel: experts over "model"
    "w1": ("model", "data", None),
    "w3": ("model", "data", None),
    "w2": ("model", None, "data"),
}


def _path_names(path):
    names = []
    for k in path:
        if isinstance(k, DictKey):
            names.append(str(k.key))
        elif isinstance(k, SequenceKey):
            names.append(str(k.idx))
    return names


def spec_for_leaf(path, leaf) -> P:
    names = _path_names(path)
    leaf_name = names[-1] if names else ""
    in_moe = "moe" in names and "shared" not in names
    rules = _MOE_RULES if in_moe and leaf_name in _MOE_RULES else _RULES
    rule = rules.get(leaf_name)
    if rule is None or leaf.ndim < len(rule):
        return tuple([None] * leaf.ndim)
    pad = leaf.ndim - len(rule)
    return tuple([None] * pad + list(rule))


def param_specs(params_shape: Any, mesh) -> Any:
    """Map a pytree of ShapeDtypeStructs/arrays -> NamedSharding tree."""
    def f(path, leaf):
        spec = spec_for_leaf(path, leaf)
        return NamedSharding(mesh, filter_spec(spec, mesh, leaf.shape))
    return jax.tree_util.tree_map_with_path(f, params_shape)


def cache_spec_for_leaf(path, leaf, mesh) -> NamedSharding:
    """KV / SSM cache shardings for decode.

    attn caches: (..., B, S, Hkv, hd) -> batch over (pod, data) when divisible,
    else sequence over data; heads over model when divisible, else head_dim.
    ssm caches:  conv (..., B, K-1, C) / ssm (..., B, H, Pd, N) -> batch over
    (pod, data), channel/head dims over model.
    """
    names = _path_names(path)
    leaf_name = names[-1] if names else ""
    nd = leaf.ndim
    if leaf_name in ("k", "v"):           # (..., B, S, Hkv, hd)
        B, S, Hkv, hd = leaf.shape[-4:]
        batch_total = mesh.devices.size // (
            dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1))
        spec = [None] * (nd - 4)
        if B % batch_total == 0 and B > 1:
            spec += [("pod", "data"), None]
        else:
            spec += [None, "data"]
        spec += ["model", None] if Hkv % _axis(mesh, "model") == 0 \
            else [None, "model"]
        return NamedSharding(mesh, filter_spec(spec, mesh, leaf.shape))
    if leaf_name == "conv":               # (..., B, K-1, C)
        spec = [None] * (nd - 3) + [("pod", "data"), None, "model"]
        return NamedSharding(mesh, filter_spec(spec, mesh, leaf.shape))
    if leaf_name == "ssm":                # (..., B, H, Pd, N)
        spec = [None] * (nd - 4) + [("pod", "data"), "model", None, None]
        return NamedSharding(mesh, filter_spec(spec, mesh, leaf.shape))
    return NamedSharding(mesh, P())


def _axis(mesh, name) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get(name, 1)


def cache_specs(cache_shape: Any, mesh) -> Any:
    def f(path, leaf):
        return cache_spec_for_leaf(path, leaf, mesh)
    return jax.tree_util.tree_map_with_path(f, cache_shape)
