"""gemma-7b [dense]: GeGLU, head_dim=256, vocab 256000. [arXiv:2403.08295]"""
from repro.configs.base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    name="gemma-7b",
    model=ModelConfig(
        name="gemma-7b", family="dense",
        n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
        d_ff=24576, vocab=256000, act="gelu", tie_embeddings=True,
        rope_theta=10000.0,
    ),
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    notes="long_500k skipped: pure full attention.",
)
