"""qwen2.5-3b [dense]: GQA (kv=2), QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]"""
from repro.configs.base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    name="qwen2.5-3b",
    model=ModelConfig(
        name="qwen2.5-3b", family="dense",
        n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2, head_dim=128,
        d_ff=11008, vocab=151936, act="silu", qkv_bias=True,
        rope_theta=1e6, tie_embeddings=True,
    ),
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    notes="long_500k skipped: pure full attention (dense 512k KV decode "
          "outside design envelope).",
)
