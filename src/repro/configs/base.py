"""Config dataclasses for models, shapes, and architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"          # dense | moe | ssm | hybrid | encdec
    n_layers: int = 4
    d_model: int = 512
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 64
    d_ff: int = 2048
    vocab: int = 32000
    act: str = "silu"              # silu | gelu
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    causal: bool = True
    attn_block: int = 1024         # kv block for flash-style attention
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    first_k_dense: int = 0         # leading dense layers (deepseek-moe style)
    moe_every: int = 1             # MoE on layers where i % moe_every == moe_offset
    moe_offset: int = 0
    # --- SSM (mamba2) ---
    ssm_state: int = 128
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # --- hybrid (jamba): period of the mixer pattern; attn at this index ---
    hybrid_period: int = 8
    hybrid_attn_index: int = 4
    # --- encoder-decoder ---
    enc_layers: int = 0
    dec_layers: int = 0
    # --- multimodal stubs ---
    n_vision_tokens: int = 0       # VLM: patch embeddings added to prefix
    audio_frontend: bool = False   # enc-dec: encoder consumes frame embeddings
    # --- lowering ---
    unroll: bool = False           # unroll layer stacks (flops accounting)
    remat: bool = True             # rematerialise layer bodies in training
    # --- beyond-baseline optimisations (EXPERIMENTS.md section Perf) ---
    opt_moe_local_dispatch: bool = False   # shard-local MoE sort/scatter
    opt_shard_carry: bool = False          # TP-shard the saved scan carry
    opt_moe_cf1: bool = False              # capacity factor 1.25 -> 1.0
    opt_remat_dots: bool = False           # save matmul outputs in remat
    opt_microbatch4: bool = False          # 4-way grad accumulation

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def mixer_kind(self, i: int) -> str:
        if self.family == "ssm":
            return "mamba"
        if self.family == "hybrid":
            return "attn" if (i % self.hybrid_period) == self.hybrid_attn_index \
                else "mamba"
        return "attn"

    def ffn_kind(self, i: int) -> Optional[str]:
        if self.family == "ssm":
            return None
        if self.family in ("moe", "hybrid"):
            if i < self.first_k_dense:
                return "mlp"
            if (i % self.moe_every) == self.moe_offset:
                return "moe"
            return "mlp"
        return "mlp"

    def param_count(self) -> int:
        """Approximate parameter count (for MODEL_FLOPS and reporting)."""
        D, hd = self.d_model, self.head_dim
        n = self.vocab * D * (1 if self.tie_embeddings else 2)
        enc_dec = self.family == "encdec"
        layers = (self.enc_layers + self.dec_layers) if enc_dec else self.n_layers
        for i in range(layers):
            mixer = self.mixer_kind(i) if not enc_dec else "attn"
            if mixer == "attn":
                n += D * hd * (self.n_heads + 2 * self.n_kv_heads) \
                    + self.n_heads * hd * D
                if enc_dec and i >= self.enc_layers:  # cross attention
                    n += D * hd * (self.n_heads + 2 * self.n_kv_heads) \
                        + self.n_heads * hd * D
            else:
                din = self.d_inner
                gn = self.ssm_groups * self.ssm_state
                n += D * (2 * din + 2 * gn + self.ssm_heads) + din * D
            ffn = self.ffn_kind(i) if not enc_dec else "mlp"
            if ffn == "mlp":
                ff = self.d_ff if not (self.family == "moe" and
                                       i < self.first_k_dense) else self.d_ff
                n += 3 * D * ff
            elif ffn == "moe":
                n += 3 * D * self.moe_d_ff * self.n_experts + D * self.n_experts
                n += 3 * D * self.moe_d_ff * self.n_shared_experts
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k)."""
        if self.family not in ("moe", "hybrid") or not self.n_experts:
            return self.param_count()
        full = self.param_count()
        n_moe_layers = sum(1 for i in range(self.n_layers)
                           if self.ffn_kind(i) == "moe")
        inactive = n_moe_layers * 3 * self.d_model * self.moe_d_ff * \
            (self.n_experts - self.top_k)
        return full - inactive


# ---------------------------------------------------------------------------
# Input shapes (the four assigned cells)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    model: ModelConfig
    # shapes this arch runs; long_500k only for sub-quadratic archs
    shapes: Tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k")
    notes: str = ""

    def smoke_model(self) -> ModelConfig:
        """Reduced config of the same family for CPU smoke tests."""
        m = self.model
        return dataclasses.replace(
            m,
            n_layers=min(m.n_layers, 2 if m.family != "hybrid"
                         else m.hybrid_period),
            d_model=256,
            n_heads=4,
            n_kv_heads=max(1, min(m.n_kv_heads, 2)) if m.n_kv_heads < m.n_heads
            else 4,
            head_dim=64,
            d_ff=512,
            vocab=512,
            moe_d_ff=128 if m.n_experts else 0,
            n_experts=min(m.n_experts, 4) if m.n_experts else 0,
            top_k=min(m.top_k, 2) if m.top_k else 0,
            n_shared_experts=min(m.n_shared_experts, 1),
            first_k_dense=min(m.first_k_dense, 1),
            ssm_state=min(m.ssm_state, 16),
            ssm_head_dim=32,
            enc_layers=min(m.enc_layers, 2),
            dec_layers=min(m.dec_layers, 2),
            n_vision_tokens=min(m.n_vision_tokens, 16),
            attn_block=64,
            ssm_chunk=16,
        )
