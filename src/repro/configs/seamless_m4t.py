"""seamless-m4t-medium [audio]: encoder-decoder; audio frontend STUBBED --
``input_specs`` supplies precomputed frame embeddings (B, S, D).
[arXiv:2308.11596]"""
from repro.configs.base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    model=ModelConfig(
        name="seamless-m4t-medium", family="encdec",
        n_layers=24, enc_layers=12, dec_layers=12,
        d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
        d_ff=4096, vocab=256206, act="gelu", norm="layernorm",
        audio_frontend=True,
    ),
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    notes="long_500k skipped: pure full attention (enc-dec). Decoder-side "
          "decode_32k attends a 32k self-KV plus the 32k encoder memory.",
)
