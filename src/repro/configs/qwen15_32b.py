"""qwen1.5-32b [dense]: MHA-equivalent GQA (kv=40), QKV bias."""
from repro.configs.base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b",
    model=ModelConfig(
        name="qwen1.5-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40, head_dim=128,
        d_ff=27392, vocab=152064, act="silu", qkv_bias=True,
        rope_theta=1e6,
    ),
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    notes="long_500k skipped: pure full attention.",
)
