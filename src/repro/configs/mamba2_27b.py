"""mamba2-2.7b [ssm]: attention-free SSD. [arXiv:2405.21060]"""
from repro.configs.base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    model=ModelConfig(
        name="mamba2-2.7b", family="ssm",
        n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0, head_dim=0,
        d_ff=0, vocab=50280, tie_embeddings=True,
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_groups=1,
    ),
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    notes="long_500k runs: SSM decode is O(1)-state (no KV cache).",
)
