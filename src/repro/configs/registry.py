"""Architecture registry: --arch <id> resolution."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ArchConfig, SHAPES, ShapeConfig

_MODULES = {
    "qwen2.5-3b": "repro.configs.qwen25_3b",
    "qwen1.5-32b": "repro.configs.qwen15_32b",
    "stablelm-12b": "repro.configs.stablelm_12b",
    "gemma-7b": "repro.configs.gemma_7b",
    "jamba-v0.1-52b": "repro.configs.jamba_52b",
    "mamba2-2.7b": "repro.configs.mamba2_27b",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi35_moe",
    "seamless-m4t-medium": "repro.configs.seamless_m4t",
}


def list_archs() -> List[str]:
    return list(_MODULES)


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {list_archs()}")
    return importlib.import_module(_MODULES[name]).CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; skipped cells flagged."""
    out = []
    for a in list_archs():
        cfg = get_config(a)
        for s in SHAPES:
            runnable = s in cfg.shapes
            if runnable or include_skipped:
                out.append((a, s, runnable))
    return out
