"""internvl2-2b [vlm]: InternLM2 backbone; InternViT frontend STUBBED --
``input_specs`` supplies 256 precomputed patch embeddings added to the
sequence prefix. [arXiv:2404.16821]
"""
from repro.configs.base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    name="internvl2-2b",
    model=ModelConfig(
        name="internvl2-2b", family="dense",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
        d_ff=8192, vocab=92553, act="silu",
        n_vision_tokens=256, rope_theta=1e6,
    ),
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    notes="long_500k skipped: pure full attention. Vision frontend is a stub"
          " (precomputed patch embeddings) per the assignment.",
)
