"""jamba-v0.1-52b [hybrid]: Mamba+attn 1:7 interleave, MoE 16e top-2.

Period-8 super-block: attention at index 4, Mamba elsewhere; MoE FFN on odd
sub-layers (16 MoE layers of 32). Jamba v0.1 uses Mamba-1 (state 16); we use
the Mamba-2/SSD block with ssm_state=16 -- TPU adaptation (SSD is the
matmul/MXU-friendly formulation of the same SSM). [arXiv:2403.19887]
"""
from repro.configs.base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    model=ModelConfig(
        name="jamba-v0.1-52b", family="hybrid",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab=65536, act="silu",
        n_experts=16, top_k=2, moe_d_ff=14336, moe_every=2, moe_offset=1,
        hybrid_period=8, hybrid_attn_index=4,
        ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_groups=1,
    ),
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    notes="long_500k runs: hybrid -- only 4 of 32 layers attend (O(L) decode"
          " over the KV cache); Mamba layers carry O(1) state.",
)
