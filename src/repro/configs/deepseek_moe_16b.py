"""deepseek-moe-16b [moe]: fine-grained 64 routed top-6 + 2 shared experts,
first layer dense. [arXiv:2401.06066]"""
from repro.configs.base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    model=ModelConfig(
        name="deepseek-moe-16b", family="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
        d_ff=10944, vocab=102400, act="silu",
        n_experts=64, top_k=6, moe_d_ff=1408, n_shared_experts=2,
        first_k_dense=1,
    ),
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    notes="long_500k skipped: pure full attention. Layer 0 dense (d_ff "
          "10944), layers 1-27 MoE with d_ff 1408 per expert.",
)
