"""stablelm-12b [dense]: GQA kv=8, LayerNorm. [hf:stabilityai/stablelm-2-1_6b]"""
from repro.configs.base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    name="stablelm-12b",
    model=ModelConfig(
        name="stablelm-12b", family="dense",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=160,
        d_ff=13824, vocab=100352, act="silu", norm="layernorm",
        rope_theta=10000.0,
    ),
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    notes="long_500k skipped: pure full attention. StableLM-2 uses partial "
          "rotary (25%); we apply full-dim RoPE (noted adaptation).",
)
