"""Quickstart: synthesize a TONS topology, route it deadlock-free, and
compare its throughput proxy against the production torus baselines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import numpy as np

from repro.core import synthesis as SY, topology as T
from repro.core.mcf import mcf_uniform, mcf_topology
from repro.core.pipeline import PipelineConfig, route_pod


def main() -> None:
    spec = (4, 4, 8)  # 128 chips = 2 cubes: the smallest interesting pod

    print("== baselines ==")
    pt = T.pt(spec)
    lam_pt, _ = mcf_uniform(pt.edges(), pt.n,
                            perms=T.torus_translations(pt.pod),
                            prefer="highs")
    pdtt = T.pdtt(spec)
    lam_pdtt, _ = mcf_uniform(
        pdtt.edges(), pdtt.n,
        perms=T.torus_translations(pdtt.pod, twisted=True), prefer="highs")
    print(f"PT   {spec}: MCF = {lam_pt:.5f}")
    print(f"PDTT {spec}: MCF = {lam_pdtt:.5f}")

    print("== TONS synthesis (Algorithm 3, symmetric, interval=4) ==")
    res = SY.synthesize(spec, symmetric=True, interval=4, verbose=True)
    lam = mcf_topology(res.topology, prefer="highs")
    print(f"TONS {spec}: MCF = {lam:.5f} "
          f"({lam / lam_pt:.2f}x PT, {lam / lam_pdtt:.2f}x PDTT)")

    print("== deadlock-free routing within 2 VCs ==")
    rp = route_pod(res.topology, PipelineConfig(
        robust=True, K=4, engine="array", local_search_rounds=3,
        vc="inplace", verify=True))
    assert rp.deadlock_free
    print(f"all {rp.table.n_routed()} pairs routed; "
          f"L_max={rp.l_max:.0f} "
          f"(MCF bound {1 / lam:.0f}); "
          f"VC hop balance={rp.vc_counts.tolist()}")


if __name__ == "__main__":
    main()
