"""Fault-tolerant pod walkthrough: synthesize with the C8 fault budget,
build robust routing, knock out an OCS, and show the job keeps running --
the network-level story (TONS robust routing) plus the framework-level
story (checkpoint restore after a preemption).

Run:  PYTHONPATH=src python examples/fault_tolerant_pod.py
"""
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import numpy as np

from repro.core import fault as F, topology as T
from repro.core.mcf import mcf_topology
from repro.core.pipeline import PipelineConfig, route_pod


def main() -> None:
    # --- network side -----------------------------------------------------
    print("== robust TONS fabric under a single-OCS fault ==")
    import pickle
    pk = Path(__file__).parent.parent / "benchmarks/results/tons_128.pkl"
    if pk.exists():
        d = pickle.load(open(pk, "rb"))
        topo = T.Topology(T.Pod((4, 4, 8)),
                          [tuple(e) for e in d["optical"]], name="TONS 128")
        lam = d["mcf"]
    else:
        topo = T.pdtt((4, 4, 8))
        lam = 0.01364
    cert = F.fault_tolerance_certificate(topo, lam, f=1)
    print(f"C8 certificate: lambda={lam:.5f} >= "
          f"{cert['required_lambda']:.5f} -> up to "
          f"{cert['certified_f']} OCS faults tolerable "
          f"(color budget {cert['color_budget']})")

    cfg = PipelineConfig(robust=True, K=4, engine="array",
                         local_search_rounds=2, vc="none")
    rp = route_pod(topo, cfg)
    at, base = rp.at, rp.routed
    print(f"no fault: all pairs routed, L_max={base.l_max:.0f}")

    colors = F.colors_in_use(topo)
    fault = colors[len(colors) // 2]
    dead = F.dead_channels_for_color(at, fault)
    routed = route_pod(topo, cfg, at=at, dead_channels=dead).routed
    print(f"OCS {fault} failed ({len(dead)} channels dead): "
          f"unreachable={routed.unreachable}, L_max={routed.l_max:.0f} "
          f"({routed.l_max / base.l_max:.2f}x degradation)")
    assert routed.unreachable == 0

    # online repair: the serving fabric patches itself instead of
    # recomputing -- only the flows crossing dead channels re-route
    import time
    from repro.core.repair import ServingState, repair_fault
    t0 = time.time()
    st = ServingState.build(topo, n_vc=2, K=4, robust=True)
    t_build = time.time() - t0
    t0 = time.time()
    rr = repair_fault(st, dead)
    t_rep = time.time() - t0
    assert rr.unreachable == 0 and rr.deadlock_free
    print(f"online repair: {rr.flows_rerouted} of "
          f"{st.table.n_flows} flows re-routed in {t_rep:.2f}s "
          f"(cold build {t_build:.1f}s, "
          f"{t_build / max(t_rep, 1e-9):.0f}x), "
          f"L_max={rr.l_max:.0f}, deadlock-free")

    # simulate the degraded fabric under several traffic patterns: one
    # vmapped kernel serves them all, only the alias tables change
    from repro.core import netsim as NS
    from repro.core.demand import WorkloadDemand
    from repro.core.traffic import TrafficPattern
    tab = NS.at_tables(topo, at, routed)
    wd = WorkloadDemand(topo.pod, w_same_cube=2.0, w_ring=2.0,
                        w_uniform=0.25)
    patterns = [TrafficPattern.uniform(topo.n),
                TrafficPattern.transpose(topo.pod),
                TrafficPattern.hotspot(topo.n, [0, 1, 2, 3], 0.4),
                TrafficPattern.from_demand(wd)]
    for pat in patterns:
        r = NS.run(tab, 0.05, traffic=pat, cycles=1200, warmup=400)
        print(f"  {pat.name:10s}: delivered {r['delivered']:.4f} "
              f"of offered {r['offered']:.4f} under the fault")

    # --- framework side ----------------------------------------------------
    print("== training survives preemption via checkpoint restore ==")
    from repro.configs.registry import get_config
    from repro.data.synthetic import DataConfig
    from repro.optim.adamw import OptConfig
    from repro.train.loop import TrainConfig, Trainer
    cfg = get_config("qwen2.5-3b").smoke_model()
    with tempfile.TemporaryDirectory() as d:
        tc = TrainConfig(steps=6, ckpt_every=3, ckpt_dir=d, log_every=3)
        t1 = Trainer(cfg, DataConfig(vocab=cfg.vocab, seq_len=32,
                                     global_batch=4),
                     OptConfig(total_steps=6), tc)
        t1.run()
        # "preemption": a fresh process picks up from the last checkpoint
        t2 = Trainer(cfg, DataConfig(vocab=cfg.vocab, seq_len=32,
                                     global_batch=4),
                     OptConfig(total_steps=6),
                     TrainConfig(steps=8, ckpt_every=3, ckpt_dir=d,
                                 log_every=3))
        print(f"restarted at step {t2.start_step}")
        out = t2.run()
        assert out["final_step"] == 8
    print("ok: fabric re-routed and training resumed")


if __name__ == "__main__":
    main()
