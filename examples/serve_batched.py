"""Batched serving example: continuous batching over decode slots using
the same serve_step the decode dry-run cells lower.

Run:  PYTHONPATH=src python examples/serve_batched.py [--arch <id>]
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

from repro.launch.serve import main

if __name__ == "__main__":
    main()
