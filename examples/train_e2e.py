"""End-to-end driver: train a ~100M-parameter qwen-family model with the
full substrate (sharded synthetic data, AdamW + cosine, remat, async
checkpointing, resume, straggler watchdog).

Default runs a shortened schedule sized for the CPU container; pass
--steps 300 --d-model 768 for the full ~100M x few-hundred-step run.

Run:  PYTHONPATH=src python examples/train_e2e.py [--steps N]
"""
import argparse
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

from repro.configs.base import ModelConfig
from repro.data.synthetic import DataConfig
from repro.optim.adamw import OptConfig
from repro.train.loop import TrainConfig, Trainer


def build_config(d_model: int, n_layers: int, vocab: int) -> ModelConfig:
    return ModelConfig(
        name="e2e-100m", family="dense", n_layers=n_layers,
        d_model=d_model, n_heads=d_model // 64, n_kv_heads=d_model // 128,
        head_dim=64, d_ff=4 * d_model, vocab=vocab, qkv_bias=True,
        tie_embeddings=True, attn_block=128, ssm_chunk=64)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--d-model", type=int, default=384)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    cfg = build_config(args.d_model, args.layers, args.vocab)
    n_params = cfg.param_count()
    print(f"model: {n_params / 1e6:.1f}M params "
          f"({args.layers}L x {args.d_model})")

    tc = TrainConfig(steps=args.steps, ckpt_every=max(args.steps // 4, 10),
                     ckpt_dir=args.ckpt_dir, log_every=5,
                     microbatches=args.microbatches)
    trainer = Trainer(
        cfg, DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                        global_batch=args.batch),
        OptConfig(lr=6e-4, warmup_steps=max(args.steps // 10, 5),
                  total_steps=args.steps),
        tc)
    out = trainer.run()
    print(f"loss: {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f} over "
          f"{len(out['losses'])} steps "
          f"(median step {sorted(out['step_times'])[len(out['step_times']) // 2]:.2f}s)")
    assert out["losses"][-1] < out["losses"][0], "training must make progress"


if __name__ == "__main__":
    main()
