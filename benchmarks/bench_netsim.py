"""Netsim perf tracking: batched sweep vs the seed's sequential sweep,
plus the CSR-native kernel at the scales the dense layout cannot stage.

Measures:

- on a 4x4x4 pod (one cube, 64 chips, PT wiring + DOR routing):
  wall-clock of the *seed's* sequential `saturation_point` (its original
  4-array kernel, vendored below as a frozen baseline; one jit call per
  rate with early exit) vs the current batched two-stage sweep, plus the
  current kernel driven sequentially, and the speedups; saturation
  points for the built-in traffic patterns (uniform, transpose, hotspot,
  demand-derived), all through the same jitted CSR kernel;
- on an 8^3 pod (512 chips): the guarded CSR section -- batched-sweep
  wall-clock (median of 3, 1.5x guard), staged array bytes of the CSR vs
  dense kernels (the CSR bytes carry a 1.15x guard: route tables are
  deterministic, so the staged working set must not creep), saturation,
  and process peak RSS;
- with ``--full``, the 12^3 (1728-chip) entry: route via the sharded
  engine, then the first saturation sweep at that scale -- dense
  ``(n, n, MAXHOP)`` tables would need ~1.7 GB before the first cycle;
  the CSR kernel stages O(total routed hops). The n1728 record is kept
  across non-full runs (like bench_routing's full-scale rows), and
  guards skip when the baseline is missing (fresh checkout / first run).

``--json`` (or ``main(json_path=...)``) writes BENCH_netsim.json so the
perf trajectory is tracked from PR to PR.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from functools import partial
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent))

from benchmarks.common import (emit, guard_regression, load_bench_json,
                               median_timed, peak_rss_mb)

SPEC = (4, 4, 4)
GUARD_SPEC = (8, 8, 8)          # 512 chips: the guarded CSR section
FULL_SPEC = (12, 12, 12)        # 1728 chips: --full saturation entry
SWEEP_REGRESSION = 1.5          # 8^3 batched-sweep wall-clock guard
BYTES_REGRESSION = 1.15         # 8^3 staged-array-bytes guard (deterministic)
ADAPTIVE_OFF_REGRESSION = 1.10  # adaptive-off path vs pre-adaptive baseline


# ---------------------------------------------------------------------------
# Frozen copy of the seed's simulator kernel (PR-0 netsim._simulate) used
# as the perf baseline. Do not modernise: its job is to stay fixed.
# ---------------------------------------------------------------------------


def _seed_simulate_factory():
    import jax
    import jax.numpy as jnp

    @partial(jax.jit, static_argnames=("n", "n_ch", "n_vc", "slots",
                                       "cycles", "flits"))
    def _simulate(ch_dst, path, vcs, rate, key, *, n, n_ch, n_vc, slots,
                  cycles, warmup, flits=1):
        NQ = n_ch * n_vc
        q_src = jnp.zeros((NQ, slots), jnp.int32)
        q_dst = jnp.zeros((NQ, slots), jnp.int32)
        q_hop = jnp.zeros((NQ, slots), jnp.int32)
        head = jnp.zeros((NQ,), jnp.int32)
        size = jnp.zeros((NQ,), jnp.int32)
        rr = jnp.zeros((n_ch,), jnp.int32)
        busy = jnp.zeros((n_ch,), jnp.int32)

        def qid(c, v):
            return c * n_vc + v

        def cycle(i, carry):
            (q_src, q_dst, q_hop, head, size, rr, busy, key, stats) = carry
            offered, accepted, delivered = stats
            hs = q_src[jnp.arange(NQ), head]
            hd = q_dst[jnp.arange(NQ), head]
            hh = q_hop[jnp.arange(NQ), head]
            nonempty = size > 0
            arrive_node = ch_dst[jnp.arange(NQ) // n_vc]
            consume = nonempty & (arrive_node == hd)
            nxt_c = path[hs, hd, hh + 1]
            nxt_v = vcs[hs, hd, hh + 1].astype(jnp.int32)
            tq = jnp.where(consume, -1, qid(nxt_c, nxt_v))
            fwd_ok = nonempty & ~consume & (size[jnp.clip(tq, 0, NQ - 1)]
                                            < slots)
            eligible = consume | fwd_ok
            eligible = eligible & jnp.repeat(busy == 0, n_vc)
            elig_cv = eligible.reshape(n_ch, n_vc)
            offs = (rr[:, None] + jnp.arange(n_vc)[None, :]) % n_vc
            pri = jnp.take_along_axis(elig_cv, offs, axis=1)
            first = jnp.argmax(pri, axis=1)
            any_e = pri.any(axis=1)
            win_v = (rr + first) % n_vc
            win_q = jnp.arange(n_ch) * n_vc + win_v
            win_valid = any_e
            rr = jnp.where(win_valid, (win_v + 1) % n_vc, rr)
            w_src = hs[win_q]
            w_dst = hd[win_q]
            w_hop = hh[win_q]
            w_consume = consume[win_q] & win_valid
            w_target = jnp.where(win_valid & ~w_consume, tq[win_q], -1)
            sort_i = jnp.argsort(jnp.where(w_target < 0, NQ + 1, w_target))
            st = jnp.where(w_target < 0, NQ + 1, w_target)[sort_i]
            newgrp = jnp.concatenate([jnp.ones(1, bool), st[1:] != st[:-1]])
            grp_start = jnp.where(newgrp, jnp.arange(n_ch), 0)
            grp_start = jax.lax.associative_scan(jnp.maximum, grp_start)
            rank_sorted = jnp.arange(n_ch) - grp_start
            rank = jnp.zeros(n_ch, jnp.int32).at[sort_i].set(
                rank_sorted.astype(jnp.int32))
            space_ok = (size[jnp.clip(w_target, 0, NQ - 1)] + rank) < slots
            w_push = win_valid & ~w_consume & (w_target >= 0) & space_ok
            w_pop = w_consume | w_push
            busy = jnp.where(w_pop, flits - 1, jnp.maximum(busy - 1, 0))
            popq = jnp.where(w_pop, win_q, NQ)
            head = head.at[jnp.clip(popq, 0, NQ - 1)].add(
                jnp.where(w_pop, 1, 0)) % slots
            size = size.at[jnp.clip(popq, 0, NQ - 1)].add(
                jnp.where(w_pop, -1, 0))
            tgt = jnp.clip(w_target, 0, NQ - 1)
            slot = (head[tgt] + size[tgt] + rank) % slots
            q_src = q_src.at[tgt, slot].set(
                jnp.where(w_push, w_src, q_src[tgt, slot]))
            q_dst = q_dst.at[tgt, slot].set(
                jnp.where(w_push, w_dst, q_dst[tgt, slot]))
            q_hop = q_hop.at[tgt, slot].set(
                jnp.where(w_push, w_hop + 1, q_hop[tgt, slot]))
            size = size.at[tgt].add(jnp.where(w_push, 1, 0))
            key, k1, k2 = jax.random.split(key, 3)
            want = jax.random.uniform(k1, (n,)) < rate
            dsts = jax.random.randint(k2, (n,), 0, n - 1)
            srcs = jnp.arange(n)
            dsts = jnp.where(dsts >= srcs, dsts + 1, dsts)
            c0 = path[srcs, dsts, 0]
            v0 = vcs[srcs, dsts, 0].astype(jnp.int32)
            iq = qid(c0, v0)
            has_space = size[iq] < slots
            inj = want & has_space
            slot = (head[iq] + size[iq]) % slots
            q_src = q_src.at[iq, slot].set(
                jnp.where(inj, srcs, q_src[iq, slot]))
            q_dst = q_dst.at[iq, slot].set(
                jnp.where(inj, dsts, q_dst[iq, slot]))
            q_hop = q_hop.at[iq, slot].set(
                jnp.where(inj, 0, q_hop[iq, slot]))
            size = size.at[iq].add(jnp.where(inj, 1, 0))
            measure = i >= warmup
            offered = offered + jnp.where(measure, want.sum(), 0)
            accepted = accepted + jnp.where(measure, inj.sum(), 0)
            delivered = delivered + jnp.where(measure, w_consume.sum(), 0)
            return (q_src, q_dst, q_hop, head, size, rr, busy, key,
                    (offered, accepted, delivered))

        stats0 = (jnp.zeros((), jnp.int32),) * 3
        carry = (q_src, q_dst, q_hop, head, size, rr, busy, key, stats0)
        carry = jax.lax.fori_loop(0, cycles, cycle, carry)
        offered, accepted, delivered = carry[-1]
        return offered, accepted, delivered

    return _simulate


def _seed_sequential_saturation(tab, step, max_rate, cycles, warmup,
                                slots=128, flits=4, deficit=0.05):
    """The seed's `saturation_point`: python loop of per-rate jit calls on
    the frozen seed kernel, early exit at the first deficit."""
    import jax
    import jax.numpy as jnp

    sim = _seed_simulate_factory()
    meas = cycles - warmup
    sat, trace, rate = 0.0, [], step
    with jax.experimental.disable_x64():
        while rate <= max_rate + 1e-9:
            off, acc, dlv = sim(
                jnp.asarray(tab.ch_dst), jnp.asarray(tab.path),
                jnp.asarray(tab.vcs), jnp.float32(rate),
                jax.random.PRNGKey(0), n=tab.n, n_ch=tab.n_ch,
                n_vc=tab.n_vc, slots=slots, cycles=cycles, warmup=warmup,
                flits=flits)
            r = {"offered": float(off) / meas / tab.n,
                 "delivered": float(dlv) / meas / tab.n, "rate": rate}
            trace.append(r)
            if r["delivered"] >= (1 - deficit) * r["offered"]:
                sat = r["delivered"]
            else:
                break
            rate += step
    return sat, trace


def main(full: bool = False, json_path=None) -> dict:
    import numpy as np

    from repro.core import netsim as NS, topology as T
    from repro.core.demand import WorkloadDemand
    from repro.core.traffic import TrafficPattern

    step = 0.02 if not full else 0.01
    cycles = 2500 if not full else 6000
    warmup = 800 if not full else 2000
    topo = T.pt(SPEC)
    tab = NS.dor_tables(topo)
    n = topo.n
    uniform = TrafficPattern.uniform(n)

    # warm every jit cache so the timings measure execution, not compile
    _seed_sequential_saturation(tab, 0.3, 0.3, cycles, warmup)
    NS.run(tab, step, traffic=uniform, cycles=cycles, warmup=warmup)
    NS.saturation_point(tab, step=step, cycles=cycles, warmup=warmup,
                        traffic=uniform)

    t0 = time.time()
    sat_seed, trace_seed = _seed_sequential_saturation(
        tab, step, 1.0, cycles, warmup)
    t_seed = time.time() - t0

    t0 = time.time()
    ct = uniform.compiled()
    sat_seq, rate = 0.0, step
    n_seq = 0
    while rate <= 1.0 + 1e-9:
        r = NS.run(tab, rate, traffic=ct, cycles=cycles, warmup=warmup)
        n_seq += 1
        if r["delivered"] >= 0.95 * r["offered"]:
            sat_seq = r["delivered"]
        else:
            break
        rate += step
    t_seq = time.time() - t0

    t0 = time.time()
    sat_batch, _ = NS.saturation_point(tab, step=step, cycles=cycles,
                                       warmup=warmup, traffic=uniform)
    t_batch = time.time() - t0

    speedup = t_seed / max(t_batch, 1e-9)
    print(f"  sweep wall-clock: seed-sequential({len(trace_seed)} rates)="
          f"{t_seed:.2f}s  current-sequential({n_seq} rates)={t_seq:.2f}s"
          f"  batched={t_batch:.2f}s -> {speedup:.1f}x vs seed")
    emit("bench_netsim_sweep_speedup", t_batch * 1e6, f"{speedup:.2f}x")

    wd = WorkloadDemand(topo.pod, w_same_cube=2.0, w_ring=2.0,
                        w_uniform=0.25)
    patterns = [uniform, TrafficPattern.transpose(topo.pod),
                TrafficPattern.hotspot(n, list(range(4)), 0.4),
                TrafficPattern.from_demand(wd)]
    sats = {}
    for pat in patterns:
        sat, _ = NS.saturation_point(tab, step=step, cycles=cycles,
                                     warmup=warmup, traffic=pat)
        sats[pat.name] = sat
        print(f"  saturation[{pat.name:10s}] = {sat:.4f}")
    emit("bench_netsim_uniform_sat", 0, f"{sats['uniform']:.4f}")

    result = {
        "pod": list(SPEC),
        "rate_step": step,
        "cycles": cycles,
        "sweep_seed_sequential_s": round(t_seed, 4),
        "sweep_current_sequential_s": round(t_seq, 4),
        "sweep_batched_s": round(t_batch, 4),
        "sweep_speedup_vs_seed": round(speedup, 2),
        "saturation_uniform_seed_kernel": round(sat_seed, 5),
        "saturation": {k: round(v, 5) for k, v in sats.items()},
    }
    prior = load_bench_json(json_path) if json_path else {}

    # ---- guarded 8^3 CSR section -------------------------------------
    topo8 = T.pt(GUARD_SPEC)
    tab8 = NS.dor_tables(topo8)
    rates8 = [0.05, 0.1, 0.2, 0.4]
    s_csr: dict = {}
    s_dense: dict = {}
    NS.sweep(tab8, rates8, cycles=1500, warmup=500, stats=s_csr)  # warm jit
    trace8, t_sweep8 = median_timed(
        lambda: NS.sweep(tab8, rates8, cycles=1500, warmup=500,
                         stats=s_csr), repeats=3)
    NS.sweep(tab8, rates8[:1], cycles=200, warmup=100, kernel="dense",
             stats=s_dense)
    sat8, _ = NS.saturation_point(tab8, step=0.02, cycles=1500,
                                  warmup=500, stats=s_csr)
    n512 = {
        "pod": list(GUARD_SPEC),
        "sweep_s": round(t_sweep8, 4),
        "saturation_uniform": round(sat8, 5),
        "csr_array_bytes": int(s_csr["array_bytes"]),
        "dense_array_bytes": int(s_dense["array_bytes"]),
        "bytes_ratio": round(s_dense["array_bytes"]
                             / max(s_csr["array_bytes"], 1), 2),
        "peak_rss_mb": peak_rss_mb(),
        # livelock-watchdog outputs of the guarded sweep: the cycle each
        # rate lane's watchdog fired (-1 = quiet) and how many cycles
        # the kernel actually ran (< cycles means every lane wedged and
        # the sweep ended early)
        "watchdog": {
            "cycles_run": int(s_csr.get("cycles_run", 0)),
            "stalled_at": [int(r["stalled_at"]) for r in trace8],
        },
    }
    result["n512"] = n512
    print(f"  n512: sweep({len(rates8)} rates)={t_sweep8:.2f}s "
          f"sat={sat8:.4f} csr_bytes={n512['csr_array_bytes']:,} "
          f"dense_bytes={n512['dense_array_bytes']:,} "
          f"({n512['bytes_ratio']}x) rss={n512['peak_rss_mb']}MB")
    print(f"  n512 watchdog: cycles_run="
          f"{n512['watchdog']['cycles_run']} stalled_at="
          f"{n512['watchdog']['stalled_at']}")
    emit("bench_netsim_n512_watchdog", 0,
         f"cycles_run={n512['watchdog']['cycles_run']} "
         f"stalled_at={n512['watchdog']['stalled_at']}")
    emit("bench_netsim_n512_sweep", t_sweep8 * 1e6,
         f"csr_bytes={n512['csr_array_bytes']}")
    if json_path:
        prior512 = prior.get("n512", {})
        guard_regression("netsim_n512_sweep_s", n512["sweep_s"],
                         prior512.get("sweep_s"), SWEEP_REGRESSION)
        guard_regression("netsim_n512_csr_array_bytes",
                         n512["csr_array_bytes"],
                         prior512.get("csr_array_bytes"),
                         BYTES_REGRESSION)
        # the adaptive features ride the same kernel behind python-static
        # flags: with adaptive off the staged trace is unchanged, so the
        # wall-clock must stay within 1.10x of the pre-adaptive baseline
        # (tighter than the general 1.5x sweep guard)
        guard_regression("netsim_n512_adaptive_off_overhead",
                         n512["sweep_s"], prior512.get("sweep_s"),
                         ADAPTIVE_OFF_REGRESSION)

    # ---- adaptive-routing lane (8^3, hotspot) ------------------------
    from repro.core.pipeline import PipelineConfig, route_pod

    atab8 = route_pod(topo8, PipelineConfig(
        n_vc=4, priority="robust", K=4, local_search_rounds=1,
        engine="sharded", reserve_escape=True)).tables
    spec8 = NS.adaptive_spec(topo8)
    # 8 hot endpoints at frac 0.4: consumption-limited sat ~= 0.039, so
    # a 0.005 step resolves the static-vs-adaptive gap (one hot node
    # saturates below any usable grid at n=512)
    hot8 = TrafficPattern.hotspot(topo8.n, list(range(8)), 0.4)
    t0 = time.time()
    sat_s8, tr_s8 = NS.saturation_point(atab8, step=0.005, max_rate=0.08,
                                        cycles=1500, warmup=500,
                                        traffic=hot8)
    t_stat8 = time.time() - t0
    t0 = time.time()
    sat_a8, tr_a8 = NS.saturation_point(atab8, step=0.005, max_rate=0.08,
                                        cycles=1500, warmup=500,
                                        traffic=hot8, adaptive=spec8)
    t_adapt8 = time.time() - t0
    n512["adaptive"] = {
        "hotspot_sat_static": round(sat_s8, 5),
        "hotspot_sat_adaptive": round(sat_a8, 5),
        "sat_static_s": round(t_stat8, 4),
        "sat_adaptive_s": round(t_adapt8, 4),
        # lanes whose livelock watchdog fired during the hotspot probes
        "stalled_lanes_static": sum(1 for r in tr_s8
                                    if r["stalled_at"] >= 0),
        "stalled_lanes_adaptive": sum(1 for r in tr_a8
                                      if r["stalled_at"] >= 0),
    }
    print(f"  n512 adaptive: hotspot sat static={sat_s8:.4f} "
          f"adaptive={sat_a8:.4f} ({t_stat8:.1f}s/{t_adapt8:.1f}s)")
    emit("bench_netsim_n512_adaptive_hotspot_sat", 0,
         f"static={sat_s8:.4f} adaptive={sat_a8:.4f}")
    if json_path:
        # within-run quality guard: adaptive saturation collapsing below
        # static under hotspot means the escape/overflow policy broke
        guard_regression("netsim_n512_adaptive_hotspot_sat", sat_a8,
                         sat_s8, 1.0, larger_is_worse=False)

    # ---- 12^3 saturation entry (--full; record kept across runs) -----
    if full:
        topo12 = T.pt(FULL_SPEC)
        s12: dict = {}
        t0 = time.time()
        tab12 = route_pod(topo12, PipelineConfig(
            K=4, local_search_rounds=1, engine="sharded")).tables
        t_route12 = time.time() - t0
        t0 = time.time()
        sat12, trace12 = NS.saturation_point(
            tab12, step=0.05, max_rate=0.5, cycles=1200, warmup=400,
            stats=s12)
        t_sat12 = time.time() - t0
        assert all(r["injected_total"] == r["consumed_total"]
                   + r["in_flight"] for r in trace12)
        result["n1728"] = {
            "pod": list(FULL_SPEC),
            "route_s": round(t_route12, 3),
            "sat_sweep_s": round(t_sat12, 3),
            "saturation_uniform": round(sat12, 5),
            "l_max": float(sel12.l_max),
            "csr_array_bytes": int(s12["array_bytes"]),
            "kernel": s12["kernel"],
            "peak_rss_mb": peak_rss_mb(),
        }
        print(f"  n1728: route={t_route12:.1f}s sat_sweep={t_sat12:.1f}s "
              f"sat={sat12:.4f} csr_bytes={s12['array_bytes']:,} "
              f"rss={result['n1728']['peak_rss_mb']}MB")
        emit("bench_netsim_n1728_sat", t_sat12 * 1e6, f"{sat12:.4f}")
    elif prior.get("n1728"):
        # keep the --full record around on quick runs (baseline may be
        # missing on a fresh checkout -- guards and readers tolerate it)
        result["n1728"] = prior["n1728"]

    if json_path:
        if prior.get("sweep_speedup_vs_seed"):
            print(f"  prior sweep speedup: "
                  f"{prior['sweep_speedup_vs_seed']}x")
        Path(json_path).write_text(json.dumps(result, indent=2) + "\n")
        print(f"  wrote {json_path}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    main(args.full,
         json_path=Path(__file__).parent.parent / "BENCH_netsim.json"
         if args.json else None)
