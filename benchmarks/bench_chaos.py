"""Chaos campaign benchmark: seeded multi-fault timeline at 8^3.

The lane builds the 512-chip serving configuration (PDTT fabric, robust
AT, n_vc=2, K=4 -- the same state the bench_routing repair lane and
tests/test_repair.py exercise), samples a >= 20-event fault/heal
schedule (storms with overlapping arrivals, correlated link groups
including a guaranteed node isolation served degraded, restorations,
and a final heal) and drives the state through it with
:func:`repro.core.chaos.run_campaign`. Every event's invariant suite
must come back green and the post-heal fabric must recover full
reachability with ``l_max`` within ``POST_HEAL_L_MAX`` of the cold
build it started from.

Guards (skip cleanly when BENCH_chaos.json has no baseline yet):
campaign wall-clock 1.5x vs the stored baseline, and the post-heal
l_max ratio against a fixed 1.0 baseline with the 1.10x quality bound.
``--full`` adds netsim throughput probes along the timeline (degraded
tables compacted through the CSR kernel, watchdog outputs included).
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

from benchmarks.common import emit, guard_regression, load_bench_json

CAMPAIGN_REGRESSION = 1.5   # campaign wall-clock guard vs stored baseline
POST_HEAL_L_MAX = 1.10      # post-heal l_max quality bound vs cold build


def main(full: bool = False, json_path=None) -> dict:
    import numpy as np

    from repro.core import chaos as X, topology as T
    from repro.core.repair import ServingState

    prior = load_bench_json(json_path) if json_path else {}
    result: dict = {"campaign": {}}
    out = result["campaign"]
    for name, spec in [("n512", (8, 8, 8))]:
        topo = T.pdtt(spec)
        t0 = time.time()
        st = ServingState.build(topo, n_vc=2, K=4, seed=0, robust=True)
        t_build = time.time() - t0
        sched = X.generate_schedule(st.at, n_arrivals=20, seed=7)
        assert sched.n_events >= 20, sched.kinds()
        t0 = time.time()
        res = X.run_campaign(st, sched, coalesce=1.0,
                             probe_every=5 if full else 0)
        t_campaign = time.time() - t0

        # acceptance coverage: a coalesced storm, a degraded-mode event
        # (lost pairs served without cold recompute), a restoration, and
        # every invariant of every event green
        recs = res.records
        assert any(r.kind == "storm" and r.coalesced > 1 for r in recs)
        assert any(r.lost_pairs > 0 and not r.fallback for r in recs)
        assert any(r.kind == "restore" for r in recs)
        assert not any(r.fallback for r in recs)
        assert res.ok, [r.invariants for r in recs if not r.ok]
        # final heal recovered every pair
        assert len(res.state.lost) == 0
        assert res.state.table.n_routed() == res.state.table.n_flows
        ratio = float(res.state.l_max) / max(res.baseline_l_max, 1e-9)

        mttrs = np.array([r.mttr_s for r in recs])
        out[name] = {
            "pod": list(spec),
            "build_s": round(t_build, 3),
            "campaign_s": round(t_campaign, 3),
            "n_events": sched.n_events,
            "n_groups": len(recs),
            "kinds": sched.kinds(),
            "max_coalesced": max(r.coalesced for r in recs),
            "mttr_median_s": round(float(np.median(mttrs)), 3),
            "mttr_max_s": round(float(mttrs.max()), 3),
            "flows_rerouted": int(sum(r.flows_rerouted for r in recs)),
            "min_served_fraction": round(res.min_served_fraction, 6),
            "max_lost_pairs": max(r.lost_pairs for r in recs),
            "baseline_l_max": res.baseline_l_max,
            "post_heal_l_max": float(res.state.l_max),
            "post_heal_l_max_ratio": round(ratio, 4),
            "invariants_ok": res.ok,
        }
        if full:
            probes = [r.probe for r in recs if r.probe is not None]
            base = (res.baseline_probe or {}).get("delivered", 0.0)
            out[name]["probes"] = {
                "baseline": res.baseline_probe,
                "n_probes": len(probes),
                "min_throughput_retained": round(min(
                    (p["delivered"] / base for p in probes), default=1.0),
                    4) if base else None,
                "stalled_lanes": sum(p["stalled_at"] >= 0 for p in probes),
            }
        print(f"  {name}: campaign={t_campaign:.1f}s "
              f"(build={t_build:.1f}s) events={sched.n_events} "
              f"groups={len(recs)} kinds={sched.kinds()} "
              f"max_coalesced={out[name]['max_coalesced']} "
              f"mttr med/max={out[name]['mttr_median_s']:.2f}/"
              f"{out[name]['mttr_max_s']:.2f}s")
        print(f"        min served={res.min_served_fraction:.4f} "
              f"max lost={out[name]['max_lost_pairs']} "
              f"post-heal lmax {res.state.l_max:.0f}/"
              f"{res.baseline_l_max:.0f} ({ratio:.3f}x) "
              f"invariants={'green' if res.ok else 'RED'}")

    n512 = out["n512"]
    emit("bench_chaos_n512", n512["campaign_s"] * 1e6,
         f"events={n512['n_events']} "
         f"min_served={n512['min_served_fraction']:.4f} "
         f"ratio={n512['post_heal_l_max_ratio']:.3f}")
    if json_path:
        prior_c = prior.get("campaign", {}).get("n512", {})
        guard_regression("chaos_n512_campaign_s", n512["campaign_s"],
                         prior_c.get("campaign_s"), CAMPAIGN_REGRESSION)
        # quality guard: fixed 1.0 baseline -> trips when the healed
        # fabric's l_max drifts past POST_HEAL_L_MAX x the cold build
        guard_regression("chaos_n512_post_heal_l_max_ratio",
                         n512["post_heal_l_max_ratio"], 1.0,
                         POST_HEAL_L_MAX)
        if not full and "probes" in prior_c and "probes" not in n512:
            n512["probes"] = prior_c["probes"]   # keep the --full record
        import json
        Path(json_path).write_text(json.dumps(result, indent=2) + "\n")
        print(f"  wrote {json_path}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", action="store_true")
    a = ap.parse_args()
    main(a.full,
         json_path=Path(__file__).parent.parent / "BENCH_chaos.json"
         if a.json else None)
