"""Fig. 6: all-gather / all-reduce / all-to-all schedule utilization."""
from __future__ import annotations

import argparse

from benchmarks.common import emit, load_tons, timed


def main(full: bool = False) -> None:
    from repro.core import collectives as C, topology as T
    from repro.core.mcf import mcf_uniform
    from repro.core.pipeline import PipelineConfig, route_pod

    cases = [("PT", T.pt((4, 4, 8)), 0.0078125)]
    loaded = load_tons(128)
    if loaded:
        cases.append(("TONS", loaded[0], loaded[1]["mcf"]))
    print("# collective utilization (paper Fig. 6: AG/AR near-ideal for "
          "all; TONS tracks a higher a2a MCF limit)")
    for name, topo, lam in cases:
        routed = route_pod(topo, PipelineConfig(
            K=4, engine="array", local_search_rounds=3,
            vc="none")).routed
        (rep, us) = timed(C.collective_report, topo, routed, lam)
        for kind, r in rep.items():
            print(f"  {name:5s} {kind:11s}: util={r['utilization']:.3f} "
                  f"(mcf-limit util={r['mcf_limit_utilization']:.3f})")
        emit(f"fig6_{name.lower()}_a2a", us,
             f"util={rep['all-to-all']['utilization']:.3f}")
        # effective a2a bandwidth for the framework's collective term
        bw = C.effective_a2a_bandwidth(lam, topo.n)
        print(f"  {name:5s} effective per-node a2a bw: {bw / 1e9:.1f} GB/s")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(ap.parse_args().full)
