"""Routing-engine perf tracking: array state-CSR pipeline + batched
allowed-turns admission vs the seed's per-source python BFS / serial
Pearce-Kelly (kept as ``engine="reference"`` / ``at_engine="reference"``).

Measures, on PT pods of 64 / 256 / 512 chips (4^3 / 4x8x8 / 8^3), plus an
opt-in 1728-chip 12^3 pod under ``--full``:

- wall-clock of the allowed-turns construction for both AT engines (the
  serial reference is skipped above ``REF_CAP`` nodes in quick mode;
  ``--full`` extends the comparison and the exact-set equivalence assert
  up to the 512-chip pod -- at 12^3 the serial reference takes many
  minutes, so only the batched engine runs there),
  with the batched engine's admission breakdown (admitted per block,
  forward/bulk vs tangle-replayed commits, BFS rows, conflict blocks);
- wall-clock of candidate enumeration + min-max path selection for both
  selection engines, and the achieved L_max of both;
- the full 8^3 (and, with ``--full``, 12^3) end-to-end chain: allowed
  turns -> candidate enumeration -> path selection -> VC allocation ->
  simulator tables.

``--json`` (or ``main(json_path=...)``) writes BENCH_routing.json so the
perf trajectory is tracked from PR to PR; prior results, if any, are
loaded tolerantly and printed for comparison, and a regression guard
warns when the 8^3 ``allowed_turns_s`` regresses more than 1.5x against
the stored baseline.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent))

from benchmarks.common import emit, load_bench_json

SPECS = [("n64", (4, 4, 4)), ("n256", (4, 8, 8)), ("n512", (8, 8, 8))]
FULL_SPECS = [("n1728", (12, 12, 12))]
REF_CAP = 256          # largest pod the reference engines run in quick mode
AT_REGRESSION = 1.5    # warn when 8^3 allowed_turns_s regresses past this


def _at_breakdown(at) -> dict:
    """Condensed admission stats of the batched allowed-turns engine."""
    s = at.stats or {}
    apb = s.get("admitted_per_block", [])
    return {
        "blocks": s.get("blocks", 0),
        "admitted_per_block_mean": round(sum(apb) / max(len(apb), 1), 1),
        "fwd_bulk": s.get("fwd_bulk", 0),
        "contested_bulk": s.get("contested_bulk", 0),
        "tangle_commits": s.get("tangle_commits", 0),
        "bfs_rows": s.get("bfs_rows", 0),
        "conflict_blocks": s.get("conflict_rounds", 0),
        "scc_checks": s.get("scc_checks", 0),
    }


def main(full: bool = False, json_path=None) -> dict:
    from repro.core import netsim as NS, routing as R, topology as T

    prior = load_bench_json(json_path) if json_path else {}
    result: dict = {"K": 4, "local_search_rounds": 2, "sizes": {}}
    # warm both engines once (scipy imports + numpy dispatch) so the
    # recorded wall-clocks compare codepaths, not cold import order
    warm = T.pt((4, 4, 4))
    R.allowed_turns(warm, n_vc=2, priority="apl")
    R.allowed_turns(warm, n_vc=2, priority="apl", at_engine="reference")
    specs = SPECS + (FULL_SPECS if full else [])
    for name, spec in specs:
        topo = T.pt(spec)
        t0 = time.time()
        at = R.allowed_turns(topo, n_vc=2, priority="apl")
        t_at = time.time() - t0
        row = {
            "pod": list(spec),
            "allowed_turns_s": round(t_at, 3),
            "allowed_turns": _at_breakdown(at),
        }
        if topo.n <= REF_CAP or (full and topo.n <= 512):
            t0 = time.time()
            at_ref = R.allowed_turns(topo, n_vc=2, priority="apl",
                                     at_engine="reference")
            t_at_ref = time.time() - t0
            row["allowed_turns_ref_s"] = round(t_at_ref, 3)
            row["at_speedup"] = round(t_at_ref / max(t_at, 1e-9), 2)
            assert at.allowed == at_ref.allowed, "AT engines diverged"
        # sub-second timings at 64 chips are noisy: take best-of-3
        reps = 3 if topo.n <= 64 else 1
        t_arr = float("inf")
        for _ in range(reps):
            t0 = time.time()
            arr = R.select_paths(at, K=4, local_search_rounds=2,
                                 engine="array")
            t_arr = min(t_arr, time.time() - t0)
        row.update({
            "array_select_s": round(t_arr, 3),
            "array_l_max": arr.l_max,
            "avg_hops": round(arr.avg_hops, 4),
            "unreachable": arr.unreachable,
        })
        bd = row["allowed_turns"]
        print(f"  {name}: allowed_turns={t_at:.2f}s "
              f"(blocks={bd['blocks']} "
              f"admitted/block={bd['admitted_per_block_mean']:.0f} "
              f"bulk={bd['fwd_bulk'] + bd['contested_bulk']} "
              f"tangle={bd['tangle_commits']} "
              f"conflicts={bd['conflict_blocks']})"
              + (f" vs reference={row['allowed_turns_ref_s']:.2f}s "
                 f"-> {row['at_speedup']:.1f}x"
                 if "at_speedup" in row else ""))
        if topo.n <= REF_CAP or (full and topo.n <= 512):
            t_ref = float("inf")
            for _ in range(reps):
                t0 = time.time()
                ref = R.select_paths(at, K=4, local_search_rounds=2,
                                     engine="reference")
                t_ref = min(t_ref, time.time() - t0)
            row["reference_select_s"] = round(t_ref, 3)
            row["reference_l_max"] = ref.l_max
            row["speedup"] = round(t_ref / max(t_arr, 1e-9), 2)
            print(f"  {name}: reference={t_ref:.2f}s array={t_arr:.2f}s "
                  f"-> {row['speedup']:.1f}x  "
                  f"lmax {arr.l_max:.0f}/{ref.l_max:.0f}")
        else:
            print(f"  {name}: array={t_arr:.2f}s lmax={arr.l_max:.0f} "
                  f"(reference select skipped)")
        if topo.n >= 512:
            t0 = time.time()
            tab = NS.at_tables(topo, at, arr)
            t_tab = time.time() - t0
            row["vcalloc_tables_s"] = round(t_tab, 3)
            row["end_to_end_s"] = round(t_at + t_arr + t_tab, 3)
            print(f"  {name}: end-to-end (AT -> paths -> VC alloc -> "
                  f"tables) = {row['end_to_end_s']:.1f}s "
                  f"unreachable={arr.unreachable}")
        result["sizes"][name] = row
    sp = result["sizes"]["n64"].get("speedup", 0.0)
    emit("bench_routing_speedup_n64",
         result["sizes"]["n64"]["array_select_s"] * 1e6, f"{sp:.2f}x")
    emit("bench_routing_e2e_n512",
         result["sizes"]["n512"]["end_to_end_s"] * 1e6,
         f"lmax={result['sizes']['n512']['array_l_max']:.0f}")
    emit("bench_routing_at_n512",
         result["sizes"]["n512"]["allowed_turns_s"] * 1e6,
         f"blocks={result['sizes']['n512']['allowed_turns']['blocks']}")
    # perf-regression guard against the stored baseline
    prior_at = prior.get("sizes", {}).get("n512", {}).get("allowed_turns_s")
    now_at = result["sizes"]["n512"]["allowed_turns_s"]
    if prior_at and now_at > AT_REGRESSION * prior_at:
        print(f"  WARNING: n512 allowed_turns_s regressed "
              f"{now_at:.2f}s vs baseline {prior_at:.2f}s "
              f"(> {AT_REGRESSION}x)")
        emit("bench_routing_at_regression", now_at * 1e6,
             f"baseline={prior_at}")
    if prior.get("sizes", {}).get("n64", {}).get("speedup"):
        print(f"  prior n64 speedup: {prior['sizes']['n64']['speedup']}x")
    if json_path:
        prior_full = prior.get("sizes", {}).get("n1728")
        if not full and prior_full:      # keep the 12^3 record around
            result["sizes"]["n1728"] = prior_full
        Path(json_path).write_text(json.dumps(result, indent=2) + "\n")
        print(f"  wrote {json_path}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    main(args.full,
         json_path=Path(__file__).parent.parent / "BENCH_routing.json"
         if args.json else None)
