"""Routing-engine perf tracking: array state-CSR pipeline, streaming
sharded engine + batched allowed-turns admission vs the seed's per-source
python BFS / serial Pearce-Kelly (kept as ``engine="reference"`` /
``at_engine="reference"``).

Measures, on PT pods of 64 / 256 / 512 chips (4^3 / 4x8x8 / 8^3), plus
opt-in 1728-chip 12^3 and 4096-chip 16^3 pods under ``--full``:

- wall-clock of the allowed-turns construction for both AT engines (the
  serial reference is skipped above ``REF_CAP`` nodes in quick mode;
  ``--full`` extends the comparison and the exact-set equivalence assert
  up to the 512-chip pod), with the batched engine's admission breakdown
  (admitted per block, forward/bulk vs tangle-replayed commits, BFS rows,
  conflict blocks);
- wall-clock and per-stage split (enumerate vs greedy vs local search vs
  hot peel/walk) of the array selection engine, and of the streaming
  sharded engine (BFS vs walk vs greedy vs refinement, with the hot-pool
  and moved-flow counters), plus both engines' achieved L_max;
- VC allocation with the exact-lookahead assignment, surfacing the
  ``greedy_dead_ends`` counter -- flows the old first-fit would have sent
  to the per-flow DFS fallback (~45% at 8^3; previously invisible);
- the full 8^3 end-to-end chain, and with ``--full`` the 12^3 / 16^3
  chains routed by the sharded engine into a packed CSR PathTable
  (allowed turns -> sharded select -> VC alloc -> simulator tables).

Also runs the **time-to-recover lane**: build a live
:class:`repro.core.repair.ServingState` at 8^3 (PDTT fabric, robust
AT, n_vc=2, K=4 -- the serving configuration), kill one OCS, and
measure :func:`repro.core.repair.repair_fault` against the
:func:`full_recompute` oracle -- repair wall clock, flows re-routed and
the post-repair ``l_max`` ratio land in the JSON, ``--full`` extends the
lane to the 12^3 pod.

``--json`` (or ``main(json_path=...)``) writes BENCH_routing.json so the
perf trajectory is tracked from PR to PR; prior results, if any, are
loaded tolerantly and printed for comparison (guards skip with a warning
on a fresh checkout with no stored baseline), and regression guards warn
-- and trip ``run.py --check`` -- when the 8^3 ``allowed_turns_s``,
``array_select_s`` or the repair lane's ``repair_s`` regress more than
1.5x against the stored baseline, or when the post-repair ``l_max``
exceeds 1.10x of the full recompute's. Guarded timings are the *median
of 3* repeats: container timing is noisy enough that single-shot 1.5x
guards false-positive.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent))

from benchmarks.common import (emit, guard_regression, load_bench_json,
                               median_timed, peak_rss_mb)

SPECS = [("n64", (4, 4, 4)), ("n256", (4, 8, 8)), ("n512", (8, 8, 8))]
FULL_SPECS = [("n1728", (12, 12, 12)), ("n4096", (16, 16, 16))]
REF_CAP = 256          # largest pod the reference engines run in quick mode
SHARDED_ONLY = 1000    # above this, only the sharded engine routes
AT_REGRESSION = 1.5    # warn when 8^3 allowed_turns_s regresses past this
SELECT_REGRESSION = 1.5  # same guard for the 8^3 array_select_s
REPAIR_REGRESSION = 1.5  # same guard for the 8^3 single-OCS repair wall
REPAIR_L_MAX = 1.10    # post-repair l_max quality bound vs full recompute


def _at_breakdown(at) -> dict:
    """Condensed admission stats of the batched allowed-turns engine."""
    s = at.stats or {}
    apb = s.get("admitted_per_block", [])
    return {
        "blocks": s.get("blocks", 0),
        "admitted_per_block_mean": round(sum(apb) / max(len(apb), 1), 1),
        "fwd_bulk": s.get("fwd_bulk", 0),
        "contested_bulk": s.get("contested_bulk", 0),
        "tangle_commits": s.get("tangle_commits", 0),
        "bfs_rows": s.get("bfs_rows", 0),
        "conflict_blocks": s.get("conflict_rounds", 0),
        "scc_checks": s.get("scc_checks", 0),
    }


def _sharded_breakdown(routed) -> dict:
    """Condensed stage split + refinement counters of the sharded engine."""
    s = routed.stats or {}
    return {k: s.get(k, 0) for k in
            ("bfs_s", "walk_s", "greedy_s", "refine_s", "greedy_l_max",
             "refine_pool", "refine_moved", "refine_iters", "k_full_flows",
             "rounds", "k_min", "refine_cap", "uniq_flows", "uniq_s")}


def _select_stages(routed) -> dict:
    """Per-stage wall-clock of the array selection engine."""
    s = routed.stats or {}
    return {k: s.get(k, 0.0) for k in
            ("enumerate_s", "greedy_s", "local_search_s", "hot_peel_s",
             "hot_walk_s")}


def _repair_lane(full: bool, prior: dict, result: dict,
                 json_path) -> None:
    """Time-to-recover: single-OCS failure under a live serving state.

    The lane runs the serving configuration (PDTT fabric, robust AT,
    n_vc=2, K=4) -- the state an online fabric actually repairs from,
    on the fabric fig8 and tests/test_repair.py exercise. The n512
    repair wall is a median of 3 (the repair path is pure, so repeats
    are exact re-runs) and feeds a 1.5x guard; the post-repair l_max
    ratio vs the full-recompute oracle feeds a 1.10x quality guard.
    """
    from repro.core import fault as F, topology as T
    from repro.core.repair import ServingState, full_recompute, repair_fault

    out = result.setdefault("repair", {})
    specs = [("n512", (8, 8, 8))] + \
        ([("n1728", (12, 12, 12))] if full else [])
    for name, spec in specs:
        topo = T.pdtt(spec)      # the paper fabric fig8/test_repair use
        t0 = time.time()
        st = ServingState.build(topo, n_vc=2, K=4, seed=0, robust=True)
        t_build = time.time() - t0
        dead = F.dead_channels_for_color(st.at, F.colors_in_use(topo)[0])
        rr, t_rep = median_timed(lambda: repair_fault(st, dead),
                                 repeats=3 if name == "n512" else 1)
        routed, _, _ = full_recompute(st, dead)
        ratio = rr.l_max / max(routed.l_max, 1e-9)
        out[name] = {
            "pod": list(spec),
            "build_s": round(t_build, 3),
            "repair_s": round(t_rep, 3),
            "flows_rerouted": rr.flows_rerouted,
            "readmitted": rr.readmitted,
            "unreachable": rr.unreachable,
            "deadlock_free": rr.deadlock_free,
            "fallback": rr.fallback,
            "repair_l_max": rr.l_max,
            "recompute_l_max": routed.l_max,
            "repair_l_max_ratio": round(ratio, 4),
            "repair_stages": {k: round(v, 3) if isinstance(v, float)
                              else v for k, v in rr.stats.items()},
        }
        print(f"  {name}: repair={t_rep:.2f}s (build={t_build:.1f}s -> "
              f"{t_build / max(t_rep, 1e-9):.0f}x faster than cold) "
              f"flows={rr.flows_rerouted} readmit={rr.readmitted} "
              f"lmax {rr.l_max:.0f}/{routed.l_max:.0f} "
              f"({ratio:.3f}x) unreachable={rr.unreachable}")
        assert rr.deadlock_free and rr.unreachable == 0 and not rr.fallback
    n512 = out["n512"]
    emit("bench_routing_repair_n512", n512["repair_s"] * 1e6,
         f"flows={n512['flows_rerouted']} "
         f"ratio={n512['repair_l_max_ratio']:.3f}")
    if json_path:
        prior_rep = prior.get("repair", {}).get("n512", {})
        guard_regression("routing_n512_repair_s", n512["repair_s"],
                         prior_rep.get("repair_s"), REPAIR_REGRESSION)
        # quality guard: fixed 1.0 baseline -> trips when the repaired
        # l_max drifts past REPAIR_L_MAX x the full-recompute oracle
        guard_regression("routing_n512_repair_l_max_ratio",
                         n512["repair_l_max_ratio"], 1.0, REPAIR_L_MAX)
        prior_full = prior.get("repair", {}).get("n1728")
        if not full and prior_full and "n1728" not in out:
            out["n1728"] = prior_full   # keep the --full record around


def main(full: bool = False, json_path=None) -> dict:
    from repro.core import netsim as NS, routing as R, topology as T, \
        vcalloc as V

    prior = load_bench_json(json_path) if json_path else {}
    result: dict = {"K": 4, "local_search_rounds": 2, "sizes": {}}
    # warm both engines once (scipy imports + numpy dispatch) so the
    # recorded wall-clocks compare codepaths, not cold import order
    warm = T.pt((4, 4, 4))
    R.allowed_turns(warm, n_vc=2, priority="apl")
    R.allowed_turns(warm, n_vc=2, priority="apl", at_engine="reference")
    specs = SPECS + (FULL_SPECS if full else [])
    for name, spec in specs:
        topo = T.pt(spec)
        # the n512 allowed_turns_s and array_select_s feed the 1.5x
        # regression guards -> median of 3 repeats (single-shot container
        # timings false-positive); everything else stays single-shot
        guard_reps = 3 if name == "n512" else 1
        at, t_at = median_timed(
            lambda: R.allowed_turns(topo, n_vc=2, priority="apl"),
            repeats=guard_reps)
        row = {
            "pod": list(spec),
            "allowed_turns_s": round(t_at, 3),
            "allowed_turns": _at_breakdown(at),
        }
        if topo.n <= REF_CAP or (full and topo.n <= 512):
            t0 = time.time()
            at_ref = R.allowed_turns(topo, n_vc=2, priority="apl",
                                     at_engine="reference")
            t_at_ref = time.time() - t0
            row["allowed_turns_ref_s"] = round(t_at_ref, 3)
            row["at_speedup"] = round(t_at_ref / max(t_at, 1e-9), 2)
            assert at.allowed == at_ref.allowed, "AT engines diverged"
        bd = row["allowed_turns"]
        print(f"  {name}: allowed_turns={t_at:.2f}s "
              f"(blocks={bd['blocks']} "
              f"admitted/block={bd['admitted_per_block_mean']:.0f} "
              f"bulk={bd['fwd_bulk'] + bd['contested_bulk']} "
              f"tangle={bd['tangle_commits']} "
              f"conflicts={bd['conflict_blocks']})"
              + (f" vs reference={row['allowed_turns_ref_s']:.2f}s "
                 f"-> {row['at_speedup']:.1f}x"
                 if "at_speedup" in row else ""))
        # sub-second timings at 64 chips are noisy: take median-of-3
        reps = 3 if topo.n <= 64 else 1
        if topo.n <= SHARDED_ONLY:
            arr, t_arr = median_timed(
                lambda: R.select_paths(at, K=4, local_search_rounds=2,
                                       engine="array"),
                repeats=max(reps, guard_reps))
            st = _select_stages(arr)
            row.update({
                "array_select_s": round(t_arr, 3),
                "array_select_stages": st,
                "array_l_max": arr.l_max,
                "avg_hops": round(arr.avg_hops, 4),
                "unreachable": arr.unreachable,
            })
            print(f"  {name}: array={t_arr:.2f}s lmax={arr.l_max:.0f} "
                  f"(enum={st['enumerate_s']:.2f} "
                  f"greedy={st['greedy_s']:.2f} "
                  f"ls={st['local_search_s']:.2f} "
                  f"peel={st['hot_peel_s']:.2f} "
                  f"walk={st['hot_walk_s']:.2f})")
        # streaming sharded engine (the only engine above SHARDED_ONLY)
        sh, t_sh = median_timed(
            lambda: R.select_paths(at, K=4, local_search_rounds=2,
                                   engine="sharded"), repeats=reps)
        sbd = _sharded_breakdown(sh)
        row.update({
            "sharded_select_s": round(t_sh, 3),
            "sharded_select_stages": sbd,
            "sharded_l_max": sh.l_max,
        })
        # the l_max delta vs the stored baseline tracks the refinement
        # levers (auto-scaled refine_cap, kcap=1 uniq lane) size by size
        prior_lmax = prior.get("sizes", {}).get(name,
                                                {}).get("sharded_l_max")
        if prior_lmax:
            row["sharded_l_max_delta"] = round(sh.l_max - prior_lmax, 1)
        if "array_l_max" not in row:
            row["avg_hops"] = round(sh.avg_hops, 4)
            row["unreachable"] = sh.unreachable
        ref_lmax = row.get("array_l_max") or \
            prior.get("sizes", {}).get(name, {}).get("array_l_max")
        ratio = f" ({sh.l_max / ref_lmax:.3f}x of array)" if ref_lmax else ""
        print(f"  {name}: sharded={t_sh:.2f}s lmax={sh.l_max:.0f}{ratio} "
              f"(bfs={sbd['bfs_s']:.2f} walk={sbd['walk_s']:.2f} "
              f"greedy={sbd['greedy_s']:.2f} refine={sbd['refine_s']:.2f} "
              f"pool={sbd['refine_pool']} moved={sbd['refine_moved']} "
              f"k_full={sbd['k_full_flows']} uniq={sbd['uniq_flows']} "
              f"cap={sbd['refine_cap']})")
        if topo.n <= REF_CAP or (full and topo.n <= 512):
            ref, t_ref = median_timed(
                lambda: R.select_paths(at, K=4, local_search_rounds=2,
                                       engine="reference"), repeats=reps)
            row["reference_select_s"] = round(t_ref, 3)
            row["reference_l_max"] = ref.l_max
            row["speedup"] = round(t_ref / max(row["array_select_s"],
                                               1e-9), 2)
            print(f"  {name}: reference={t_ref:.2f}s "
                  f"array={row['array_select_s']:.2f}s "
                  f"-> {row['speedup']:.1f}x  "
                  f"lmax {row['array_l_max']:.0f}/{ref.l_max:.0f}")
        if topo.n >= 512:
            routed = sh if topo.n > SHARDED_ONLY else arr
            vstats: dict = {}
            t0 = time.time()
            tab = NS.at_tables(topo, at, routed, stats=vstats)
            t_tab = time.time() - t0
            sel_s = row.get("array_select_s", row["sharded_select_s"])
            row["vcalloc_tables_s"] = round(t_tab, 3)
            row["vcalloc_greedy_dead_ends"] = \
                vstats.get("greedy_dead_ends", 0)
            row["end_to_end_s"] = round(t_at + sel_s + t_tab, 3)
            assert V.verify_deadlock_free(at, tab.table)
            print(f"  {name}: end-to-end (AT -> paths -> VC alloc -> "
                  f"tables) = {row['end_to_end_s']:.1f}s "
                  f"unreachable={row['unreachable']} "
                  f"vc_dead_ends={row['vcalloc_greedy_dead_ends']} "
                  f"(resolved by lookahead, no DFS)")
        result["sizes"][name] = row
    sp = result["sizes"]["n64"].get("speedup", 0.0)
    emit("bench_routing_speedup_n64",
         result["sizes"]["n64"]["array_select_s"] * 1e6, f"{sp:.2f}x")
    emit("bench_routing_e2e_n512",
         result["sizes"]["n512"]["end_to_end_s"] * 1e6,
         f"lmax={result['sizes']['n512']['array_l_max']:.0f}")
    emit("bench_routing_at_n512",
         result["sizes"]["n512"]["allowed_turns_s"] * 1e6,
         f"blocks={result['sizes']['n512']['allowed_turns']['blocks']}")
    # perf-regression guards against the stored baseline (median-of-3
    # timings; skip with a warning when no baseline exists yet)
    if json_path:
        prior_512 = prior.get("sizes", {}).get("n512", {})
        for key, bound in (("allowed_turns_s", AT_REGRESSION),
                           ("array_select_s", SELECT_REGRESSION)):
            guard_regression(f"routing_n512_{key}",
                             result["sizes"]["n512"].get(key),
                             prior_512.get(key), bound)
    _repair_lane(full, prior, result, json_path)
    result["peak_rss_mb"] = peak_rss_mb()
    if prior.get("sizes", {}).get("n64", {}).get("speedup"):
        print(f"  prior n64 speedup: {prior['sizes']['n64']['speedup']}x")
    if json_path:
        for keep in ("n1728", "n4096"):     # keep the --full records around
            prior_full = prior.get("sizes", {}).get(keep)
            if not full and prior_full and keep not in result["sizes"]:
                result["sizes"][keep] = prior_full
        Path(json_path).write_text(json.dumps(result, indent=2) + "\n")
        print(f"  wrote {json_path}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    main(args.full,
         json_path=Path(__file__).parent.parent / "BENCH_routing.json"
         if args.json else None)
