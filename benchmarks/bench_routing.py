"""Routing-engine perf tracking: array state-CSR pipeline vs the seed's
per-source python BFS + per-flow greedy (kept as ``engine="reference"``).

Measures, on PT pods of 64 / 256 / 512 chips (4^3 / 4x8x8 / 8^3):

- wall-clock of candidate enumeration + min-max path selection for both
  engines (the reference is skipped above ``REF_CAP`` nodes unless
  ``--full`` -- it is minutes-slow there, which is the point);
- achieved L_max of both (the array engine must stay within a few % --
  it usually wins);
- the full 8^3 end-to-end chain: allowed turns -> candidate enumeration
  -> path selection -> VC allocation -> simulator tables.

``--json`` (or ``main(json_path=...)``) writes BENCH_routing.json so the
perf trajectory is tracked from PR to PR; prior results, if any, are
loaded tolerantly and printed for comparison.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent))

from benchmarks.common import emit, load_bench_json

SPECS = [("n64", (4, 4, 4)), ("n256", (4, 8, 8)), ("n512", (8, 8, 8))]
REF_CAP = 256          # largest pod the reference engine runs in quick mode


def main(full: bool = False, json_path=None) -> dict:
    from repro.core import netsim as NS, routing as R, topology as T

    prior = load_bench_json(json_path) if json_path else {}
    result: dict = {"K": 4, "local_search_rounds": 2, "sizes": {}}
    for name, spec in SPECS:
        topo = T.pt(spec)
        t0 = time.time()
        at = R.allowed_turns(topo, n_vc=2, priority="apl")
        t_at = time.time() - t0
        # sub-second timings at 64 chips are noisy: take best-of-3
        reps = 3 if topo.n <= 64 else 1
        t_arr = float("inf")
        for _ in range(reps):
            t0 = time.time()
            arr = R.select_paths(at, K=4, local_search_rounds=2,
                                 engine="array")
            t_arr = min(t_arr, time.time() - t0)
        row = {
            "pod": list(spec),
            "allowed_turns_s": round(t_at, 3),
            "array_select_s": round(t_arr, 3),
            "array_l_max": arr.l_max,
            "avg_hops": round(arr.avg_hops, 4),
            "unreachable": arr.unreachable,
        }
        if topo.n <= REF_CAP or full:
            t_ref = float("inf")
            for _ in range(reps):
                t0 = time.time()
                ref = R.select_paths(at, K=4, local_search_rounds=2,
                                     engine="reference")
                t_ref = min(t_ref, time.time() - t0)
            row["reference_select_s"] = round(t_ref, 3)
            row["reference_l_max"] = ref.l_max
            row["speedup"] = round(t_ref / max(t_arr, 1e-9), 2)
            print(f"  {name}: reference={t_ref:.2f}s array={t_arr:.2f}s "
                  f"-> {row['speedup']:.1f}x  "
                  f"lmax {arr.l_max:.0f}/{ref.l_max:.0f}")
        else:
            print(f"  {name}: array={t_arr:.2f}s lmax={arr.l_max:.0f} "
                  f"(reference skipped; --full runs it)")
        if topo.n == 512:
            t0 = time.time()
            tab = NS.at_tables(topo, at, arr)
            t_tab = time.time() - t0
            row["vcalloc_tables_s"] = round(t_tab, 3)
            row["end_to_end_s"] = round(t_at + t_arr + t_tab, 3)
            print(f"  {name}: end-to-end (AT -> paths -> VC alloc -> "
                  f"tables) = {row['end_to_end_s']:.1f}s")
        result["sizes"][name] = row
    sp = result["sizes"]["n64"].get("speedup", 0.0)
    emit("bench_routing_speedup_n64",
         result["sizes"]["n64"]["array_select_s"] * 1e6, f"{sp:.2f}x")
    emit("bench_routing_e2e_n512",
         result["sizes"]["n512"]["end_to_end_s"] * 1e6,
         f"lmax={result['sizes']['n512']['array_l_max']:.0f}")
    if prior.get("sizes", {}).get("n64", {}).get("speedup"):
        print(f"  prior n64 speedup: {prior['sizes']['n64']['speedup']}x")
    if json_path:
        Path(json_path).write_text(json.dumps(result, indent=2) + "\n")
        print(f"  wrote {json_path}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    main(args.full,
         json_path=Path(__file__).parent.parent / "BENCH_routing.json"
         if args.json else None)
