"""Fig. 1: analytical MCF of directed 4-radix topologies vs TONS synthesis."""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit, timed


def main(full: bool = False) -> None:
    from repro.core import smallgraphs as SG
    sizes = [10, 15, 20] if not full else [10, 15, 20, 25, 30, 40]
    r = 4
    kautz_sizes = SG.kautz_sizes(r, max(sizes))
    print("# n, kautz, genkautz, xpander, jellyfish(best of 20), tons")
    for n in sizes:
        row = {"kautz": None}
        if n in kautz_sizes:
            row["kautz"] = n * SG.directed_mcf(SG.kautz(r, kautz_sizes[n]),
                                               n)
        row["genkautz"] = n * SG.directed_mcf(SG.gen_kautz(n, r), n)
        xp = SG.xpander(n, r)
        row["xpander"] = n * SG.directed_mcf(xp, n) if xp is not None \
            else None
        best_jf = 0.0
        for s in range(20):
            jf = SG.jellyfish(n, r, seed=s)
            if jf is not None:
                best_jf = max(best_jf, SG.directed_mcf(jf, n))
        row["jellyfish"] = n * best_jf
        (edges, _), us = timed(SG.synthesize_directed, n, r,
                               interval=1 if n <= 20 else max(2, n // 10),
                               restarts=3 if n <= 25 else 2)
        row["tons"] = n * SG.directed_mcf(edges, n)
        fmt = {k: (f"{v:.4f}" if v else "-") for k, v in row.items()}
        print(f"  n={n:3d} " + " ".join(f"{k}={v}" for k, v in fmt.items()))
        best_base = max(v for k, v in row.items()
                        if k != "tons" and v is not None)
        emit(f"fig1_n{n}", us, f"tons/best_baseline="
             f"{row['tons'] / best_base:.4f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(ap.parse_args().full)
