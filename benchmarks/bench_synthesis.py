"""Synthesis-at-scale tracking: batched LP topology synthesis, evaluated
end-to-end through the production routing stack.

For each pod size, measures:

- wall-clock of ``synthesize`` (vectorised LP build + batched greedy
  fixing with warm-started solves), the LP-relaxation lambda trajectory,
  and the final lambda against the Basu et al. theoretical upper bound
  (``mcf_upper_bound_basu``);
- the exact integral MCF of the synthesized topology (HiGHS metric LP)
  where affordable, vs the PT torus baseline -- the paper's Fig. 2/3
  story;
- the synthesized fabric routed end-to-end (``Channels.from_topology``
  -> ``allowed_turns`` -> ``select_paths(engine="sharded")`` -> VC alloc
  -> deadlock-free verify): routed ``l_max`` and netsim saturation
  throughput vs the same pipeline on the best-torus baseline.

Quick mode covers the 128-chip 4x4x8 pod; ``--full`` adds 4x8x8 (256)
and the 8^3 512-chip pod -- the scale the seed synthesis never reached.
Synthesized topologies are cached to ``benchmarks/results/tons_<n>.pkl``
so fig2/fig3/fig9 and the examples pick them up.

``--json`` writes BENCH_synthesis.json; prior results are loaded
tolerantly (guards skip with a warning on a fresh checkout) and
regression guards warn -- and trip ``run.py --check`` -- when synthesis
wall-clock exceeds 2x the stored baseline or the final LP lambda drops
below 1/1.1 of it.
"""
from __future__ import annotations

import argparse
import json
import pickle
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent))

from benchmarks.common import (RESULTS, emit, guard_regression,
                               load_bench_json)

SPECS = [("n128", (4, 4, 8))]
FULL_SPECS = [("n256", (4, 8, 8)), ("n512", (8, 8, 8))]
MCF_CAP = 256           # largest pod whose exact integral MCF we solve
SAT_CAP = 256           # largest pod simulated to saturation
SYNTH_REGRESSION = 2.0  # single-shot wall-clock guard (synthesis is too
                        # expensive to repeat 3x; use a loose bound)
LAMBDA_REGRESSION = 1.1  # quality guard on the final LP lambda


def _exact_mcf(topo, n_completed: int) -> float:
    """Integral MCF; the cube-translation reduction is only sound when
    the matching completion added no symmetry-breaking edges."""
    from repro.core import topology as T
    from repro.core.mcf import mcf_uniform
    perms = T.cube_translations(topo.pod) if n_completed == 0 else None
    lam, _ = mcf_uniform(topo.edges(), topo.n, perms=perms, prefer="highs")
    return float(lam)


def main(full: bool = False, json_path=None) -> dict:
    from repro.core import synthesis as SY, topology as T
    from repro.core.mcf import mcf_upper_bound_basu

    prior = load_bench_json(json_path) if json_path else {}
    result: dict = {"K": 4, "select_engine": "sharded", "sizes": {}}
    sat_kwargs = dict(step=0.02, cycles=2500, warmup=800)

    for name, spec in SPECS + (FULL_SPECS if full else []):
        n = spec[0] * spec[1] * spec[2]
        t0 = time.time()
        res = SY.synthesize(spec, symmetric=True)
        t_synth = time.time() - t0
        topo = res.to_topology()
        basu = mcf_upper_bound_basu(n)
        # None (JSON null) when every LP solve failed -- NaN would both
        # corrupt the JSON and sail through the quality guard
        lp_lambda = round(res.lp_lambda, 6) if res.lambdas else None
        row = {
            "pod": list(spec),
            "synth_s": round(t_synth, 3),
            "status": res.status,
            "lp_lambda": lp_lambda,
            "lp_rounds": len(res.lambdas),
            "interval": res.stats["interval"],
            "lp_n_var": res.stats["n_var"],
            "lp_build_s": res.stats["build_s"],
            "n_orbits": res.n_orbits,
            "n_fixed": res.n_fixed,
            "n_completed": res.n_completed,
            "basu_bound": round(basu, 6),
            "lambda_vs_basu": round(res.lp_lambda / basu, 4)
            if lp_lambda is not None else None,
        }
        print(f"  {name}: synth={t_synth:.1f}s lambda={res.lp_lambda:.5f} "
              f"({(row['lambda_vs_basu'] or float('nan')):.2f}x of Basu "
              f"bound "
              f"{basu:.5f}) fixed={res.n_fixed}/{res.n_orbits} orbits "
              f"+{res.n_completed} completion edges "
              f"({row['lp_rounds']} solves, interval={row['interval']})")

        mcf = None
        if n <= MCF_CAP:
            t0 = time.time()
            mcf = _exact_mcf(topo, res.n_completed)
            row["mcf"] = round(mcf, 6)
            row["mcf_s"] = round(time.time() - t0, 3)
            row["mcf_vs_basu"] = round(mcf / basu, 4)
            print(f"  {name}: integral mcf={mcf:.5f} "
                  f"({row['mcf_vs_basu']:.2f}x of Basu bound)")

        # ---- end-to-end: synthesized vs best-torus through the stack ----
        sat = (n <= SAT_CAP) if full else (n <= 128)
        ee = SY.evaluate_end_to_end(topo, K=4, select_engine="sharded",
                                    saturation=sat, sat_kwargs=sat_kwargs)
        row["synth_routed"] = ee
        pt_topo = T.pt(spec)
        pt = SY.evaluate_end_to_end(pt_topo, K=4, select_engine="sharded",
                                    saturation=sat, sat_kwargs=sat_kwargs)
        row["pt_routed"] = pt
        row["l_max_vs_pt"] = round(ee["l_max"] / max(pt["l_max"], 1e-9), 4)
        assert ee["deadlock_free"] and ee["unreachable"] == 0, \
            "synthesized pod must route deadlock-free"
        print(f"  {name}: routed l_max={ee['l_max']:.0f} vs "
              f"PT {pt['l_max']:.0f} ({row['l_max_vs_pt']:.2f}x, lower is "
              f"better) avg_hops {ee['avg_hops']:.2f}/{pt['avg_hops']:.2f} "
              f"e2e={ee['end_to_end_s']:.1f}s deadlock_free="
              f"{ee['deadlock_free']}")
        if sat and "saturation" in ee:
            ratio = ee["saturation"] / max(pt["saturation"], 1e-9)
            row["saturation_vs_pt"] = round(ratio, 3)
            print(f"  {name}: saturation {ee['saturation']:.4f} vs PT "
                  f"{pt['saturation']:.4f} ({ratio:.2f}x)")

        # cache for fig2/fig3/fig9 + the examples ("mcf" falls back to
        # the LP relaxation when the exact metric LP wasn't affordable)
        d, h = T.diameter_avg_hops(topo)
        pkl = RESULTS / f"tons_{n}.pkl"
        pickle.dump({"optical": [list(e) for e in topo.optical],
                     "lambdas": res.lambdas, "times": res.times,
                     "mcf": mcf if mcf is not None else res.lp_lambda,
                     "mcf_exact": mcf is not None,
                     "diam": d, "hops": h},
                    open(pkl, "wb"))
        row["diam"], row["hops"] = d, round(h, 4)
        print(f"  {name}: cached {pkl.name} (diam={d} hops={h:.3f})")

        if json_path:
            prior_row = prior.get("sizes", {}).get(name, {})
            guard_regression(f"synthesis_{name}_synth_s", t_synth,
                             prior_row.get("synth_s"), SYNTH_REGRESSION)
            # lp_lambda is None when synthesis failed -> trips the
            # missing-metric branch of the guard
            guard_regression(f"synthesis_{name}_lambda", lp_lambda,
                             prior_row.get("lp_lambda"), LAMBDA_REGRESSION,
                             larger_is_worse=False)
        result["sizes"][name] = row

    r128 = result["sizes"]["n128"]
    emit("bench_synthesis_n128", r128["synth_s"] * 1e6,
         f"lambda={r128['lp_lambda']}")
    if "mcf" in r128:
        emit("bench_synthesis_n128_mcf", 0, f"{r128['mcf']:.5f}")
    if json_path:
        for keep in ("n256", "n512"):       # keep the --full records around
            prior_full = prior.get("sizes", {}).get(keep)
            if not full and prior_full and keep not in result["sizes"]:
                result["sizes"][keep] = prior_full
        Path(json_path).write_text(json.dumps(result, indent=2) + "\n")
        print(f"  wrote {json_path}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    main(args.full,
         json_path=Path(__file__).parent.parent / "BENCH_synthesis.json"
         if args.json else None)
