"""Fig. 7: cumulative all-to-all network throughput (TB/s), PT vs TONS.

Sustained aggregate throughput = simulated saturation rate x nodes x
flit-bytes x clock (Table 2: 128 B flits @ 1.05 GHz ~ one flit per link
per cycle = 128 GB/s links)."""
from __future__ import annotations

import argparse

from benchmarks.common import emit, load_tons, timed

FLIT_B = 128
CLOCK = 1.05e9


def agg_tbps(sat_per_node: float, n: int) -> float:
    return sat_per_node * n * FLIT_B * CLOCK / 1e12


def main(full: bool = False) -> None:
    from benchmarks.fig5_saturation import saturation
    from repro.core import topology as T
    from repro.core.traffic import TrafficPattern

    step = 0.04 if not full else 0.02
    pt = T.pt((4, 4, 8))
    # all-to-all == uniform demand over every ordered pair
    a2a = TrafficPattern.uniform(pt.n)
    sat_pt, us = timed(saturation, pt, "dor", step, 2500, 1000, 0, a2a)
    rows = [("PT+DOR", sat_pt)]
    loaded = load_tons(128)
    if loaded:
        sat_t, _ = timed(saturation, loaded[0], "at", step, 2500, 1000, 0,
                         a2a)
        rows.append(("TONS+AT", sat_t))
    print("# sustained a2a throughput at saturation (128 nodes)")
    for name, sat in rows:
        print(f"  {name:8s}: {agg_tbps(sat, 128):.2f} TB/s")
    if len(rows) == 2:
        gain = agg_tbps(rows[1][1], 128) - agg_tbps(rows[0][1], 128)
        print(f"  TONS gain: +{gain:.2f} TB/s "
              f"(paper: +9 TB/s at 256 nodes)")
        emit("fig7_gain_tbps", us, f"{gain:.2f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(ap.parse_args().full)
