"""Fig. 3 / Appendix C: MCF, diameter, avg hops for PT / PDTT / TONS.

Checked against the paper's Appendix C (values in comments)."""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit, load_tons, timed

PAPER = {  # size -> {name: (mcf, diam, hops)}
    128: {"PT(4,4,8)": (0.00781, 8, 4.032), "PDTT": (0.01364, 6, 3.465),
          "TONS LP SYM": (0.01403, 6, 3.368)},
    192: {"PT(4,4,12)": (0.00347, 10, 5.026),
          "TONS LP SYM": (0.00883, 6, 3.560)},
    256: {"PT(4,8,8)": (0.00391, 10, 5.020), "PT(4,4,16)": (0.00195, 12,
                                                            6.024),
          "PDTT": (0.00544, 6, 4.329), "TONS LP SYM": (0.00636, 6, 3.739)},
}


def _twisted_perms(pod, la, shifts):
    import numpy as np
    from repro.core import topology as T
    X, Y, Z = pod.dims
    coords = pod.all_coords()
    sa = [a for a in range(3) if a != la]
    perms = set()
    for tx in range(X):
        for ty in range(Y):
            for tz in range(Z):
                c = coords + np.array([tx, ty, tz])
                c = T._pdtt_reduce(c, pod.dims, la, sa, shifts)
                perms.add(tuple(c[:, 0] + X * (c[:, 1] + Y * c[:, 2])))
    return np.array(sorted(perms), dtype=np.int32)


def evaluate(topo, perms):
    from repro.core.mcf import mcf_uniform
    from repro.core.topology import diameter_avg_hops
    lam, _ = mcf_uniform(topo.edges(), topo.n, perms=perms, prefer="highs")
    d, h = diameter_avg_hops(topo)
    return lam, d, h


def main(full: bool = False) -> None:
    from repro.core import topology as T
    from repro.core.mcf import mcf_upper_bound_basu
    rows = []
    for size, specs in [(128, [(4, 4, 8)]), (192, [(4, 4, 12)]),
                        (256, [(4, 8, 8), (4, 4, 16)])]:
        for spec in specs:
            topo = T.pt(spec)
            (vals, us) = timed(evaluate, topo,
                               T.torus_translations(topo.pod))
            lam, d, h = vals
            print(f"  PT {spec}: mcf={lam:.5f} diam={d} hops={h:.3f}")
            rows.append((f"PT{spec}", size, lam))
            emit(f"fig3_pt_{size}_{spec[0]}x{spec[1]}x{spec[2]}", us,
                 f"mcf={lam:.5f}")
        # best PDTT (twisted-lattice variants: long axis x wrap shifts)
        best = None
        spec = specs[0]
        dims = spec
        for la in range(3):
            half = dims[la] // 2
            for shifts in {(half, half), (half, 0), (0, half),
                           (half // 2 or 1, half), (2, 2)}:
                try:
                    pod = T.Pod(spec)
                    topo = T.Topology(
                        pod, T.twisted_torus_optical(pod, la, shifts),
                        name=f"PDTT{spec}")
                    # twisted lattices stay vertex-transitive
                    perms = _twisted_perms(pod, la, shifts)
                    lam, _, _ = evaluate(topo, perms)
                    if best is None or lam > best[0]:
                        best = (lam, la, shifts)
                except Exception:
                    pass
        if best:
            print(f"  PDTT {spec} best axis={best[1]} shifts={best[2]}: "
                  f"mcf={best[0]:.5f}")
            emit(f"fig3_pdtt_{size}", 0, f"mcf={best[0]:.5f}")
        loaded = load_tons(size)
        if loaded:
            topo, d_ = loaded
            print(f"  TONS_SYM {size}: mcf={d_['mcf']:.5f} "
                  f"diam={d_['diam']} hops={d_['hops']:.3f} "
                  f"(paper {PAPER[size]['TONS LP SYM']})")
            emit(f"fig3_tons_{size}", 0, f"mcf={d_['mcf']:.5f}")
        ub = mcf_upper_bound_basu(size)
        print(f"  Basu bound n={size}: per-source {size * ub:.4f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(ap.parse_args().full)
