"""Fig. 8: saturation under all 48 single-OCS faults (robust AT routing).

Quick mode scores every fault analytically (1/L_max of the re-routed
tables) and simulates a few representative faults; --full simulates all.
Each fault is recovered **both ways** -- full re-selection against the
masked AT (the paper's fault-specific tables) and the incremental
:func:`repro.core.repair.repair_fault` from a live serving state -- and
the wall clocks are reported side by side. Each simulated fault then
runs both recovered tables twice: uniform traffic, and the adversarial
fault-correlated pattern (recovery demand concentrated on the nodes that
just lost links, boosted injection inside the region), so the repaired
fabric's post-recovery saturation sits next to the recomputed one."""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit, load_tons, timed


def main(full: bool = False) -> None:
    from repro.core import collectives as C, fault as F, netsim as NS, \
        topology as T
    from repro.core.pipeline import PipelineConfig, route_pod
    from repro.core.repair import ServingState, repair_fault
    from repro.core.routing import RoutingResult
    from repro.core.traffic import TrafficPattern

    cases = [("PDTT", T.pdtt((4, 4, 8)))]
    loaded = load_tons(128)
    if loaded:
        cases.append(("TONS", loaded[0]))

    import time

    for name, topo in cases:
        cfg = PipelineConfig(n_vc=4, robust=True, K=4,
                             local_search_rounds=2, vc="none")
        rp = route_pod(topo, cfg)
        at, base = rp.at, rp.routed
        # the live fabric the incremental repairs recover from
        st = ServingState.build(topo, n_vc=4, K=4, seed=0, robust=True)
        colors = F.colors_in_use(topo)
        lmaxes, rep_lmaxes = [], []
        disconnected = 0
        sims = {}
        sim_colors = colors[:: max(1, len(colors) // 4)] if not full \
            else colors
        t_route = 0.0
        t_repair = 0.0
        flows_rerouted = 0
        sstats: dict = {}

        def saturate(tables, rres, dead_region):
            traffic = C.a2a_traffic(rres)
            sat, _ = NS.saturation_point(tables, step=0.05, cycles=2000,
                                         warmup=800, traffic=traffic,
                                         stats=sstats)
            fc = TrafficPattern.fault_correlated(topo.n, dead_region,
                                                 frac=0.5)
            sat_fc, _ = NS.saturation_point(tables, step=0.05, cycles=2000,
                                            warmup=800, traffic=fc,
                                            stats=sstats)
            return sat, sat_fc

        for color in colors:
            dead = F.dead_channels_for_color(at, color)
            t0 = time.time()
            routed = route_pod(
                topo, PipelineConfig(K=4, local_search_rounds=1,
                                     vc="none"),
                at=at, dead_channels=dead).routed
            t_route += time.time() - t0
            t0 = time.time()
            rr = repair_fault(st, dead)
            t_repair += time.time() - t0
            flows_rerouted += rr.flows_rerouted
            if routed.unreachable:
                disconnected += 1
                continue
            lmaxes.append(routed.l_max)
            rep_lmaxes.append(rr.l_max)
            if color in sim_colors:
                region = F.fault_region_nodes(at, color)
                tab = NS.at_tables(topo, at, routed)
                rst = rr.state
                rrouted = RoutingResult(
                    rst.table, rst.loads[:-1].astype(np.float64),
                    float(rr.l_max), rst.table.avg_hops(),
                    rr.unreachable)
                rtab = NS.at_tables(topo, rst.at, rrouted, balance=None)
                sims[color] = (saturate(tab, routed, region),
                               saturate(rtab, rrouted, region))
        lmaxes = np.array(lmaxes)
        rep_lmaxes = np.array(rep_lmaxes)
        print(f"  {name}: faults={len(colors)} disconnected={disconnected}"
              f" analytic 1/Lmax: no-fault={1 / base.l_max:.5f} "
              f"min={1 / lmaxes.max():.5f} med={1 / np.median(lmaxes):.5f}"
              f" ({t_route:.1f}s to re-route all faults, array engine)")
        print(f"        incremental repair: {t_repair:.1f}s for all "
              f"faults ({t_route / max(t_repair, 1e-9):.0f}x faster, "
              f"{flows_rerouted} flows re-routed total) "
              f"repaired 1/Lmax: min={1 / rep_lmaxes.max():.5f} "
              f"med={1 / np.median(rep_lmaxes):.5f} "
              f"worst ratio={float((rep_lmaxes / lmaxes).max()):.3f}x")
        if sims:
            print(f"        simulated saturations "
                  f"(recomputed | repaired, uniform/fault-correlated): "
                  + " ".join(
                      f"c{c}={u:.3f}/{fcv:.3f}|{ru:.3f}/{rfc:.3f}"
                      for c, ((u, fcv), (ru, rfc)) in sims.items()))
            print(f"        sim kernel={sstats.get('kernel')} peak array "
                  f"bytes {sstats.get('array_bytes', 0):,}")
        # mid-sweep fault: the OCS dies at cycle t *while packets are in
        # flight* -- no chance to preload fault-specific tables. Static
        # tables strand every packet whose frozen path died; the
        # adaptive escape-VC kernel re-resolves them onto surviving
        # alternates or the re-rooted escape tree, conserving both ways.
        color0 = sim_colors[0]
        ev = F.fault_event(at, color0, 800)
        atab = NS.at_tables(topo, at, base, reserve_escape=True)
        aspec = NS.adaptive_spec(topo, dead_channels=ev[1])
        wstats: dict = {}
        stt = NS.sweep(atab, [0.1], cycles=2000, warmup=800,
                       fault=ev, stats=wstats)[0]
        st_cycles = wstats.get("cycles_run")
        adt = NS.sweep(atab, [0.1], cycles=2000, warmup=800, fault=ev,
                       adaptive=aspec, stats=wstats)[0]
        print(f"        mid-sweep fault c{color0}@800: stranded "
              f"in-flight static={stt['in_flight']} "
              f"adaptive={adt['in_flight']} "
              f"(escaped={adt['escaped']}, watchdog "
              f"{'quiet' if adt['stalled_at'] < 0 else 'FIRED'})")
        # watchdog outputs, surfaced: the cycle each lane's livelock
        # watchdog fired (-1 = never) and the cycles the kernels ran
        # (static strands packets but must not wedge the whole lane)
        print(f"        watchdog: static stalled_at={stt['stalled_at']} "
              f"cycles_run={st_cycles} | adaptive "
              f"stalled_at={adt['stalled_at']} "
              f"cycles_run={wstats.get('cycles_run')}")
        emit(f"fig8_{name.lower()}_midsweep", 0,
             f"static_stranded={stt['in_flight']} "
             f"adaptive_stranded={adt['in_flight']}")
        emit(f"fig8_{name.lower()}_watchdog", 0,
             f"static_stalled_at={stt['stalled_at']} "
             f"adaptive_stalled_at={adt['stalled_at']} "
             f"cycles_run={wstats.get('cycles_run')}")
        emit(f"fig8_{name.lower()}", 0,
             f"worst_fault_frac={base.l_max / lmaxes.max():.3f}")
        emit(f"fig8_{name.lower()}_repair", t_repair * 1e6,
             f"speedup={t_route / max(t_repair, 1e-9):.1f}x "
             f"worst_ratio={float((rep_lmaxes / lmaxes).max()):.3f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(ap.parse_args().full)
