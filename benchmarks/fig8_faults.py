"""Fig. 8: saturation under all 48 single-OCS faults (robust AT routing).

Quick mode scores every fault analytically (1/L_max of the re-routed
tables) and simulates a few representative faults; --full simulates all.
Each simulated fault runs twice: uniform traffic, and the adversarial
fault-correlated pattern (recovery demand concentrated on the nodes that
just lost links, boosted injection inside the region)."""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit, load_tons, timed


def main(full: bool = False) -> None:
    from repro.core import collectives as C, fault as F, netsim as NS, \
        routing as R, topology as T

    cases = [("PDTT", T.pdtt((4, 4, 8)))]
    loaded = load_tons(128)
    if loaded:
        cases.append(("TONS", loaded[0]))

    import time

    for name, topo in cases:
        at = R.allowed_turns(topo, n_vc=4, priority="apl", robust=True)
        base = R.select_paths(at, K=4, local_search_rounds=2)
        colors = F.colors_in_use(topo)
        lmaxes = []
        disconnected = 0
        sims = {}
        sim_colors = colors[:: max(1, len(colors) // 4)] if not full \
            else colors
        t_route = 0.0
        sstats: dict = {}
        for color in colors:
            dead = F.dead_channels_for_color(at, color)
            t0 = time.time()
            routed = R.select_paths(at, K=4, local_search_rounds=1,
                                    dead_channels=dead)
            t_route += time.time() - t0
            if routed.unreachable:
                disconnected += 1
                continue
            lmaxes.append(routed.l_max)
            if color in sim_colors:
                tab = NS.at_tables(topo, at, routed)
                # all-to-all over the surviving reachable pairs
                traffic = C.a2a_traffic(routed)
                sat, _ = NS.saturation_point(tab, step=0.05, cycles=2000,
                                             warmup=800, traffic=traffic,
                                             stats=sstats)
                # recovery traffic clustered on the impaired region
                from repro.core.traffic import TrafficPattern
                fc = TrafficPattern.fault_correlated(
                    topo.n, F.fault_region_nodes(at, color), frac=0.5)
                sat_fc, _ = NS.saturation_point(tab, step=0.05,
                                                cycles=2000, warmup=800,
                                                traffic=fc, stats=sstats)
                sims[color] = (sat, sat_fc)
        lmaxes = np.array(lmaxes)
        print(f"  {name}: faults={len(colors)} disconnected={disconnected}"
              f" analytic 1/Lmax: no-fault={1 / base.l_max:.5f} "
              f"min={1 / lmaxes.max():.5f} med={1 / np.median(lmaxes):.5f}"
              f" ({t_route:.1f}s to re-route all faults, array engine)")
        if sims:
            print(f"        simulated saturations (subset, "
                  f"uniform/fault-correlated): "
                  + " ".join(f"c{c}={u:.3f}/{fcv:.3f}"
                             for c, (u, fcv) in sims.items()))
            print(f"        sim kernel={sstats.get('kernel')} peak array "
                  f"bytes {sstats.get('array_bytes', 0):,}")
        emit(f"fig8_{name.lower()}", 0,
             f"worst_fault_frac={base.l_max / lmaxes.max():.3f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(ap.parse_args().full)
