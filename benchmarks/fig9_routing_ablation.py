"""Figs. 9-11: AT turn prioritization, VC load balance, DOR VC skew."""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit, load_tons, timed


def main(full: bool = False) -> None:
    from repro.core import netsim as NS, routing as R, topology as T
    from repro.core.pipeline import PipelineConfig, route_pod
    from repro.core.vcalloc import allocate_vcs

    # --full ablates on a 512-chip 8^3 pod (synthesized TONS if cached,
    # else PDTT) -- feasible since the array routing engine; quick mode
    # keeps the 128-chip pod. The pod scale depends only on --full, not
    # on which TONS caches happen to exist.
    loaded = load_tons(512) if full else load_tons(128)
    topo = loaded[0] if loaded else \
        T.pdtt((8, 8, 8) if full else (4, 4, 8))
    lb_hops = None
    from repro.core.topology import bfs_all_pairs
    d = bfs_all_pairs(topo)
    lb_hops = d[np.isfinite(d)].sum() / (topo.n * (topo.n - 1))
    lb_load = R.load_lower_bound(topo)

    # Fig. 9: prioritization heuristics. The facade's per-stage timings
    # separate the admission front-end cost from the path-selection cost.
    results = {}
    for mode in ("apl", "random"):
        rp = route_pod(topo, PipelineConfig(
            priority=mode, K=4, engine="array",
            local_search_rounds=3, vc="none"))
        routed = rp.routed
        t_at, t_sel = rp.timings["at_s"], rp.timings["select_s"]
        results[mode] = (routed, rp.at)
        print(f"  {mode:6s}: Lmax/LB={routed.l_max / lb_load:.3f} "
              f"hops/min={routed.avg_hops / lb_hops:.3f} "
              f"AT={t_at:.2f}s select={t_sel:.2f}s")
        emit(f"fig9_at_time_{mode}", t_at * 1e6,
             f"{routed.l_max / lb_load:.3f}")
    # CPL: re-prioritize by the APL routing's chosen turn frequencies
    freq = R.turn_frequencies(results["apl"][0].table)
    rp = route_pod(topo, PipelineConfig(
        K=4, engine="array", local_search_rounds=3, vc="none"),
        chosen_loads=freq)
    routed_cpl = rp.routed
    t_at, t_sel = rp.timings["at_s"], rp.timings["select_s"]
    print(f"  cpl   : Lmax/LB={routed_cpl.l_max / lb_load:.3f} "
          f"hops/min={routed_cpl.avg_hops / lb_hops:.3f} "
          f"AT={t_at:.2f}s select={t_sel:.2f}s")
    emit("fig9_at_time_cpl", t_at * 1e6,
         f"{routed_cpl.l_max / lb_load:.3f}")
    emit("fig9_cpl_lmax_over_lb", 0,
         f"{routed_cpl.l_max / lb_load:.3f}")

    # Fig. 10: VC balance on TONS/AT
    at, routed = results["apl"][1], results["apl"][0]
    bal = allocate_vcs(at, routed.table.copy(), balance=True)
    unbal = allocate_vcs(at, routed.table.copy(), balance=False)
    print(f"  VC hops balanced={bal.tolist()} unbalanced={unbal.tolist()}")
    emit("fig10_vc_balance", 0,
         f"max/min={bal.max() / max(bal.min(), 1):.3f}")

    # Fig. 11: DOR skew on the torus baseline
    pt = T.pt((4, 4, 8))
    counts = NS.dor_paths(pt).vc_hop_counts()
    at_counts = route_pod(pt, PipelineConfig(
        K=4, engine="array", local_search_rounds=2,
        vc="inplace")).vc_counts
    print(f"  DOR hops/VC={counts.tolist()}  AT hops/VC="
          f"{at_counts.tolist()}")
    emit("fig11_dor_vc0_share", 0,
         f"{counts[0] / counts.sum():.3f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(ap.parse_args().full)
