"""Fig. 5: uniform-random saturation points, normalized to best PT+DOR.

The injection-rate sweep runs as batched (lane-flattened) device
executions (`netsim.saturation_point`); pass ``traffic=`` for
non-uniform patterns.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit, load_tons, timed


def saturation(topo, mode: str, step=0.02, cycles=3000, warmup=1000,
               seed=0, traffic=None, stats=None):
    from repro.core import netsim as NS
    from repro.core.pipeline import PipelineConfig, route_pod
    if mode == "dor":
        tab = NS.dor_tables(topo)          # 2 escape VCs (datelines)
    else:
        # Table 2: 4 VCs total; AT spreads turns over all of them
        tab = route_pod(topo, PipelineConfig(
            n_vc=4, K=4, seed=seed, engine="array",
            local_search_rounds=3)).tables
    sat, _ = NS.saturation_point(tab, step=step, cycles=cycles,
                                 warmup=warmup, traffic=traffic,
                                 stats=stats)
    return sat


def main(full: bool = False) -> None:
    from repro.core import topology as T
    spec = (4, 4, 8)
    step = 0.04 if not full else 0.01
    cyc = 2500 if not full else 6000

    results = {}
    sstats: dict = {}
    pt = T.pt(spec)
    results["PT+DOR"], us = timed(saturation, pt, "dor", step, cyc,
                                  stats=sstats)
    results["PT+AT"], _ = timed(saturation, pt, "at", step, cyc,
                                stats=sstats)
    pdtt = T.pdtt(spec)
    results["PDTT+AT"], _ = timed(saturation, pdtt, "at", step, cyc,
                                  stats=sstats)
    loaded = load_tons(128)
    if loaded:
        results["TONS+AT"], _ = timed(saturation, loaded[0], "at", step,
                                      cyc, stats=sstats)
    base = results["PT+DOR"]
    print("# saturation, normalized to PT+DOR (paper Fig. 5: TONS ~2x)")
    print(f"#  kernel={sstats.get('kernel')} peak sim array bytes "
          f"{sstats.get('array_bytes', 0):,}")
    for k, v in results.items():
        print(f"  {k:10s}: sat={v:.4f}  norm={v / base:.2f}x")
    if "TONS+AT" in results:
        emit("fig5_tons_over_pt", us,
             f"speedup={results['TONS+AT'] / base:.3f}x")
    emit("fig5_at_over_dor", us,
         f"speedup={results['PT+AT'] / base:.3f}x")

    # adaptive escape-VC lane: the same LP-balanced PDTT tables run
    # static and with occupancy-driven adaptivity, under the stress
    # patterns the static tables were not planned for (hotspot
    # concentration; synchronized mean-preserving injection bursts)
    from repro.core import netsim as NS
    from repro.core.pipeline import PipelineConfig, route_pod
    from repro.core.traffic import TrafficPattern
    tab = route_pod(pdtt, PipelineConfig(
        n_vc=4, priority="robust", K=4, local_search_rounds=1,
        engine="sharded", reserve_escape=True)).tables
    aspec = NS.adaptive_spec(pdtt)
    # hotspot saturation is consumption-limited (~= hot/(frac*n)), far
    # below the uniform grid -- each stress row carries its own grid
    stress = (
        ("hotspot", TrafficPattern.hotspot(pdtt.n, list(range(4)), 0.4),
         0.01, 0.12),
        ("bursty", TrafficPattern.uniform(pdtt.n).with_burst(
            64, duty=0.25, gain=3.0), step, 1.0),
    )
    print(f"# adaptive escape-VC routing vs static ({pdtt.name})")
    for pname, tp, pstep, pmax in stress:
        s, _ = NS.saturation_point(tab, step=pstep, max_rate=pmax,
                                   cycles=cyc, warmup=cyc // 3,
                                   traffic=tp)
        a, _ = NS.saturation_point(tab, step=pstep, max_rate=pmax,
                                   cycles=cyc, warmup=cyc // 3,
                                   traffic=tp, adaptive=aspec)
        print(f"  {pname:8s}: static={s:.4f} adaptive={a:.4f} "
              f"({a / max(s, 1e-9):.2f}x)")
        emit(f"fig5_adaptive_{pname}", 0,
             f"static={s:.4f} adaptive={a:.4f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(ap.parse_args().full)
