"""Fig. 10: availability over a chaos-campaign timeline.

A 128-chip PDTT serving state rides a seeded fault/heal schedule
(storms, correlated link groups with a guaranteed node isolation,
restorations, final heal) and every repair group is followed by a
netsim throughput probe of the degraded fabric (lost pairs compacted
out of the CSR table). The figure is the timeline table: served-pair
fraction and throughput retained vs the healthy baseline at every
event, alongside MTTR, flows re-routed and the post-event l_max --
the degraded-mode serving story end to end. ``--full`` lengthens the
campaign and the probes."""
from __future__ import annotations

import argparse

from benchmarks.common import emit


def main(full: bool = False) -> None:
    from repro.core import chaos as X, topology as T
    from repro.core.repair import ServingState

    topo = T.pdtt((4, 4, 8))
    st = ServingState.build(topo, n_vc=4, K=4, seed=0, robust=True)
    sched = X.generate_schedule(st.at, n_arrivals=16 if full else 10,
                                seed=3)
    res = X.run_campaign(st, sched, coalesce=1.0, probe_every=1,
                         probe_rate=0.05,
                         probe_cycles=2000 if full else 1200,
                         probe_warmup=800 if full else 400)
    assert res.ok, [r.invariants for r in res.records if not r.ok]

    base = (res.baseline_probe or {}).get("delivered", 0.0)
    print(f"  PDTT 128: events={sched.n_events} groups="
          f"{len(res.records)} kinds={sched.kinds()} baseline "
          f"lmax={res.baseline_l_max:.0f} delivered={base:.4f}")
    print("        t      kind     chans coal  mttr_s  flows  lost "
          "served   lmax  tput_ret")
    for r in res.records:
        ret = (r.probe["delivered"] / base
               if r.probe is not None and base else float("nan"))
        print(f"   {r.t:8.1f} {r.kind:>8s} {r.n_channels:5d} "
              f"{r.coalesced:4d} {r.mttr_s:7.3f} {r.flows_rerouted:6d} "
              f"{r.lost_pairs:5d} {r.served_fraction:6.4f} "
              f"{r.l_max:6.0f} {ret:9.4f}")
    final = res.records[-1]
    rets = [r.probe["delivered"] / base for r in res.records
            if r.probe is not None and base]
    print(f"        final: served={final.served_fraction:.4f} "
          f"lost={len(res.state.lost)} post-heal lmax "
          f"{res.state.l_max:.0f}/{res.baseline_l_max:.0f} "
          f"min tput retained={min(rets, default=1.0):.4f}")
    emit("fig10_chaos", 0,
         f"min_served={res.min_served_fraction:.4f} "
         f"min_tput_retained={min(rets, default=1.0):.4f} "
         f"final_served={final.served_fraction:.4f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(ap.parse_args().full)
