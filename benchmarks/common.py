"""Shared helpers for the benchmark suite."""
from __future__ import annotations

import os
import pickle
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

RESULTS = Path(__file__).parent / "results"
RESULTS.mkdir(exist_ok=True)


def load_tons(n: int):
    """Load a synthesized TONS topology from benchmarks/results."""
    from repro.core.topology import Pod, Topology
    p = RESULTS / f"tons_{n}.pkl"
    if not p.exists():
        return None
    d = pickle.load(open(p, "rb"))
    spec = {128: (4, 4, 8), 192: (4, 4, 12), 256: (4, 8, 8),
            384: (4, 8, 12), 512: (8, 8, 8)}[n]
    topo = Topology(Pod(spec), [tuple(e) for e in d["optical"]],
                    name=f"TONS_SYM {n}")
    return topo, d


def load_bench_json(json_path) -> dict:
    """Prior BENCH_*.json contents, or {} when the file is missing or
    corrupt -- benchmark runs must never crash on absent history."""
    import json
    try:
        return json.loads(Path(json_path).read_text())
    except (OSError, ValueError, TypeError):
        return {}


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, (time.time() - t0) * 1e6


def emit(name: str, us: float, derived):
    print(f"{name},{us:.0f},{derived}")
