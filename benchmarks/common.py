"""Shared helpers for the benchmark suite."""
from __future__ import annotations

import os
import pickle
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

RESULTS = Path(__file__).parent / "results"
RESULTS.mkdir(exist_ok=True)


def load_tons(n: int):
    """Load a synthesized TONS topology from benchmarks/results."""
    from repro.core.topology import Pod, Topology
    p = RESULTS / f"tons_{n}.pkl"
    if not p.exists():
        return None
    d = pickle.load(open(p, "rb"))
    spec = {128: (4, 4, 8), 192: (4, 4, 12), 256: (4, 8, 8),
            384: (4, 8, 12), 512: (8, 8, 8)}[n]
    topo = Topology(Pod(spec), [tuple(e) for e in d["optical"]],
                    name=f"TONS_SYM {n}")
    return topo, d


def load_bench_json(json_path) -> dict:
    """Prior BENCH_*.json contents, or {} when the file is missing or
    corrupt -- benchmark runs must never crash on absent history."""
    import json
    try:
        return json.loads(Path(json_path).read_text())
    except (OSError, ValueError, TypeError):
        return {}


# Regression guards tripped during this process; ``run.py --check`` exits
# non-zero when this is non-empty after the suites finish.
REGRESSIONS: list = []


def guard_regression(name: str, now, baseline, bound: float = 1.5,
                     larger_is_worse: bool = True) -> bool:
    """Shared perf/quality regression guard.

    Missing baselines (fresh checkout, CI fork) skip with a warning
    instead of crashing or tripping; a tripped guard prints a WARNING,
    emits a CSV line and is recorded in :data:`REGRESSIONS` for
    ``run.py --check``. Returns True when tripped.

    ``BENCH_GUARD_SCALE`` (env) multiplies every bound -- committed
    baselines are recorded on the dev container, so CI on different
    hardware sets it (e.g. 2.0) to absorb the host delta while still
    catching step-function regressions.
    """
    bound = bound * float(os.environ.get("BENCH_GUARD_SCALE", "1.0"))
    if now is None:
        # the *current* run failed to produce the guarded metric -- that
        # is itself a regression, not a skippable fresh checkout
        print(f"  WARNING: {name} missing from the current run")
        REGRESSIONS.append({"name": name, "now": None,
                            "baseline": baseline, "bound": bound})
        return True
    if baseline in (None, 0, 0.0):
        print(f"  guard[{name}]: no stored baseline -- skipped "
              f"(fresh checkout?)")
        return False
    tripped = now > bound * baseline if larger_is_worse \
        else now < baseline / bound
    if tripped:
        rel = "regressed" if larger_is_worse else "dropped"
        print(f"  WARNING: {name} {rel} to {now:.4g} vs baseline "
              f"{baseline:.4g} (> {bound}x guard)")
        emit(f"guard_{name}", float(now) * 1e6, f"baseline={baseline}")
        REGRESSIONS.append({"name": name, "now": float(now),
                            "baseline": float(baseline), "bound": bound})
    return tripped


def peak_rss_mb() -> float:
    """Peak resident set size of this process in MB.

    ``ru_maxrss`` is kilobytes on Linux and bytes on macOS; benchmark
    JSON records this next to the staged-array byte counts so the sim
    memory win is visible end to end (allocator slack included).
    """
    import resource
    r = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    return round(r / (1024 * 1024) if sys.platform == "darwin"
                 else r / 1024, 1)


def median_timed(fn, repeats: int = 3):
    """Run ``fn`` ``repeats`` times; return (first result, median seconds).

    Guarded timings use the median of 3 -- container timing is noisy
    enough that single-shot 1.5x guards false-positive.
    """
    import statistics
    ts, out = [], None
    for i in range(repeats):
        t0 = time.time()
        r = fn()
        ts.append(time.time() - t0)
        if i == 0:
            out = r
    return out, float(statistics.median(ts))


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, (time.time() - t0) * 1e6


def emit(name: str, us: float, derived):
    print(f"{name},{us:.0f},{derived}")
