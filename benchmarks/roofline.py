"""Roofline table from the dry-run artifacts (one row per cell) +
TONS-adjusted collective terms for the MoE (all-to-all-bound) cells."""
from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path

from benchmarks.common import RESULTS, emit, load_tons

DRYRUN = RESULTS / "dryrun"


def rows(mesh="single_pod_16x16"):
    out = []
    for f in sorted(glob.glob(str(DRYRUN / f"*__{mesh}.json"))):
        d = json.load(open(f))
        if "error" not in d:
            out.append(d)
    return out


def tons_collective_speedup() -> float:
    """Paper-derived fabric gain for a2a-dominant traffic: the ratio of
    TONS vs best-torus MCF at the matching pod size (128 here; the paper
    reports 1.6-2.1x at larger scales)."""
    loaded = load_tons(128)
    if not loaded:
        return 1.65
    return loaded[1]["mcf"] / 0.01364  # vs best PDTT


def main(full: bool = False) -> None:
    rs = rows()
    if not rs:
        print("no dry-run artifacts; run repro.launch.dryrun first")
        return
    print("# arch, shape, dominant, t_compute, t_memory, t_collective, "
          "useful_flop_ratio, fits_v5p")
    worst = None
    most_coll = None
    for d in rs:
        t = d["terms"]
        frac = d.get("useful_flop_ratio", 0)
        key = f"{d['arch']}|{d['shape']}"
        print(f"  {d['arch']:22s} {d['shape']:12s} {t['dominant']:13s} "
              f"{t['t_compute']:9.4f} {t['t_memory']:9.4f} "
              f"{t['t_collective']:9.4f} useful={frac:5.2f} "
              f"fits95={d.get('memory', {}).get('fits_v5p_95g')}")
        rf = t["t_compute"] / max(t["t_compute"], t["t_memory"],
                                  t["t_collective"], 1e-12)
        if d["kind"] != "decode":  # decode is trivially memory-bound
            if worst is None or rf < worst[1]:
                worst = (key, rf)
            cr = t["t_collective"] / max(t["t_compute"], 1e-12)
            if most_coll is None or cr > most_coll[1]:
                most_coll = (key, cr)
    print(f"  worst roofline fraction: {worst[0]} ({worst[1]:.4f})")
    print(f"  most collective-bound:   {most_coll[0]} "
          f"(t_coll/t_comp={most_coll[1]:.2f})")
    su = tons_collective_speedup()
    print(f"  TONS fabric a2a speedup applied to collective terms: "
          f"{su:.2f}x (paper technique -> framework integration)")
    for d in rs:
        if "moe" in d["arch"] or d["arch"].startswith("jamba"):
            t = d["terms"]
            base = t["t_collective"]
            print(f"    {d['arch']:22s} {d['shape']:12s} "
                  f"t_coll {base:.3f}s -> {base / su:.3f}s on TONS fabric")
    emit("roofline_cells", 0, f"{len(rs)}")
    emit("roofline_worst", 0, f"{worst[0]}:{worst[1]:.4f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(ap.parse_args().full)
