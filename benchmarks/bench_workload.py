"""Workload co-design tracking: demand-specialized synthesis vs the
demand-blind fabrics (the PR-10 headline).

For each registered workload (an a2a-heavy MoE arch and a ring-heavy
dense arch, both on the ``train_4k`` shape), measures at 128 chips
(``--full`` adds 256):

- wall-clock of ``synthesize_for_workload`` (the workload's
  translation-invariant demand weights riding into the symmetric
  synthesis LP as ``pair_weight``);
- the demand-weighted MCF and the trace-replay saturation
  (:func:`repro.core.workload.evaluate_workload`, routed through
  ``route_pod``) of the specialized fabric vs the generic
  uniform-demand TONS (``tons_<n>.pkl`` cache, skipped when absent)
  vs the PT torus -- both metrics must favor the specialized fabric;
- a two-tenant lane: the MoE and dense workloads composed onto one
  shared fabric (:func:`repro.core.traffic.compose_tenants`), swept
  through the CSR kernel with exact per-tenant packet conservation
  asserted and per-tenant delivered throughput recorded.

Specialized topologies are cached to
``benchmarks/results/tons_wl_<n>_<arch>.pkl`` so ``fig11_workload``
renders without re-synthesizing.

``--json`` writes BENCH_workload.json; guards warn -- and trip
``run.py --check`` -- when synthesis wall-clock exceeds 2x the stored
baseline, evaluation wall-clock exceeds 1.5x, or the
specialized-over-generic weighted-MCF advantage decays below 1/1.1 of
the stored ratio. All guards skip with a warning on a fresh checkout.
"""
from __future__ import annotations

import argparse
import json
import pickle
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).parent.parent))

from benchmarks.common import (RESULTS, emit, guard_regression,
                               load_bench_json, load_tons)

WORKLOADS = [("deepseek-moe-16b", "train_4k"),    # MoE: a2a-heavy
             ("gemma-7b", "train_4k")]            # dense: ring-heavy
SPECS = [("n128", (4, 4, 8))]
FULL_SPECS = [("n256", (4, 8, 8))]
SYNTH_REGRESSION = 2.0   # single-shot synthesis wall guard (loose)
EVAL_REGRESSION = 1.5    # evaluation (route + LP + sweep) wall guard
QUALITY_REGRESSION = 1.1  # specialized/generic weighted-MCF ratio guard


def _evaluate(topo, wd, trace, sat_kwargs):
    # engine="array": demand-weighted selection (pair_weight) only
    # exists there, and evaluate_workload routes with the workload's
    # integer pair multiplicities by default.
    from repro.core import workload as W
    from repro.core.pipeline import PipelineConfig
    return W.evaluate_workload(
        topo, wd, trace=trace,
        cfg=PipelineConfig(K=4, engine="array", local_search_rounds=1),
        sat_kwargs=sat_kwargs)


def main(full: bool = False, json_path=None) -> dict:
    from repro.core import netsim as NS, topology as T, workload as W
    from repro.core.pipeline import PipelineConfig, route_pod
    from repro.core.traffic import compose_tenants

    prior = load_bench_json(json_path) if json_path else {}
    result: dict = {"K": 4, "select_engine": "array",
                    "weighted_routing": True, "sizes": {}}
    sat_kwargs = dict(step=0.02, cycles=2000, warmup=600)

    for sname, spec in SPECS + (FULL_SPECS if full else []):
        n = spec[0] * spec[1] * spec[2]
        generic = load_tons(n)
        pt_topo = T.pt(spec)
        size_row: dict = {"pod": list(spec), "workloads": {}}

        for arch, shape in WORKLOADS:
            wd = W.workload_demand(spec, arch, shape)
            trace = W.replay_trace(wd)
            t0 = time.time()
            res, _ = W.synthesize_for_workload(spec, arch, shape, wd=wd)
            t_synth = time.time() - t0
            sp_topo = res.to_topology()
            pkl = RESULTS / f"tons_wl_{n}_{arch}.pkl"
            pickle.dump({"optical": [list(e) for e in sp_topo.optical],
                         "arch": arch, "shape": shape,
                         "w_same_cube": wd.w_same_cube,
                         "w_ring": wd.w_ring,
                         "w_uniform": wd.w_uniform},
                        open(pkl, "wb"))

            t0 = time.time()
            ev_sp = _evaluate(sp_topo, wd, trace, sat_kwargs)
            ev_pt = _evaluate(pt_topo, wd, trace, sat_kwargs)
            ev_gn = _evaluate(generic[0], wd, trace, sat_kwargs) \
                if generic else None
            t_eval = time.time() - t0

            row = {
                "demand": {"w_same_cube": round(wd.w_same_cube, 4),
                           "w_ring": round(wd.w_ring, 4),
                           "w_uniform": round(wd.w_uniform, 4)},
                "synth_s": round(t_synth, 3),
                "eval_s": round(t_eval, 3),
                "lp_lambda": round(res.lp_lambda, 6) if res.lambdas
                else None,
                "specialized": ev_sp,
                "pt": ev_pt,
            }
            if ev_gn is not None:
                row["generic"] = ev_gn
                row["mcf_vs_generic"] = round(
                    ev_sp["weighted_mcf"]
                    / max(ev_gn["weighted_mcf"], 1e-12), 4)
                row["sat_vs_generic"] = round(
                    ev_sp["trace_saturation"]
                    / max(ev_gn["trace_saturation"], 1e-12), 4)
            row["mcf_vs_pt"] = round(
                ev_sp["weighted_mcf"]
                / max(ev_pt["weighted_mcf"], 1e-12), 4)
            row["sat_vs_pt"] = round(
                ev_sp["trace_saturation"]
                / max(ev_pt["trace_saturation"], 1e-12), 4)
            size_row["workloads"][arch] = row
            gen_txt = (f" generic={ev_gn['weighted_mcf']:.5f}"
                       f"/{ev_gn['trace_saturation']:.4f}"
                       if ev_gn else " generic=<no cache>")
            print(f"  {sname} {arch}: ws={wd.w_same_cube:.2f} "
                  f"wr={wd.w_ring:.2f} synth={t_synth:.1f}s")
            print(f"  {sname} {arch}: wMCF/sat specialized="
                  f"{ev_sp['weighted_mcf']:.5f}"
                  f"/{ev_sp['trace_saturation']:.4f}{gen_txt} "
                  f"pt={ev_pt['weighted_mcf']:.5f}"
                  f"/{ev_pt['trace_saturation']:.4f}")

            if json_path:
                prior_row = prior.get("sizes", {}).get(sname, {}) \
                    .get("workloads", {}).get(arch, {})
                guard_regression(f"workload_{sname}_{arch}_synth_s",
                                 t_synth, prior_row.get("synth_s"),
                                 SYNTH_REGRESSION)
                guard_regression(f"workload_{sname}_{arch}_eval_s",
                                 t_eval, prior_row.get("eval_s"),
                                 EVAL_REGRESSION)
                guard_regression(f"workload_{sname}_{arch}_mcf_vs_generic",
                                 row.get("mcf_vs_generic"),
                                 prior_row.get("mcf_vs_generic"),
                                 QUALITY_REGRESSION,
                                 larger_is_worse=False)

        # ---- two jobs, one fabric: per-tenant accounting -------------
        moe_arch, dense_arch = WORKLOADS[0][0], WORKLOADS[1][0]
        ta = W.workload_tenant("moe", spec, list(range(0, n // 2)),
                               moe_arch)
        tb = W.workload_tenant("dense", spec, list(range(n // 2, n)),
                               dense_arch, rate_share=0.5)
        tp = compose_tenants(n, [ta, tb])
        shared = generic[0] if generic else pt_topo
        tab = route_pod(shared, PipelineConfig(
            K=4, engine="sharded", local_search_rounds=1)).tables
        r = NS.sweep(tab, [0.1], traffic=tp, cycles=1500, warmup=500)[0]
        tens = r["tenants"]
        for tname, t in tens.items():
            assert t["injected"] == t["consumed"] + t["in_flight"], \
                f"tenant {tname} leaked packets"
        size_row["tenants"] = {
            "fabric": shared.name,
            "rate": 0.1,
            "per_tenant": {k: {kk: (round(vv, 5)
                                    if isinstance(vv, float) else vv)
                               for kk, vv in v.items()}
                           for k, v in tens.items()},
        }
        print(f"  {sname} tenants on {shared.name}: " + " ".join(
            f"{k}: inj={v['injected']} delivered={v['delivered']:.4f}"
            for k, v in tens.items()) + " (conservation exact)")
        result["sizes"][sname] = size_row

    r128 = result["sizes"]["n128"]["workloads"]
    for arch, _ in WORKLOADS:
        row = r128[arch]
        emit(f"bench_workload_{arch.split('-')[0]}_mcf_vs_pt", 0,
             f"{row['mcf_vs_pt']:.3f}x")
        if "mcf_vs_generic" in row:
            emit(f"bench_workload_{arch.split('-')[0]}_mcf_vs_generic",
                 row["synth_s"] * 1e6, f"{row['mcf_vs_generic']:.3f}x")
    if json_path:
        keep = "n256"                      # keep the --full record around
        prior_full = prior.get("sizes", {}).get(keep)
        if not full and prior_full and keep not in result["sizes"]:
            result["sizes"][keep] = prior_full
        Path(json_path).write_text(json.dumps(result, indent=2) + "\n")
        print(f"  wrote {json_path}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    main(args.full,
         json_path=Path(__file__).parent.parent / "BENCH_workload.json"
         if args.json else None)
