"""Fig. 11 (workload co-design): specialized vs generic TONS vs torus.

Renders the headline comparison of ``bench_workload``: for each
registered workload, the demand-weighted MCF and the trace-replay
saturation of the workload-specialized fabric, the generic
uniform-demand TONS, and the PT torus, normalized to the torus.

Cheap by construction: reads BENCH_workload.json when present
(written by ``bench_workload --json``, which ``run.py`` executes
earlier in the same suite pass); otherwise falls back to an
analytic-only comparison -- weighted MCF of the cached topologies
(``tons_wl_<n>_<arch>.pkl`` / ``tons_<n>.pkl``) without any synthesis
or simulation, skipping fabrics whose caches are absent.
"""
from __future__ import annotations

import argparse
import json
import pickle
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent))

from benchmarks.common import RESULTS, emit, load_tons


def _bars(label: str, vals: dict, base: float) -> None:
    for name, v in vals.items():
        norm = v / max(base, 1e-12)
        bar = "#" * max(1, int(round(norm * 20)))
        print(f"  {label:28s} {name:11s} {v:.5f} ({norm:.2f}x) {bar}")


def _from_bench(d: dict) -> None:
    for sname, size in d.get("sizes", {}).items():
        for arch, row in size.get("workloads", {}).items():
            for metric, key in (("weighted MCF", "weighted_mcf"),
                                ("trace saturation",
                                 "trace_saturation")):
                vals = {"specialized": row["specialized"][key],
                        "pt": row["pt"][key]}
                if "generic" in row:
                    vals["generic"] = row["generic"][key]
                _bars(f"{sname} {arch} {metric}", vals, row["pt"][key])
        if "tenants" in size:
            pt = size["tenants"]["per_tenant"]
            print(f"  {sname} shared fabric "
                  f"({size['tenants']['fabric']}): " + " ".join(
                      f"{k} delivered={v['delivered']:.4f}"
                      for k, v in pt.items()))
    r = d["sizes"]["n128"]["workloads"]
    for arch, row in r.items():
        emit(f"fig11_{arch.split('-')[0]}_mcf_vs_pt", 0,
             f"{row['mcf_vs_pt']:.3f}x")


def _analytic_fallback() -> None:
    """No bench record yet: weighted MCF only, cached topologies only."""
    import numpy as np

    from repro.core import demand as D, topology as T, workload as W

    spec, n = (4, 4, 8), 128
    generic = load_tons(n)
    pt = T.pt(spec)
    for arch, shape in (("deepseek-moe-16b", "train_4k"),
                        ("gemma-7b", "train_4k")):
        wd = W.workload_demand(spec, arch, shape)
        vals = {"pt": D.weighted_mcf(pt, wd)}
        if generic:
            vals["generic"] = D.weighted_mcf(generic[0], wd)
        pkl = RESULTS / f"tons_wl_{n}_{arch}.pkl"
        if pkl.exists():
            cached = pickle.load(open(pkl, "rb"))
            topo = T.Topology(T.Pod(spec),
                              [tuple(e) for e in cached["optical"]],
                              name=f"TONS-wl {arch}")
            vals["specialized"] = D.weighted_mcf(topo, wd)
        else:
            print(f"  n128 {arch}: no specialized cache "
                  f"(run bench_workload first)")
        _bars(f"n128 {arch} weighted MCF", vals, vals["pt"])


def main(full: bool = False) -> None:
    bench = Path(__file__).parent.parent / "BENCH_workload.json"
    print("# workload co-design (fig 11): specialized vs generic vs "
          "torus, normalized to PT")
    if bench.exists():
        _from_bench(json.loads(bench.read_text()))
    else:
        _analytic_fallback()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(ap.parse_args().full)
