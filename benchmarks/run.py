"""Benchmark suite entry point: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (plus human-readable detail).
Quick settings by default; pass --full for the paper-scale sweeps.

CI usage: ``python benchmarks/run.py --json --check`` runs every suite,
writes the BENCH_*.json trackers, and exits non-zero when a regression
guard trips (exit 1) or a suite raises (exit 2). Guards compare against
the stored BENCH_*.json baselines and skip with a warning when those are
absent (fresh checkout / fork), so a first CI run always passes the
guard stage.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).parent.parent))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", action="store_true",
                    help="also write machine-readable BENCH_netsim.json "
                         "(netsim sweep wall-clock + per-pattern "
                         "saturation points, the guarded 8^3 CSR-kernel "
                         "section with staged array bytes + peak RSS, "
                         "and with --full the 12^3 n1728 saturation "
                         "entry -- kept across quick runs, guards skip "
                         "while it is missing), BENCH_routing.json "
                         "(routing-engine wall-clock at 64/256/512 chips "
                         "incl. the batched allowed-turns admission "
                         "breakdown, per-stage select splits for the "
                         "array and streaming sharded engines, and VC "
                         "greedy-dead-end counters; the guarded 8^3 "
                         "time-to-recover lane -- single-OCS repair wall "
                         "clock, flows re-routed and post-repair l_max "
                         "ratio vs the full-recompute oracle; with "
                         "--full also the "
                         "1728-chip 12^3 and 4096-chip 16^3 end-to-end "
                         "entries routed by the sharded engine into the "
                         "CSR PathTable plus the 12^3 repair entry) and "
                         "BENCH_synthesis.json "
                         "(batched LP synthesis wall-clock, lambda vs "
                         "the Basu bound, routed l_max + saturation of "
                         "synthesized vs torus pods; --full adds the "
                         "256-chip and 8^3 512-chip entries) and "
                         "BENCH_chaos.json (the guarded 8^3 chaos "
                         "campaign: >= 20-event seeded fault/heal "
                         "timeline wall-clock with per-event invariant "
                         "checks, min served-pair fraction and the "
                         "post-heal l_max ratio vs the cold build; "
                         "--full adds netsim throughput probes along "
                         "the timeline) and BENCH_workload.json (the "
                         "guarded workload co-design lane: per-workload "
                         "demand-specialized synthesis wall-clock, "
                         "demand-weighted MCF + trace-replay saturation "
                         "of specialized vs generic TONS vs torus, and "
                         "the two-tenant shared-fabric accounting; "
                         "--full adds the 256-chip entry). Guarded "
                         "timings are medians of 3 repeats; regressions "
                         "past the per-guard bound vs the stored "
                         "baseline print a WARNING line")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero when any regression guard trips "
                         "(exit 1) or a suite errors (exit 2) -- the CI "
                         "regression-guard mode; guards skip cleanly "
                         "when no BENCH_*.json baseline exists yet")
    args = ap.parse_args()
    if args.check and not args.json:
        # guards compare against (and refresh) the BENCH_*.json
        # baselines; --check without them would silently check nothing
        print("## --check implies --json (guards need the stored "
              "baselines)")
        args.json = True

    from benchmarks import (bench_chaos, bench_netsim, bench_routing,
                            bench_synthesis, bench_workload,
                            fig1_smallgraphs, fig2_progress,
                            fig3_analytical, fig5_saturation,
                            fig6_collectives, fig7_traces, fig8_faults,
                            fig9_routing_ablation, fig10_chaos,
                            fig11_workload, roofline)
    from benchmarks.common import REGRESSIONS
    root = Path(__file__).parent.parent
    netsim_json = root / "BENCH_netsim.json" if args.json else None
    routing_json = root / "BENCH_routing.json" if args.json else None
    synthesis_json = root / "BENCH_synthesis.json" if args.json else None
    chaos_json = root / "BENCH_chaos.json" if args.json else None
    workload_json = root / "BENCH_workload.json" if args.json else None
    suites = [
        ("fig1_smallgraphs", fig1_smallgraphs.main),
        ("fig2_progress", fig2_progress.main),
        ("fig3_analytical", fig3_analytical.main),
        ("fig5_saturation", fig5_saturation.main),
        ("fig6_collectives", fig6_collectives.main),
        ("fig7_traces", fig7_traces.main),
        ("fig8_faults", fig8_faults.main),
        ("fig9_routing_ablation", fig9_routing_ablation.main),
        ("fig10_chaos", fig10_chaos.main),
        ("roofline", roofline.main),
        ("bench_netsim",
         lambda full=False: bench_netsim.main(full, json_path=netsim_json)),
        ("bench_routing",
         lambda full=False: bench_routing.main(full,
                                               json_path=routing_json)),
        ("bench_synthesis",
         lambda full=False: bench_synthesis.main(
             full, json_path=synthesis_json)),
        ("bench_chaos",
         lambda full=False: bench_chaos.main(full, json_path=chaos_json)),
        ("bench_workload",
         lambda full=False: bench_workload.main(
             full, json_path=workload_json)),
        ("fig11_workload", fig11_workload.main),
    ]
    errors = []
    print("name,us_per_call,derived")
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        print(f"## {name}")
        t0 = time.time()
        try:
            fn(full=args.full)
        except Exception as e:
            print(f"{name},0,ERROR:{e}")
            traceback.print_exc()
            errors.append(name)
        print(f"## {name} done in {time.time() - t0:.1f}s", flush=True)

    if errors:
        print(f"## suites with errors: {', '.join(errors)}")
    if REGRESSIONS:
        print(f"## regression guards tripped: "
              f"{', '.join(g['name'] for g in REGRESSIONS)}")
    if args.check:
        if errors:
            return 2
        if REGRESSIONS:
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
