"""Fig. 2: LP objective / topology quality over synthesis time, vs the
TPU-constrained random baseline."""
from __future__ import annotations

import argparse
import pickle

import numpy as np

from benchmarks.common import RESULTS, emit


def main(full: bool = False) -> None:
    from repro.core import topology as T
    from repro.core.mcf import mcf_uniform

    p = RESULTS / "tons_128.pkl"
    if p.exists():
        d = pickle.load(open(p, "rb"))
        lams, times = d["lambdas"], d["times"]
        print("# LP-relaxation objective over greedy iterations "
              "(128 nodes):")
        idx = np.linspace(0, len(lams) - 1, min(8, len(lams))).astype(int)
        for i in idx:
            print(f"  t={times[i]:7.1f}s  lambda={lams[i]:.5f}")
        print(f"  final integral mcf={d['mcf']:.5f}")
        emit("fig2_final_mcf", times[-1] * 1e6, f"{d['mcf']:.5f}")

    # random (TPU-constrained) baseline band
    vals = []
    for s in range(4 if not full else 16):
        topo = T.random_topology((4, 4, 8), seed=s)
        lam, _ = mcf_uniform(topo.edges(), topo.n,
                             perms=None, prefer="highs")
        vals.append(lam)
    vals = np.array(vals)
    print(f"  random baseline: mean={vals.mean():.5f} "
          f"std={vals.std():.5f} max={vals.max():.5f}")
    emit("fig2_random_mean", 0, f"{vals.mean():.5f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(ap.parse_args().full)
