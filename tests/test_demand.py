"""Weighted-demand (beyond-paper) machinery tests."""
import numpy as np
import pytest

from repro.core import demand as D, topology as T
from repro.core.mcf import mcf_uniform


def test_weight_fn_translation_invariant():
    pod = T.Pod((4, 4, 8))
    wd = D.WorkloadDemand(pod, w_same_cube=2.0, w_ring=3.0, w_uniform=0.5)
    fn = wd.weight_fn()
    perms = T.cube_translations(pod)
    rng = np.random.default_rng(0)
    a = rng.integers(0, pod.n, 40)
    b = rng.integers(0, pod.n, 40)
    w0 = fn(a, b)
    for g in range(len(perms)):
        wg = fn(perms[g][a], perms[g][b])
        np.testing.assert_allclose(w0, wg)


def test_weighted_mcf_reduces_to_uniform():
    """With all weights equal the weighted MCF equals scaled uniform MCF."""
    topo = T.pt((4, 4, 8))
    perms = T.torus_translations(topo.pod)
    lam_u, _ = mcf_uniform(topo.edges(), topo.n, perms=perms,
                           prefer="highs")
    wd = D.WorkloadDemand(topo.pod, w_same_cube=0.0, w_ring=0.0,
                          w_uniform=2.0)
    lam_w, _ = mcf_uniform(topo.edges(), topo.n, perms=perms,
                           prefer="highs", pair_weight=wd.weight_fn())
    # doubling every demand halves the concurrent rate
    assert abs(lam_w - lam_u / 2.0) < 1e-6


def test_weighted_mcf_prefers_matching_topology():
    """Ring-heavy demand should rate the torus higher than uniform does
    (relatively): the PT/PDTT weighted gap shrinks vs the uniform gap."""
    pod = T.Pod((4, 4, 8))
    wd = D.WorkloadDemand(pod, w_same_cube=0.2, w_ring=4.0, w_uniform=0.2)
    fn = wd.weight_fn()
    pt = T.pt((4, 4, 8))
    pdtt = T.pdtt((4, 4, 8))
    lam_pt = D.weighted_mcf(pt, wd, perms=T.torus_translations(pt.pod))
    lam_pdtt = D.weighted_mcf(
        pdtt, wd, perms=T.torus_translations(pdtt.pod, twisted=True))
    assert lam_pt > 0 and lam_pdtt > 0
    uniform_ratio = 0.01364 / 0.0078125      # PDTT/PT under uniform
    weighted_ratio = lam_pdtt / lam_pt
    assert weighted_ratio < uniform_ratio
