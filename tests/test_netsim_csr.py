"""CSR-vs-dense simulator kernel parity suite.

The CSR-native kernel (`netsim._sweep_csr`) is the production path; the
legacy dense kernel (`netsim._sweep_dense`) is kept solely as its
bit-identity oracle. Both draw the same RNG stream and sample the same
flow slots, so every counter of every rate lane must match exactly --
delivered, tagged, conservation, all of them -- on any topology small
enough for the dense (n, n, MAXHOP) tables to exist. The suite also
pins the memory claim (CSR stages fewer bytes than dense even at tiny
pods) and, under the opt-in ``huge`` marker, proves the headline: a 12^3
saturation sweep that the dense layout could never run.
"""
import numpy as np
import pytest

from repro.core import fault as F, netsim as NS, routing as R, \
    topology as T
from repro.core.pathtable import CSRPathTable
from repro.core.traffic import TrafficPattern, compile_flow_traffic


def _patterns(topo, at):
    color = F.colors_in_use(topo)[0]
    region = F.fault_region_nodes(at, color)
    return {
        "uniform": None,
        "hotspot": TrafficPattern.hotspot(topo.n, frac=0.4),
        "fault_correlated": TrafficPattern.fault_correlated(
            topo.n, region, frac=0.6, src_boost=2.0),
    }


@pytest.fixture(scope="module", params=[(4, 4, 4), (4, 4, 8)])
def pod_tables(request):
    topo = T.pt(request.param)
    at = R.allowed_turns(topo, n_vc=2, priority="apl")
    sel = R.select_paths(at, K=4, local_search_rounds=1, engine="sharded")
    tab = NS.at_tables(topo, at, sel)
    return topo, at, tab


# ---------------------------------------------------------------------------
# bit-identity of the two kernels
# ---------------------------------------------------------------------------


def test_csr_and_dense_kernels_bit_identical_across_patterns(pod_tables):
    topo, at, tab = pod_tables
    rates = [0.02, 0.08, 0.2, 0.6]
    for name, tp in _patterns(topo, at).items():
        s_csr: dict = {}
        s_dense: dict = {}
        tc = NS.sweep(tab, rates, traffic=tp, cycles=1200, warmup=400,
                      kernel="csr", stats=s_csr)
        td = NS.sweep(tab, rates, traffic=tp, cycles=1200, warmup=400,
                      kernel="dense", stats=s_dense)
        assert tc == td, f"kernel divergence under {name}"
        for r in tc:
            assert r["injected_total"] == (r["consumed_total"]
                                           + r["in_flight"]), name
        assert s_csr["kernel"] == "csr"
        assert s_dense["kernel"] == "dense"
        # the memory claim in miniature: CSR stages fewer bytes than the
        # dense (n, n, MAXHOP) gather tables even at these pod sizes
        assert s_csr["array_bytes"] < s_dense["array_bytes"]


def test_kernels_match_on_dor_tables_and_other_seeds(pod_tables):
    topo, _, _ = pod_tables
    tab = NS.dor_tables(topo)
    for seed in (0, 3):
        a = NS.run(tab, 0.15, cycles=900, warmup=300, seed=seed,
                   kernel="csr")
        b = NS.run(tab, 0.15, cycles=900, warmup=300, seed=seed,
                   kernel="dense")
        assert a == b
    # different seeds genuinely change the sampled stream
    assert NS.run(tab, 0.15, cycles=900, warmup=300, seed=0) \
        != NS.run(tab, 0.15, cycles=900, warmup=300, seed=3)


def test_kernels_match_under_fault_rerouted_tables():
    topo = T.pt((4, 4, 4))
    at = R.allowed_turns(topo, n_vc=2, priority="apl")
    color = F.colors_in_use(topo)[0]
    dead = F.dead_channels_for_color(at, color)
    sel = R.select_paths(at, K=4, local_search_rounds=1,
                         dead_channels=dead, engine="sharded")
    tab = NS.at_tables(topo, at, sel)
    tp = TrafficPattern.fault_correlated(
        topo.n, F.fault_region_nodes(at, color), frac=0.5)
    a = NS.sweep(tab, [0.05, 0.3], traffic=tp, cycles=1000, warmup=300,
                 kernel="csr")
    b = NS.sweep(tab, [0.05, 0.3], traffic=tp, cycles=1000, warmup=300,
                 kernel="dense")
    assert a == b


def test_compiled_flow_traffic_reused_across_kernels(pod_tables):
    """Pre-compiling the pattern onto flow slots must not change counts
    -- saturation_point relies on compiling once and sharing it."""
    topo, at, tab = pod_tables
    tp = TrafficPattern.hotspot(topo.n, frac=0.3)
    csr = tab.csr()
    ct = compile_flow_traffic(tp, csr.src_indptr, csr.dst)
    a = NS.run(tab, 0.1, traffic=ct, cycles=800, warmup=200)
    b = NS.run(tab, 0.1, traffic=tp, cycles=800, warmup=200)
    assert a == b


# ---------------------------------------------------------------------------
# saturation parity on the synthesized fabric
# ---------------------------------------------------------------------------


def _load_tons_topo(n):
    import pickle
    from pathlib import Path
    from repro.core.topology import Pod, Topology
    p = Path(__file__).parent.parent / "benchmarks" / "results" \
        / f"tons_{n}.pkl"
    if not p.exists():
        return None
    d = pickle.load(open(p, "rb"))
    return Topology(Pod((4, 4, 8)), [tuple(e) for e in d["optical"]],
                    name=f"TONS_SYM {n}")


@pytest.mark.slow
def test_csr_saturation_matches_dense_on_synthesized_128():
    topo = _load_tons_topo(128)
    if topo is None:
        pytest.skip("no synthesized tons_128.pkl artifact")
    at = R.allowed_turns(topo, n_vc=2, priority="apl")
    sel = R.select_paths(at, K=4, local_search_rounds=1,
                         engine="sharded")
    tab = NS.at_tables(topo, at, sel)
    sat_c, tr_c = NS.saturation_point(tab, step=0.05, cycles=1500,
                                      warmup=500, kernel="csr")
    sat_d, tr_d = NS.saturation_point(tab, step=0.05, cycles=1500,
                                      warmup=500, kernel="dense")
    assert sat_c == sat_d
    assert tr_c == tr_d


# ---------------------------------------------------------------------------
# the headline: scales the dense layout cannot reach
# ---------------------------------------------------------------------------


def test_sim_tables_stay_csr_and_cache_views(pod_tables):
    _, _, tab = pod_tables
    assert isinstance(tab.table, CSRPathTable)
    d1 = tab.dense()
    c1 = tab.csr()
    assert tab.dense() is d1 and tab.csr() is c1  # cached, not rebuilt
    assert isinstance(tab.table, CSRPathTable)    # never swapped out
    assert c1 is tab.table
    assert c1.nbytes() < d1.nbytes()


@pytest.mark.huge
@pytest.mark.slow
def test_12cube_csr_saturation_smoke():
    """12^3 saturation via the CSR kernel (opt-in ``-m huge``): the
    scale the dense (n, n, MAXHOP) layout cannot stage at all."""
    topo = T.pt((12, 12, 12))
    at = R.allowed_turns(topo, n_vc=2, priority="apl")
    sel = R.select_paths(at, K=4, local_search_rounds=1,
                         engine="sharded")
    assert sel.unreachable == 0
    tab = NS.at_tables(topo, at, sel)
    assert isinstance(tab.table, CSRPathTable)
    stats: dict = {}
    sat, trace = NS.saturation_point(tab, step=0.05, max_rate=0.5,
                                     cycles=1200, warmup=400,
                                     kernel="csr", stats=stats)
    assert sat > 0.0
    assert all(r["injected_total"] == r["consumed_total"] + r["in_flight"]
               for r in trace)
    # the whole staged working set stays far below the ~1.7 GB the dense
    # tables alone would need at n=1728
    assert stats["array_bytes"] < 400 * 1024 * 1024
