"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.minplus import minplus

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("S", [128, 256])
@pytest.mark.parametrize("hd", [64, 128])
@pytest.mark.parametrize("heads", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(S, hd, heads, dtype):
    Hq, Hkv = heads
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, Hq, S, hd), dtype)
    k = jax.random.normal(ks[1], (1, Hkv, S, hd), dtype)
    v = jax.random.normal(ks[2], (1, Hkv, S, hd), dtype)
    o1 = flash_attention(q, k, v, causal=True, bq=128, bk=128,
                         interpret=True)
    o2 = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_noncausal():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 4, 128, 64), jnp.float32)
    k = jax.random.normal(ks[1], (2, 2, 256, 64), jnp.float32)
    v = jax.random.normal(ks[2], (2, 2, 256, 64), jnp.float32)
    o1 = flash_attention(q, k, v, causal=False, interpret=True)
    o2 = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 128, 384),
                                   (128, 256, 128)])
def test_minplus_sweep(shape):
    M, K, N = shape
    a = jax.random.uniform(KEY, (M, K), jnp.float32) * 10
    b = jax.random.uniform(jax.random.PRNGKey(7), (K, N), jnp.float32) * 10
    o1 = minplus(a, b, interpret=True)
    o2 = ref.minplus_ref(a, b)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def test_apsp_matches_scipy():
    from repro.core import topology as T
    topo = T.pt((4, 4, 8))
    d_kernel, h_kernel = ops.topology_metrics(topo.edges(), topo.n)
    d_ref, h_ref = T.diameter_avg_hops(topo)
    assert d_kernel == d_ref
    assert abs(h_kernel - h_ref) < 1e-3


def test_minplus_property_random():
    """Property-style: idempotence D = minplus(D, D) at the APSP fixpoint
    and triangle inequality of the closure."""
    rng = np.random.default_rng(0)
    n = 128
    d0 = np.full((n, n), 1e9, np.float32)
    np.fill_diagonal(d0, 0)
    for _ in range(3 * n):
        u, v = rng.integers(0, n, 2)
        if u != v:
            d0[u, v] = d0[v, u] = 1.0
    closure = np.asarray(ref.apsp_ref(jnp.asarray(d0)))
    again = np.asarray(ref.minplus_ref(jnp.asarray(closure),
                                       jnp.asarray(closure)))
    np.testing.assert_allclose(closure, again, atol=1e-5)
