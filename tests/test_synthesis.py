"""Batched synthesis engine: LP-builder bit-exactness vs the seed loops,
small-pod known optima, and the end-to-end round trip into a
deadlock-free routed pod."""
import numpy as np
import pytest

from repro.core import synthesis as SY, topology as T


def _canon(A):
    import scipy.sparse as sp
    M = sp.coo_matrix((A.vals, (A.rows, A.cols)), shape=A.shape).tocsr()
    M.sum_duplicates()
    return M


@pytest.mark.parametrize("spec,kw", [
    ((4, 4, 4), {}),
    ((4, 4, 8), {}),
    ((4, 4, 8), {"fault_f": 1}),
    ((4, 4, 8), {"symmetric": False}),
])
def test_lp_builders_bit_identical(spec, kw):
    """The ragged-CSR builder reproduces the seed's per-pair loops
    exactly: same variable layout, same rows, same coalesced matrix."""
    pod = T.Pod(spec)
    ref = SY.build_synthesis_lp(pod, engine="reference", **kw)
    bat = SY.build_synthesis_lp(pod, engine="batched", **kw)
    assert ref.n_var == bat.n_var
    assert ref.A.shape == bat.A.shape
    assert np.array_equal(ref.c, bat.c)
    assert np.array_equal(ref.b, bat.b)
    assert np.array_equal(ref.lo, bat.lo)
    assert np.array_equal(ref.hi, bat.hi)
    diff = _canon(ref.A) - _canon(bat.A)
    diff.eliminate_zeros()
    assert diff.nnz == 0
    assert ref.orbit_keys == bat.orbit_keys
    assert ref.orbit_members == bat.orbit_members
    assert ref.port_of == bat.port_of


def test_lp_builder_pair_weight_matches():
    def pw(a, b):
        return (np.asarray(a) + np.asarray(b)) % 3 * 0.5

    pod = T.Pod((4, 4, 4))
    ref = SY.build_synthesis_lp(pod, engine="reference", pair_weight=pw)
    bat = SY.build_synthesis_lp(pod, engine="batched", pair_weight=pw)
    assert np.array_equal(ref.b, bat.b)
    diff = _canon(ref.A) - _canon(bat.A)
    diff.eliminate_zeros()
    assert diff.nnz == 0


def test_lp_builder_rejects_unknown_engine():
    with pytest.raises(ValueError):
        SY.build_synthesis_lp(T.Pod((4, 4, 4)), engine="nope")


@pytest.fixture(scope="module")
def small_synth():
    return SY.synthesize((4, 4, 4), interval=48)


def test_synthesize_small_pod_recovers_torus(small_synth):
    """Single-cube pods admit exactly one perfect matching per OCS group
    (two ports per color), so synthesis must recover the 4-torus wrap --
    a known small-graph optimum -- and its LP lambda must equal the
    exact torus MCF."""
    from repro.core.mcf import mcf_topology
    want = {(u, v) for u, v, _ in T.pt_optical(T.Pod((4, 4, 4)))}
    got = {(u, v) for u, v, _ in small_synth.topology.optical}
    assert got == want
    assert small_synth.status == "ok"
    assert small_synth.n_fixed == small_synth.n_orbits == 48
    assert small_synth.n_completed == 0
    lam = mcf_topology(small_synth.topology, prefer="highs")
    lam_pt = mcf_topology(T.pt((4, 4, 4)), prefer="highs")
    assert abs(lam - lam_pt) < 1e-6
    assert abs(small_synth.lp_lambda - lam) < 1e-4


def test_to_topology_roundtrip_deadlock_free(small_synth):
    """to_topology() feeds the production pipeline: allowed_turns ->
    select_paths(engine="sharded") -> VC alloc -> deadlock-free verify."""
    topo = small_synth.to_topology()
    assert topo is small_synth.topology
    ee = SY.evaluate_end_to_end(topo, K=4, select_engine="sharded")
    assert ee["deadlock_free"]
    assert ee["unreachable"] == 0
    assert ee["l_max"] >= ee["load_lower_bound"] > 0
    assert ee["n_allowed_turns"] > 0


def test_synthesize_directed_complete_graph():
    """Known optimum from core/smallgraphs.py: with r = n-1 the only
    degree-saturating topology is the complete digraph."""
    from repro.core import smallgraphs as SG
    n, r = 6, 5
    edges, _ = SG.synthesize_directed(n, r, interval=5)
    assert len(edges) == n * (n - 1)
    complete = np.array([(a, b) for a in range(n)
                         for b in range(n) if a != b], np.int32)
    assert abs(SG.directed_mcf(edges, n) -
               SG.directed_mcf(complete, n)) < 1e-8


@pytest.mark.slow
def test_synthesize_128_beats_torus_baselines():
    """(4,4,8) synthesis quality: the integral MCF must clear the PT
    torus (0.00781) by a wide margin; measured 0.01418 on this container
    vs the paper's 0.01403 (TONS) / 0.01364 (PDTT)."""
    from repro.core.mcf import mcf_uniform
    res = SY.synthesize((4, 4, 8))
    topo = res.topology
    perms = T.cube_translations(topo.pod) if res.n_completed == 0 else None
    lam, _ = mcf_uniform(topo.edges(), topo.n, perms=perms, prefer="highs")
    assert lam > 0.012    # >1.5x PT; observed 0.01418
    # matching completion guarantees a full radix-6 fabric
    deg = np.zeros(topo.n, int)
    for u, v in topo.edges():
        deg[u] += 1
        deg[v] += 1
    assert (deg == 6).all()
