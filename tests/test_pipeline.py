"""Facade equivalence: `route_pod` must reproduce the raw staged chain.

The PR-10 API redesign is only safe if a migrated call site is
bit-identical to the hand-rolled `allowed_turns -> select_paths ->
allocate_vcs / at_tables` chain it replaced -- same seed in, same
tables out, on every engine and VC mode the internal call sites use.
These tests pin exactly that, plus the deprecation surface
(`RoutingResult.paths` / `PathTable.as_dicts`).
"""
import dataclasses
import warnings

import numpy as np
import pytest

from repro.core import fault as F, netsim as NS, routing as R, \
    topology as T
from repro.core.pipeline import PipelineConfig, RoutedPod, route_pod
from repro.core.vcalloc import allocate_vcs, verify_deadlock_free

SPEC = (4, 4, 4)


def _tables_equal(a, b) -> bool:
    """Bit-identity across every ndarray/scalar field of a path table
    (works for both the dense PathTable and the CSRPathTable)."""
    assert type(a) is type(b)
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, np.ndarray):
            if va.dtype != vb.dtype or not np.array_equal(va, vb):
                return False
        elif va != vb:
            return False
    return True


@pytest.mark.parametrize("engine", ["array", "sharded"])
def test_route_pod_matches_raw_chain(engine):
    topo = T.pt(SPEC)
    at = R.allowed_turns(topo, n_vc=2, priority="apl", seed=0)
    sel = R.select_paths(at, K=4, seed=0, local_search_rounds=2,
                         engine=engine)
    tab = NS.at_tables(topo, at, sel)

    rp = route_pod(topo, PipelineConfig(K=4, seed=0, engine=engine,
                                        local_search_rounds=2))
    assert isinstance(rp, RoutedPod)
    assert rp.l_max == float(sel.l_max)
    assert rp.avg_hops == float(sel.avg_hops)
    assert rp.unreachable == int(sel.unreachable)
    assert _tables_equal(rp.routed.table, sel.table)
    assert _tables_equal(rp.tables.table, tab.table)
    assert set(rp.timings) >= {"at_s", "select_s", "vc_s"}


def test_route_pod_inplace_matches_allocate_vcs():
    topo = T.pdtt(SPEC)
    at = R.allowed_turns(topo, n_vc=2, priority="apl", seed=0)
    sel = R.select_paths(at, K=4, seed=0, local_search_rounds=1,
                         engine="array")
    counts = allocate_vcs(at, sel.table, balance=True)
    assert verify_deadlock_free(at, sel.table)

    rp = route_pod(topo, PipelineConfig(K=4, seed=0, engine="array",
                                        local_search_rounds=1,
                                        vc="inplace", verify=True))
    assert rp.deadlock_free is True
    assert rp.tables is None
    np.testing.assert_array_equal(rp.vc_counts, counts)
    # in-place mode allocates on the routed table itself, no copy
    assert rp.table is rp.routed.table
    assert _tables_equal(rp.table, sel.table)


def test_route_pod_vc_none_skips_allocation():
    topo = T.pt(SPEC)
    rp = route_pod(topo, PipelineConfig(K=4, local_search_rounds=1,
                                        engine="array", vc="none"))
    assert rp.tables is None and rp.vc_counts is None
    assert rp.unreachable == 0 and rp.l_max > 0


def test_route_pod_prebuilt_at_and_dead_channels():
    """The fault-sweep shape: reuse one robust AT, re-select around a
    dead color -- identical to calling select_paths directly."""
    topo = T.pdtt(SPEC)
    at = R.allowed_turns(topo, n_vc=4, priority="apl", robust=True,
                         seed=0)
    dead = F.dead_channels_for_color(at, F.colors_in_use(topo)[0])
    sel = R.select_paths(at, K=4, seed=0, local_search_rounds=1,
                         engine="array", dead_channels=dead)

    rp = route_pod(topo, PipelineConfig(K=4, seed=0, engine="array",
                                        local_search_rounds=1,
                                        vc="none"),
                   at=at, dead_channels=dead)
    assert rp.at is at                    # reused, not rebuilt
    assert "at_s" not in rp.timings
    assert _tables_equal(rp.routed.table, sel.table)


def test_pipeline_config_rejects_bad_vc_mode():
    with pytest.raises(ValueError, match="vc mode"):
        PipelineConfig(vc="bogus")


def test_select_kw_overrides_config():
    topo = T.pt(SPEC)
    rp = route_pod(topo, PipelineConfig(K=4, engine="array",
                                        local_search_rounds=2,
                                        vc="none"),
                   select_kw={"local_search_rounds": 0})
    ref = route_pod(topo, PipelineConfig(K=4, engine="array",
                                        local_search_rounds=0,
                                        vc="none"))
    assert _tables_equal(rp.routed.table, ref.routed.table)


# ---------------------------------------------------------------------------
# deprecation surface
# ---------------------------------------------------------------------------


def _routed(topo):
    return route_pod(topo, PipelineConfig(K=4, engine="array",
                                          local_search_rounds=1,
                                          vc="none")).routed


def test_pathtable_as_dicts_deprecated():
    sel = _routed(T.pt(SPEC))
    with pytest.warns(DeprecationWarning, match="as_dicts"):
        d = sel.table.as_dicts()
    assert len(d) > 0


def test_routing_result_paths_deprecated_single_warning():
    sel = _routed(T.pt(SPEC))
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        p = sel.paths
    deps = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    # the property warns once; the inner as_dicts warning is suppressed
    assert len(deps) == 1
    assert "paths" in str(deps[0].message)
    assert len(p) > 0


# ---------------------------------------------------------------------------
# demand-weighted selection (pair_weight)
# ---------------------------------------------------------------------------


def test_pair_weight_all_ones_is_identity():
    """Unit multiplicities must be bit-identical to the unweighted
    selector -- the weighted arithmetic degenerates exactly."""
    topo = T.pt(SPEC)
    n = topo.n
    plain = route_pod(topo, PipelineConfig(K=4, engine="array",
                                           local_search_rounds=2,
                                           vc="none"))
    ones = route_pod(topo, PipelineConfig(K=4, engine="array",
                                          local_search_rounds=2,
                                          vc="none"),
                     pair_weight=np.ones((n, n)))
    assert plain.l_max == ones.l_max
    assert _tables_equal(plain.routed.table, ones.routed.table)


def _weighted_bottleneck(table, w) -> float:
    """Max per-channel load when pair (s, d) counts as w[s, d] flows."""
    valid = table.path >= 0
    loads = np.bincount(
        table.path[valid],
        weights=np.broadcast_to(w[:, :, None], table.path.shape)[valid],
        minlength=table.n_ch)
    return float(loads.max())


def test_pair_weight_skew_steers_selection():
    """A skewed demand must steer the selector: the weighted run's
    reported l_max is its true weighted bottleneck, and it beats the
    weighted bottleneck the demand-blind selection lands on."""
    topo = T.pt(SPEC)
    n = topo.n
    rng = np.random.default_rng(7)
    w = np.ones((n, n))
    hot = rng.permutation(n)
    w[np.arange(n), hot] = 8.0            # one hot partner per source
    np.fill_diagonal(w, 1.0)
    plain = route_pod(topo, PipelineConfig(K=4, engine="array",
                                           local_search_rounds=2,
                                           vc="none"))
    weighted = route_pod(topo, PipelineConfig(K=4, engine="array",
                                              local_search_rounds=2,
                                              vc="none"),
                         pair_weight=w)
    assert weighted.routed.unreachable == 0
    assert weighted.l_max == _weighted_bottleneck(weighted.table, w)
    assert weighted.l_max < _weighted_bottleneck(plain.table, w)


def test_pair_weight_requires_array_engine():
    topo = T.pt(SPEC)
    n = topo.n
    with pytest.raises(ValueError, match="array"):
        route_pod(topo, PipelineConfig(K=4, engine="sharded",
                                       vc="none"),
                  pair_weight=np.ones((n, n)))


def test_pair_weight_validation():
    topo = T.pt(SPEC)
    n = topo.n
    at = R.allowed_turns(topo, n_vc=2, priority="apl", seed=0)
    with pytest.raises(ValueError, match="shape"):
        R.select_paths(at, K=4, engine="array",
                       pair_weight=np.ones((3, 3)))
    bad = np.ones((n, n))
    bad[0, 1] = -2.0
    with pytest.raises(ValueError, match="non-negative"):
        R.select_paths(at, K=4, engine="array", pair_weight=bad)
