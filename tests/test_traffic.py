"""TrafficPattern / alias tables / PathTable unit tests."""
import numpy as np
import pytest

from repro.core import topology as T
from repro.core.demand import WorkloadDemand
from repro.core.pathtable import MAXHOP, PathTable
from repro.core.traffic import TrafficPattern, _alias_tables


def _alias_distribution(prob, alias):
    """Exact sampling distribution implied by an alias table row set."""
    n = prob.shape[0]
    dist = np.zeros((n, n), np.float64)
    for s in range(n):
        for j in range(n):
            dist[s, j] += prob[s, j] / n
            dist[s, alias[s, j]] += (1.0 - prob[s, j]) / n
    return dist


@pytest.mark.parametrize("maker", [
    lambda: TrafficPattern.uniform(24),
    lambda: TrafficPattern.hotspot(24, [1, 7], 0.6),
    lambda: TrafficPattern.permutation(np.roll(np.arange(24), 5)),
    lambda: TrafficPattern.from_demand(
        WorkloadDemand(T.Pod((4, 4, 4)), w_same_cube=3.0, w_ring=1.5,
                       w_uniform=0.5)),
])
def test_alias_tables_reproduce_matrix_exactly(maker):
    """The alias method is exact: the implied sampling distribution equals
    the normalised demand matrix row by row."""
    pat = maker()
    ct = pat.compiled()
    dist = _alias_distribution(ct.prob.astype(np.float64), ct.alias)
    m = pat.matrix.copy()
    rows = m.sum(axis=1)
    live = rows > 0
    m[live] /= rows[live][:, None]
    np.testing.assert_allclose(dist[live], m[live], atol=1e-6)
    assert np.abs(np.diag(dist)).max() < 1e-12, "self-traffic"


def test_pattern_diag_zero_and_src_rates():
    n = 16
    u = TrafficPattern.uniform(n)
    assert np.diag(u.matrix).sum() == 0
    np.testing.assert_allclose(u.src_rate, 1.0)
    # permutation with fixed points: those sources inject nothing
    perm = np.arange(n)
    perm[:4] = [1, 0, 3, 2]          # nodes 4.. are fixed points
    p = TrafficPattern.permutation(perm)
    assert (p.src_rate[4:] == 0).all()
    assert (p.src_rate[:4] > 0).all()


def test_transpose_is_injective_permutation():
    # symmetric pod: coordinate swap (x,y,z)->(z,y,x); its fixed points
    # (the x == z plane, X*Y of them) inject nothing
    pod = T.Pod((4, 4, 4))
    pat = TrafficPattern.transpose(pod)
    live = pat.matrix.sum(axis=1) > 0
    assert int(live.sum()) == pod.n - 4 * 4
    dests = pat.matrix.argmax(axis=1)
    assert len(set(dests[live].tolist())) == int(live.sum())
    # asymmetric pod: coordinate complement, fixed-point-free on even dims
    pod = T.Pod((4, 4, 8))
    pat = TrafficPattern.transpose(pod)
    live = pat.matrix.sum(axis=1) > 0
    assert int(live.sum()) == pod.n
    dests = pat.matrix.argmax(axis=1)
    assert len(set(dests.tolist())) == pod.n


def test_hotspot_fraction():
    n, hot, frac = 32, [0, 1], 0.4
    pat = TrafficPattern.hotspot(n, hot, frac)
    m = pat.matrix / pat.matrix.sum(axis=1, keepdims=True)
    hot_share = m[5, hot].sum()
    assert abs(hot_share - frac) < 0.02


def test_demand_matrix_matches_weight_fn():
    pod = T.Pod((4, 4, 8))
    wd = WorkloadDemand(pod, w_same_cube=2.0, w_ring=3.0, w_uniform=0.5)
    m = wd.matrix()
    fn = wd.weight_fn()
    rng = np.random.default_rng(0)
    a = rng.integers(0, pod.n, 64)
    b = rng.integers(0, pod.n, 64)
    w = fn(a, b)
    off = a != b
    np.testing.assert_allclose(m[a[off], b[off]], w[off])
    assert (np.diag(m) == 0).all()


def test_pathtable_roundtrip_and_stats():
    t = PathTable.empty(6, 20, 2)
    t.set_path(0, 1, [3, 4, 5], [0, 0, 1])
    t.set_path(2, 3, [7], [1])
    assert t.n_routed() == 2
    assert t.hops[0, 1] == 3 and t.hops[2, 3] == 1
    loads = t.loads()
    assert loads[3] == 1 and loads[7] == 1 and loads.sum() == 4
    assert t.l_max() == 1.0
    assert abs(t.avg_hops() - 2.0) < 1e-12
    assert t.vc_hop_counts().tolist() == [2, 2]
    paths, vcs = t.as_dicts()
    assert paths[(0, 1)] == (3, 4, 5)
    assert vcs[(0, 1)] == [0, 0, 1]
    back = PathTable.from_dicts(6, 20, paths, vcs)
    np.testing.assert_array_equal(back.path, t.path)
    np.testing.assert_array_equal(back.vcs, t.vcs)
    np.testing.assert_array_equal(back.hops, t.hops)


def test_alias_tables_random_matrices_exact():
    """The batched (row-parallel) Vose construction stays exact on
    unstructured matrices: dense random weights, heavy-tailed rows, and
    rows mixing zeros with large spikes."""
    rng = np.random.default_rng(3)
    n = 48
    dense = rng.random((n, n))
    heavy = rng.pareto(0.7, (n, n)) + 1e-9
    spiky = rng.random((n, n)) * (rng.random((n, n)) < 0.2)
    spiky[np.arange(n), rng.integers(0, n, n)] += 50.0
    for m in (dense, heavy, spiky):
        m = m.copy()
        np.fill_diagonal(m, 0.0)
        prob, alias = _alias_tables(m)
        dist = _alias_distribution(prob.astype(np.float64), alias)
        rows = m.sum(axis=1)
        live = rows > 0
        np.testing.assert_allclose(dist[live], m[live] / rows[live][:, None],
                                   atol=1e-6)


def test_alias_degenerate_rows():
    """All-zero rows compile without NaNs and are masked by src_rate."""
    m = np.zeros((4, 4))
    m[0, 1] = 1.0
    pat = TrafficPattern.from_matrix("deg", m)
    ct = pat.compiled()
    assert np.isfinite(ct.prob).all()
    assert (pat.src_rate[1:] == 0).all()
