"""Routing invariants: acyclic CDG, routability, VC balance, faults."""
import numpy as np
import pytest

from repro.core import fault as F, netsim as NS, routing as R, \
    topology as T, vcalloc as V


@pytest.fixture(scope="module")
def pt128():
    return T.pt((4, 4, 8))


@pytest.fixture(scope="module")
def at128(pt128):
    return R.allowed_turns(pt128, n_vc=2, priority="apl", robust=True)


@pytest.fixture(scope="module")
def routed128(at128):
    return R.select_paths(at128, K=4, local_search_rounds=2)


def _is_dag(at):
    """Kahn's algorithm over the allowed-turn CDG."""
    from collections import defaultdict, deque
    nodes = set()
    adj = defaultdict(list)
    indeg = defaultdict(int)
    for (a, b) in at.allowed:
        nodes.add(a)
        nodes.add(b)
        adj[a].append(b)
        indeg[b] += 1
    q = deque([x for x in nodes if indeg[x] == 0])
    seen = 0
    while q:
        x = q.popleft()
        seen += 1
        for y in adj[x]:
            indeg[y] -= 1
            if indeg[y] == 0:
                q.append(y)
    return seen == len(nodes)


def test_cdg_acyclic(at128):
    assert _is_dag(at128)


def test_all_pairs_routable(routed128, pt128):
    assert routed128.unreachable == 0
    assert len(routed128.paths) == pt128.n * (pt128.n - 1)


def test_paths_are_connected_channel_sequences(routed128, at128):
    ch = at128.channels
    for (s, d), p in list(routed128.paths.items())[::97]:
        assert int(ch.src[p[0]]) == s
        assert int(ch.dst[p[-1]]) == d
        for a, b in zip(p[:-1], p[1:]):
            assert int(ch.dst[a]) == int(ch.src[b])


def test_vc_allocation_valid_and_balanced(at128, routed128):
    vcs, counts = V.allocate_vcs(at128, routed128.paths, balance=True)
    assert V.verify_deadlock_free(at128, routed128.paths, vcs)
    ratio = counts.max() / max(counts.min(), 1)
    assert ratio < 1.2, f"VC imbalance {counts}"
    _, unbal = V.allocate_vcs(at128, routed128.paths, balance=False)
    assert unbal[0] > unbal[1], "naive policy should bias VC0"


def test_routed_lmax_near_mcf_bound(routed128):
    # MCF(PT 4x4x8) = 1/128 -> ordered-pair completion bound = 128
    assert routed128.l_max <= 128 * 1.15


def test_dor_paths_minimal_on_torus(pt128):
    paths, vcs = NS.dor_paths(pt128)
    d = T.bfs_all_pairs(pt128)
    for (s, dd), p in list(paths.items())[::211]:
        assert len(p) == int(d[s, dd])


def test_robust_at_survives_every_fault():
    topo = T.pt((4, 4, 8))
    at = R.allowed_turns(topo, n_vc=2, priority="random", robust=True)
    assert len(at.trees) == 2
    colors = F.colors_in_use(topo)
    # spot-check 6 fault scenarios for full reachability
    for color in colors[::8]:
        dead = F.dead_channels_for_color(at, color)
        routed = R.select_paths(at, K=2, local_search_rounds=0,
                                dead_channels=dead)
        assert routed.unreachable == 0, f"color {color} broke reachability"


def test_incremental_dag_rejects_cycles():
    dag = R.IncrementalDAG(4)
    assert dag.try_add(0, 1)
    assert dag.try_add(1, 2)
    assert dag.try_add(2, 3)
    assert not dag.try_add(3, 0)
    assert not dag.try_add(2, 0)
    assert dag.try_add(0, 3)


def test_netsim_conservation(pt128):
    tab = NS.dor_tables(pt128)
    r = NS.run(tab, 0.05, cycles=1500, warmup=500)
    assert r["delivered"] <= r["offered"] + 1e-9
    assert r["delivered"] > 0.8 * r["offered"]
