"""Routing invariants: acyclic CDG, routability, VC balance, faults."""
import numpy as np
import pytest

from repro.core import fault as F, netsim as NS, routing as R, \
    topology as T, vcalloc as V


@pytest.fixture(scope="module")
def pt128():
    return T.pt((4, 4, 8))


@pytest.fixture(scope="module")
def at128(pt128):
    return R.allowed_turns(pt128, n_vc=2, priority="apl", robust=True)


@pytest.fixture(scope="module")
def routed128(at128):
    return R.select_paths(at128, K=4, local_search_rounds=2)


def _is_dag(at):
    """Kahn's algorithm over the allowed-turn CDG."""
    from collections import defaultdict, deque
    nodes = set()
    adj = defaultdict(list)
    indeg = defaultdict(int)
    for (a, b) in at.allowed:
        nodes.add(a)
        nodes.add(b)
        adj[a].append(b)
        indeg[b] += 1
    q = deque([x for x in nodes if indeg[x] == 0])
    seen = 0
    while q:
        x = q.popleft()
        seen += 1
        for y in adj[x]:
            indeg[y] -= 1
            if indeg[y] == 0:
                q.append(y)
    return seen == len(nodes)


def test_cdg_acyclic(at128):
    assert _is_dag(at128)


def test_all_pairs_routable(routed128, pt128):
    assert routed128.unreachable == 0
    assert routed128.table.n_routed() == pt128.n * (pt128.n - 1)


def test_paths_are_connected_channel_sequences(routed128, at128):
    """Vectorised over every routed pair at once (array-native table)."""
    ch = at128.channels
    t = routed128.table
    ss, dd = np.nonzero(t.routed_mask())
    first = t.path[ss, dd, 0]
    last = t.path[ss, dd, t.hops[ss, dd] - 1]
    assert (ch.src[first] == ss).all()
    assert (ch.dst[last] == dd).all()
    a = t.path[..., :-1]
    b = t.path[..., 1:]
    ok = (a >= 0) & (b >= 0)
    assert (ch.dst[a[ok]] == ch.src[b[ok]]).all()


def test_paths_dict_view_matches_table(routed128, at128):
    """The API-edge dict view stays consistent with the packed arrays."""
    t = routed128.table
    paths = routed128.paths
    assert len(paths) == t.n_routed()
    for (s, d), p in list(paths.items())[::997]:
        L = int(t.hops[s, d])
        assert len(p) == L
        assert list(p) == t.path[s, d, :L].tolist()


def test_vc_allocation_valid_and_balanced(at128, routed128):
    bal_table = routed128.table.copy()
    counts = V.allocate_vcs(at128, bal_table, balance=True)
    assert V.verify_deadlock_free(at128, bal_table)
    assert (counts == bal_table.vc_hop_counts()).all()
    ratio = counts.max() / max(counts.min(), 1)
    assert ratio < 1.2, f"VC imbalance {counts}"
    unbal = V.allocate_vcs(at128, routed128.table.copy(), balance=False)
    assert unbal[0] > unbal[1], "naive policy should bias VC0"


def test_routed_lmax_near_mcf_bound(routed128):
    # MCF(PT 4x4x8) = 1/128 -> ordered-pair completion bound = 128
    assert routed128.l_max <= 128 * 1.15


def test_dor_paths_minimal_on_torus(pt128):
    table = NS.dor_paths(pt128)
    d = T.bfs_all_pairs(pt128)
    np.testing.assert_array_equal(table.hops, d.astype(np.int64))


@pytest.mark.slow
def test_robust_at_survives_every_fault():
    topo = T.pt((4, 4, 8))
    at = R.allowed_turns(topo, n_vc=2, priority="random", robust=True)
    assert len(at.trees) == 2
    colors = F.colors_in_use(topo)
    # spot-check 6 fault scenarios for full reachability
    for color in colors[::8]:
        dead = F.dead_channels_for_color(at, color)
        routed = R.select_paths(at, K=2, local_search_rounds=0,
                                dead_channels=dead)
        assert routed.unreachable == 0, f"color {color} broke reachability"


def test_incremental_dag_rejects_cycles():
    dag = R.IncrementalDAG(4)
    assert dag.try_add(0, 1)
    assert dag.try_add(1, 2)
    assert dag.try_add(2, 3)
    assert not dag.try_add(3, 0)
    assert not dag.try_add(2, 0)
    assert dag.try_add(0, 3)


def test_netsim_conservation(pt128):
    """Regression guard for the seed's accounting deficit: the single
    'delivered' counter mixed warmup-injected arrivals into the measured
    window and could (just) exceed offered, while the in-flight tail made
    it undershoot for long-latency routings. Now delivered_tagged counts
    only window-injected packets (conservation-exact) and delivered is the
    steady-state window consumption rate."""
    tab = NS.dor_tables(pt128)
    r = NS.run(tab, 0.05, cycles=1500, warmup=500)
    assert r["delivered_tagged"] <= r["accepted"] <= r["offered"] + 1e-9
    assert r["delivered"] > 0.8 * r["offered"]
    assert r["delivered_tagged"] > 0.8 * r["offered"]
    # exact conservation over the whole run: every injected packet is
    # either consumed or still queued at the end
    assert r["injected_total"] == r["consumed_total"] + r["in_flight"]


@pytest.fixture(scope="module")
def dor64():
    return NS.dor_tables(T.pt((4, 4, 4)))


@pytest.mark.parametrize("pattern", ["uniform", "transpose", "hotspot",
                                     "demand"])
def test_netsim_flow_conservation_per_pattern(dor64, pattern):
    """Every traffic pattern runs through the same jitted kernel and
    conserves packets exactly."""
    from repro.core.demand import WorkloadDemand
    from repro.core.traffic import TrafficPattern
    pod = T.Pod((4, 4, 4))
    pat = {
        "uniform": lambda: TrafficPattern.uniform(64),
        "transpose": lambda: TrafficPattern.transpose(pod),
        "hotspot": lambda: TrafficPattern.hotspot(64, [0, 5], 0.5),
        "demand": lambda: TrafficPattern.from_demand(
            WorkloadDemand(pod, w_same_cube=2.0, w_ring=1.0,
                           w_uniform=0.25)),
    }[pattern]()
    r = NS.run(dor64, 0.04, traffic=pat, cycles=900, warmup=300)
    assert r["injected_total"] == r["consumed_total"] + r["in_flight"]
    assert r["delivered_tagged"] <= r["accepted"] <= r["offered"] + 1e-9
    assert r["delivered"] > 0, f"{pattern} delivered nothing"
    # destinations obey the pattern: a permutation saturates earlier than
    # uniform but still flows; sanity-check utilisation is reasonable
    assert r["delivered"] > 0.5 * r["offered"]
