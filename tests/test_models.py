"""Per-arch smoke tests + model-level correctness invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.configs.registry import get_config, list_archs
from repro.models import layers as L, model as M
from repro.optim import adamw

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)), jnp.bfloat16)
    if cfg.n_vision_tokens:
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_vision_tokens, cfg.d_model)),
            jnp.bfloat16)
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced config of the same family: one forward + one train step on
    CPU, asserting output shapes and no NaNs (assignment requirement)."""
    cfg = get_config(arch).smoke_model()
    params = M.init_params(cfg, KEY)
    B, S = 2, 32
    batch = _batch(cfg, B, S)
    loss = M.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"

    opt = adamw.init(params)
    from repro.launch.steps import make_train_step
    p2, o2, stats = jax.jit(make_train_step(cfg))(params, opt, batch)
    assert np.isfinite(float(stats["loss"]))
    assert int(o2["step"]) == 1
    # params actually changed
    d0 = jax.tree.leaves(params)[0]
    d1 = jax.tree.leaves(p2)[0]
    assert not np.allclose(np.asarray(d0, np.float32),
                           np.asarray(d1, np.float32))


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen2.5-3b", "mamba2-2.7b",
                                  "jamba-v0.1-52b", "deepseek-moe-16b",
                                  "seamless-m4t-medium"])
def test_prefill_decode_matches_forward(arch):
    """Teacher-forcing consistency: decode at position t after prefill of
    t tokens must reproduce the full forward's logits at position t."""
    import dataclasses
    cfg = get_config(arch).smoke_model()
    if cfg.n_experts:
        # capacity-based MoE drops tokens differently between the full
        # teacher-forced pass and stepwise decode; disable dropping here
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    params = M.init_params(cfg, KEY)
    B, S = 2, 24
    batch = _batch(cfg, B, S)
    if cfg.family == "encdec":
        from repro.models import seq2seq
        full, _ = seq2seq.forward(cfg, params, batch["frames"],
                                  batch["tokens"]), None
        full = seq2seq.forward(cfg, params, batch["frames"],
                               batch["tokens"])
    else:
        from repro.models import lm
        full, _ = lm.forward(cfg, params, batch["tokens"],
                             batch.get("patches"))

    t = S - 8
    pre = {k: (v[:, :t] if k in ("tokens", "labels") else v)
           for k, v in batch.items()}
    if cfg.family == "encdec":
        pre["frames"] = batch["frames"]  # encoder sees the whole input
    logits_t, caches = M.prefill_fn(cfg, params, pre, cache_len=S)
    np.testing.assert_allclose(
        np.asarray(logits_t[:, 0], np.float32),
        np.asarray(full[:, t - 1], np.float32), rtol=0.06, atol=0.15)

    # decode the next few tokens teacher-forced and compare
    for i in range(3):
        tok = batch["tokens"][:, t + i:t + i + 1]
        logits, caches = M.decode_fn(cfg, params, caches, tok,
                                     jnp.int32(t + i))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(full[:, t + i], np.float32), rtol=0.06, atol=0.15)


def test_ssd_chunked_equals_sequential():
    b, l, h, p, g, n = 2, 64, 4, 16, 1, 8
    k = jax.random.split(KEY, 5)
    x = jax.random.normal(k[0], (b, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(k[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(k[2], (h,)) * 0.3)
    Bm = jax.random.normal(k[3], (b, l, g, n), jnp.float32)
    Cm = jax.random.normal(k[4], (b, l, g, n), jnp.float32)
    y1, s1 = L.ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    y2, s2 = L.ssd_sequential(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


def test_flash_jnp_attention_vs_dense():
    B, S, Hq, Hkv, hd = 2, 64, 4, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.float32)
    o1 = L.gqa_attention(q, k, v, causal=True, block=16)
    from repro.kernels.ref import flash_attention_ref
    o2 = flash_attention_ref(q.transpose(0, 2, 1, 3),
                             k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), causal=True)
    np.testing.assert_allclose(np.asarray(o1),
                               np.asarray(o2.transpose(0, 2, 1, 3)),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_and_gates():
    cfg = get_config("deepseek-moe-16b").smoke_model()
    p = L.init_moe(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.bfloat16)
    y, aux = L.moe_ffn(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()
    assert float(aux) > 0.0


def test_scan_vs_unroll_forward_identical():
    import dataclasses
    cfg = get_config("qwen2.5-3b").smoke_model()
    params = M.init_params(cfg, KEY)
    batch = _batch(cfg, 2, 32)
    from repro.models import lm
    l1, _ = lm.forward(cfg, params, batch["tokens"])
    cfg2 = dataclasses.replace(cfg, unroll=True)
    l2, _ = lm.forward(cfg2, params, batch["tokens"])
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32),
                               rtol=2e-2, atol=2e-2)
