"""Equivalence suite for the streaming sharded selection engine and the
packed CSR PathTable: distances and reachability must match the array
engine exactly, min-max quality must stay within 5%, the CSR layout must
round-trip the dense one losslessly, and the exact-lookahead VC
allocation must reproduce the reference DFS policy bit for bit."""
import numpy as np
import pytest

from repro.core import fault as F, netsim as NS, routing as R, \
    topology as T, vcalloc as V
from repro.core.pathtable import CSRPathTable, PathTable


@pytest.fixture(scope="module", params=[(4, 4, 4), (4, 8, 8)])
def pod_routed(request):
    topo = T.pt(request.param)
    at = R.allowed_turns(topo, n_vc=2, priority="apl")
    arr = R.select_paths(at, K=4, local_search_rounds=2, engine="array")
    sh = R.select_paths(at, K=4, local_search_rounds=2, engine="sharded")
    return topo, at, arr, sh


# ---------------------------------------------------------------------------
# sharded engine equivalence
# ---------------------------------------------------------------------------


def test_sharded_routes_every_pair_at_exact_distance(pod_routed):
    topo, at, arr, sh = pod_routed
    assert sh.unreachable == 0
    assert isinstance(sh.table, CSRPathTable)
    assert sh.table.n_routed() == topo.n * (topo.n - 1)
    best = R.node_distances(at, np.arange(topo.n))
    fs, fd = sh.table.flow_src, sh.table.dst
    # every flow's length equals the exact BFS distance of the array
    # engine (all candidates are shortest, the engines only pick)
    assert (sh.table.flow_len == best[fs, fd]).all()
    assert abs(sh.avg_hops - arr.avg_hops) < 1e-12


def test_sharded_paths_are_valid_allowed_turn_walks(pod_routed):
    topo, at, arr, sh = pod_routed
    ch = at.channels
    t = sh.table
    src = t.flow_src
    lens = t.flow_len.astype(np.int64)
    first = t.chan[t.hop_indptr[:-1]]
    last = t.chan[t.hop_indptr[1:] - 1]
    assert (ch.src[first] == src).all()
    assert (ch.dst[last] == t.dst).all()
    # consecutive channels connect node-wise
    m = np.ones(len(t.chan) - 1, bool)
    m[t.hop_indptr[1:-1] - 1] = False
    assert (ch.dst[t.chan[:-1][m]] == ch.src[t.chan[1:][m]]).all()
    # and the (channel, vc) hops are allowed turns
    assert V.verify_deadlock_free(at, t)
    del lens


def test_sharded_l_max_within_5pct_of_array(pod_routed):
    topo, at, arr, sh = pod_routed
    assert sh.l_max <= arr.l_max * 1.05, (sh.l_max, arr.l_max)
    np.testing.assert_array_equal(sh.loads, sh.table.loads())


def test_sharded_under_fault_matches_array_reachability(pod_routed):
    topo, at, arr, _ = pod_routed
    color = F.colors_in_use(topo)[0]
    dead = F.dead_channels_for_color(at, color)
    ref = R.select_paths(at, K=4, local_search_rounds=1,
                         dead_channels=dead, engine="array")
    sh = R.select_paths(at, K=4, local_search_rounds=1,
                        dead_channels=dead, engine="sharded")
    assert sh.unreachable == ref.unreachable
    assert abs(sh.avg_hops - ref.avg_hops) < 1e-12
    assert sh.l_max <= ref.l_max * 1.05
    deadarr = np.fromiter(dead, np.int64, len(dead))
    assert not np.isin(sh.table.chan, deadarr).any()
    assert V.verify_deadlock_free(at, sh.table)


def test_sharded_stats_surface_stage_split_and_counters(pod_routed):
    _, _, arr, sh = pod_routed
    for k in ("bfs_s", "walk_s", "greedy_s", "refine_s", "refine_pool",
              "refine_moved", "k_full_flows", "refine_cap", "uniq_flows",
              "uniq_s"):
        assert k in sh.stats
    # the kcap=1 fast lane must actually fire on these pods: a healthy
    # fraction of flows is channel-path-unique even on symmetric tori
    assert sh.stats["uniq_dp"] is True      # auto heuristic: n <= 512
    assert sh.stats["uniq_flows"] > 0
    for k in ("enumerate_s", "greedy_s", "local_search_s", "hot_peel_s",
              "hot_walk_s"):
        assert k in arr.stats


def test_uniq_dp_gate_off_still_routes_and_records_decision(pod_routed):
    topo, at, _, sh = pod_routed
    off = R.select_paths(at, K=4, local_search_rounds=1, engine="sharded",
                         uniq_dp=False)
    assert off.stats["uniq_dp"] is False
    assert off.stats["uniq_flows"] == 0
    assert off.stats["uniq_s"] == 0.0
    assert off.unreachable == sh.unreachable == 0
    assert V.verify_deadlock_free(at, off.table)
    # the DP is a perf fast lane, not a quality lever
    assert off.l_max <= sh.l_max * 1.05 and sh.l_max <= off.l_max * 1.05


def test_unique_channel_flows_matches_brute_force_enumeration():
    """The kcap=1 fast-lane predicate (all shortest state paths share
    one channel projection) must agree with explicit path enumeration,
    with and without dead channels breaking the torus symmetry."""
    topo = T.pt((4, 4, 4))
    at = R.allowed_turns(topo, n_vc=2, priority="apl")
    sg = R._build_state_graph(at)
    color = F.colors_in_use(topo)[0]
    dead = F.dead_channels_for_color(at, color)
    for dead_set in (None, dead):
        srcs = np.arange(topo.n)
        dist = R.state_bfs(at, srcs, dead_set)
        best = R.node_distances(at, srcs, dist=dist)
        uniq = R._unique_channel_flows(sg, dist, best, topo.n)
        rng = np.random.default_rng(7)
        rows = rng.choice(topo.n, size=8, replace=False)
        for b in rows:
            db = dist[b]
            for d in range(topo.n):
                L = best[b, d]
                if L <= 0:
                    continue
                arrivals = [v for v in np.nonzero(sg.dst_node == d)[0]
                            if db[v] == L]
                projs: set = set()

                def walk(v, lvl, suffix):
                    if len(projs) > 2:
                        return
                    suffix = (int(v) // sg.n_vc,) + suffix
                    if lvl == 1:
                        projs.add(suffix)
                        return
                    for p in sg.rev_pad[v]:
                        if p >= 0 and db[p] == lvl - 1:
                            walk(p, lvl - 1, suffix)

                for v in arrivals:
                    walk(v, L, ())
                assert (len(projs) == 1) == bool(uniq[b, d]), (b, d)


# ---------------------------------------------------------------------------
# CSR PathTable round trip + consumers
# ---------------------------------------------------------------------------


def test_csr_dense_round_trip_bit_identity(pod_routed):
    _, _, arr, sh = pod_routed
    dense = sh.table.to_dense()
    back = CSRPathTable.from_dense(dense)
    for a, b in ((back.src_indptr, sh.table.src_indptr),
                 (back.dst, sh.table.dst),
                 (back.hop_indptr, sh.table.hop_indptr),
                 (back.chan, sh.table.chan), (back.vc, sh.table.vc)):
        np.testing.assert_array_equal(a, b)
    d2 = back.to_dense()
    np.testing.assert_array_equal(d2.path, dense.path)
    np.testing.assert_array_equal(d2.vcs, dense.vcs)
    np.testing.assert_array_equal(d2.hops, dense.hops)
    # statistics parity with the dense layout
    np.testing.assert_array_equal(sh.table.loads(), dense.loads())
    assert sh.table.l_max() == dense.l_max()
    assert abs(sh.table.avg_hops() - dense.avg_hops()) < 1e-12
    assert (sh.table.vc_hop_counts() == dense.vc_hop_counts()).all()
    np.testing.assert_array_equal(sh.table.routed_mask(),
                                  dense.routed_mask())
    np.testing.assert_array_equal(sh.table.hops, dense.hops)
    assert sh.table.as_dicts() == dense.as_dicts()
    # round trip of the array engine's dense table too
    rt = CSRPathTable.from_dense(arr.table).to_dense()
    np.testing.assert_array_equal(rt.path, arr.table.path)
    np.testing.assert_array_equal(rt.vcs, arr.table.vcs)


def test_build_tables_bit_identical_for_csr_and_dense(pod_routed):
    topo, at, _, sh = pod_routed
    t_csr = NS.build_tables(topo, sh.table)
    t_dense = NS.build_tables(topo, sh.table.to_dense())
    # dense views are cached on the side; `table` keeps the CSR layout
    # the simulator kernel consumes natively
    assert isinstance(t_csr.table, CSRPathTable)
    np.testing.assert_array_equal(t_csr.path, t_dense.path)
    np.testing.assert_array_equal(t_csr.vcs, t_dense.vcs)
    np.testing.assert_array_equal(t_csr.hops, t_dense.hops)
    assert isinstance(t_csr.table, CSRPathTable)
    # and the dense table's CSR view round-trips bit-identically
    c2 = t_dense.csr()
    for a, b in ((c2.src_indptr, sh.table.src_indptr),
                 (c2.dst, sh.table.dst), (c2.chan, sh.table.chan)):
        np.testing.assert_array_equal(a, b)


def test_csr_sim_runs_and_conserves_packets(pod_routed):
    topo, at, _, sh = pod_routed
    tab = NS.at_tables(topo, at, sh)
    r = NS.run(tab, 0.02, cycles=600, warmup=200)
    assert r["injected_total"] == r["consumed_total"] + r["in_flight"]
    assert r["delivered"] > 0


# ---------------------------------------------------------------------------
# exact-lookahead VC allocation
# ---------------------------------------------------------------------------


def test_lookahead_vcalloc_identical_on_csr_and_dense(pod_routed):
    topo, at, _, sh = pod_routed
    dense = sh.table.to_dense()
    csr = sh.table.copy()
    s_dense: dict = {}
    s_csr: dict = {}
    c1 = V.allocate_vcs(at, dense, balance=True, stats=s_dense)
    c2 = V.allocate_vcs(at, csr, balance=True, stats=s_csr)
    np.testing.assert_array_equal(c1, c2)
    np.testing.assert_array_equal(csr.to_dense().vcs, dense.vcs)
    assert s_dense["greedy_dead_ends"] == s_csr["greedy_dead_ends"]
    assert V.verify_deadlock_free(at, csr)
    assert V.verify_deadlock_free(at, dense)


def test_lookahead_matches_reference_dfs_per_flow(pod_routed):
    """The vectorised lookahead must return exactly the assignment the
    reference per-flow DFS finds (first solution in priority order)."""
    topo, at, _, sh = pod_routed
    table = sh.table.copy()
    counts = V.allocate_vcs(at, table, balance=False)
    assert counts[0] > counts[1], "naive policy should bias VC0"
    P, Vc, lens = table.block_paths(0, min(table.n_flows, 500))
    for f in range(P.shape[0]):
        path = [int(c) for c in P[f, :lens[f]]]
        ref = V._assign_path(at, path, 0)
        assert ref == [int(v) for v in Vc[f, :lens[f]]], f


def test_fault_correlated_traffic_pattern():
    from repro.core.traffic import TrafficPattern
    topo = T.pt((4, 4, 4))
    at = R.allowed_turns(topo, n_vc=2, priority="apl")
    color = F.colors_in_use(topo)[0]
    region = F.fault_region_nodes(at, color)
    assert len(region) and len(region) < topo.n
    tp = TrafficPattern.fault_correlated(topo.n, region, frac=0.6,
                                         src_boost=2.0)
    m = tp.matrix
    assert (np.diag(m) == 0).all()
    outside = np.setdiff1d(np.arange(topo.n), region)
    src = int(outside[0])
    # 60% of that source's demand lands inside the region
    assert abs(m[src, region].sum() / m[src].sum() - 0.6) < 1e-9
    # impaired sources inject at twice the baseline
    assert np.allclose(tp.src_rate[region], 2.0)
    assert np.allclose(tp.src_rate[outside], 1.0)
    # compiles to alias tables and drives the simulator
    dead = F.dead_channels_for_color(at, color)
    routed = R.select_paths(at, K=4, local_search_rounds=1,
                            dead_channels=dead, engine="sharded")
    tab = NS.at_tables(topo, at, routed)
    r = NS.run(tab, 0.02, cycles=400, warmup=100, traffic=tp)
    assert r["injected_total"] == r["consumed_total"] + r["in_flight"]


@pytest.mark.huge
@pytest.mark.slow          # the fast lane's -m "not slow" overrides the
def test_12cube_routes_end_to_end_sharded():        # "not huge" addopts
    """12^3 smoke (opt-in via ``pytest -m huge``): the sharded engine
    routes 1728 chips end-to-end into a CSR table, deadlock-free."""
    topo = T.pt((12, 12, 12))
    at = R.allowed_turns(topo, n_vc=2, priority="apl")
    sh = R.select_paths(at, K=4, local_search_rounds=2, engine="sharded")
    assert sh.unreachable == 0
    assert sh.table.n_routed() == topo.n * (topo.n - 1)
    tab = NS.at_tables(topo, at, sh)
    assert V.verify_deadlock_free(at, tab.table)
