"""Batched allowed-turns admission engine: exact-set equivalence vs the
serial Pearce-Kelly reference, acyclicity property, and reachability
parity (including robust spanning-tree seeding and a dead-channel fault).

Pods are the smallest constructible ones (dims must be multiples of the
4-chip cube): 4^3 and 4x4x8 stand in for the issue's "3^3 and 4^3"
oracle sizes.
"""
import numpy as np
import pytest

from repro.core import fault as F, routing as R, topology as T


def _kahn_acyclic(at) -> bool:
    """Batched Kahn peel over the emitted allowed set (independent of
    the engine's own structures): acyclic iff every state peels off."""
    n_vc = at.n_vc
    S = at.channels.n * n_vc
    if not at.allowed:
        return True
    e = np.array([(ci * n_vc + v0, co * n_vc + v1)
                  for (ci, v0), (co, v1) in at.allowed], np.int64)
    a, b = e[:, 0], e[:, 1]
    order = np.argsort(a, kind="stable")
    a, b = a[order], b[order]
    indeg = np.bincount(b, minlength=S)
    alive = np.ones(len(a), bool)
    frontier = np.nonzero(indeg == 0)[0]
    indeg[frontier] = -1
    removed = 0
    while len(frontier):
        removed += len(frontier)
        fmask = np.zeros(S, bool)
        fmask[frontier] = True
        m = alive & fmask[a]
        dec = np.bincount(b[m], minlength=S)
        alive[m] = False
        indeg -= dec
        frontier = np.nonzero((indeg == 0) & (dec > 0))[0]
        indeg[frontier] = -1
    return removed == S


CONFIGS = [
    ((4, 4, 4), "apl", False, 2),
    ((4, 4, 4), "apl", True, 2),
    ((4, 4, 4), "random", False, 2),
    ((4, 4, 8), "apl", True, 4),
]


@pytest.fixture(scope="module", params=CONFIGS,
                ids=lambda c: f"{c[0]}-{c[1]}-robust{c[2]}-vc{c[3]}")
def engine_pair(request):
    spec, priority, robust, n_vc = request.param
    topo = T.pt(spec)
    bat = R.allowed_turns(topo, n_vc=n_vc, priority=priority,
                          robust=robust, at_engine="batched")
    ref = R.allowed_turns(topo, n_vc=n_vc, priority=priority,
                          robust=robust, at_engine="reference")
    return topo, bat, ref


def test_exact_set_equivalence(engine_pair):
    """The batched engine replays the serial greedy bit for bit."""
    topo, bat, ref = engine_pair
    assert bat.allowed == ref.allowed
    assert bat.trees == ref.trees
    # the packed edge array matches the set exactly
    n_vc = bat.n_vc
    from_edges = {((int(u) // n_vc, int(u) % n_vc),
                   (int(v) // n_vc, int(v) % n_vc))
                  for u, v in bat._edges}
    assert from_edges == bat.allowed


def test_emitted_set_is_acyclic(engine_pair):
    _, bat, _ = engine_pair
    assert _kahn_acyclic(bat)


def test_reachability_matches_reference(engine_pair):
    """Identical allowed sets must also yield identical deadlock-free
    distances through the array BFS front-end (the oracle the issue's
    acceptance criterion names)."""
    topo, bat, ref = engine_pair
    srcs = np.arange(0, topo.n, 3)
    np.testing.assert_array_equal(R.node_distances(bat, srcs),
                                  R.node_distances(ref, srcs))


def test_reachability_matches_reference_under_fault(engine_pair):
    topo, bat, ref = engine_pair
    color = F.colors_in_use(topo)[0]
    dead = F.dead_channels_for_color(bat, color)
    srcs = np.arange(0, topo.n, 5)
    db = R.node_distances(bat, srcs, dead_channels=dead)
    dr = R.node_distances(ref, srcs, dead_channels=dead)
    np.testing.assert_array_equal(db, dr)


def test_select_paths_identical_across_at_engines():
    """Same allowed set + canonical StateGraph compilation => the whole
    selection pipeline is bit-identical regardless of the AT engine."""
    topo = T.pt((4, 4, 4))
    bat = R.allowed_turns(topo, n_vc=2, priority="apl")
    ref = R.allowed_turns(topo, n_vc=2, priority="apl",
                          at_engine="reference")
    rb = R.select_paths(bat, K=4, local_search_rounds=1)
    rr = R.select_paths(ref, K=4, local_search_rounds=1)
    np.testing.assert_array_equal(rb.table.path, rr.table.path)
    np.testing.assert_array_equal(rb.table.vcs, rr.table.vcs)
    assert rb.l_max == rr.l_max


def test_cpl_chosen_loads_equivalence():
    """The CPL re-prioritisation path (dict-driven ordering) goes
    through the same shared permutation in both engines."""
    topo = T.pt((4, 4, 4))
    at = R.allowed_turns(topo, n_vc=2, priority="apl")
    routed = R.select_paths(at, K=2, local_search_rounds=0)
    freq = R.turn_frequencies(routed.table)
    bat = R.allowed_turns(topo, n_vc=2, chosen_loads=freq)
    ref = R.allowed_turns(topo, n_vc=2, chosen_loads=freq,
                          at_engine="reference")
    assert bat.allowed == ref.allowed


def test_batched_engine_reports_stats():
    topo = T.pt((4, 4, 4))
    at = R.allowed_turns(topo, n_vc=2, priority="apl")
    s = at.stats
    assert s["engine"] == "batched"
    assert s["blocks"] == len(s["admitted_per_block"])
    assert sum(s["admitted_per_block"]) == len(at.allowed)
    admitted = s["fwd_bulk"] + s["contested_bulk"] + s["tangle_commits"]
    assert admitted == len(at.allowed)
    assert s["bfs_rows"] > 0          # backward minority was classified


def test_vectorized_turn_builders_match_dict_loops():
    """base_turns / _tree_turns CSR vectorisation is order-exact vs the
    seed's dict-loop construction."""
    from collections import defaultdict
    topo = T.pt((4, 4, 8))
    ch = R.Channels.from_topology(topo)
    # seed base_turns, verbatim
    out_by_node = defaultdict(list)
    for c in range(ch.n):
        out_by_node[int(ch.src[c])].append(c)
    seed_turns = []
    for cin in range(ch.n):
        mid = int(ch.dst[cin])
        for cout in out_by_node[mid]:
            if int(ch.dst[cout]) != int(ch.src[cin]):
                seed_turns.append((cin, cout))
    assert R.base_turns(ch) == seed_turns
    # seed _tree_turns, verbatim
    t0, _ = R.spanning_tree_channels(topo, ch, 0)
    by_node = defaultdict(list)
    for c in t0:
        by_node[int(ch.dst[c])].append(c)
    outn = defaultdict(list)
    for c in t0:
        outn[int(ch.src[c])].append(c)
    seed_tree = []
    for mid, ins in by_node.items():
        for cin in ins:
            for cout in outn.get(mid, []):
                if ch.dst[cout] != ch.src[cin]:
                    seed_tree.append((cin, cout))
    assert R._tree_turns(t0, ch) == seed_tree


def test_channels_cached_on_topology():
    topo = T.pt((4, 4, 4))
    ch1 = R.Channels.from_topology(topo)
    ch2 = R.Channels.from_topology(topo)
    assert ch1 is ch2                 # rebuilt once, reused by re-routes
    assert T.pt((4, 4, 4)).__dict__.get("_channels") is None
