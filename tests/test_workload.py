"""Workload co-design tests: demand derivation, multi-tenant traffic,
trace replay, and the synthesis hookup.

Covers the PR-10 acceptance surface:

- `demand.from_dryrun` / `workload.collective_mix` on >= 2 registered
  model configs (MoE vs dense -> genuinely different demand mixes),
  weight symmetry and shape handling (train / decode);
- unrouted-pair behaviour: zero-weight pairs don't break the weighted
  MCF and the demand matrix renormalises into valid alias tables;
- two-tenant composition through the CSR sim kernel with *exact*
  per-tenant packet conservation, bit-identical to the dense oracle;
- phased trace replay: a single-phase schedule is bit-identical to its
  stationary pattern, multi-phase stays CSR==dense;
- (huge) a workload-specialized synthesis smoke for the nightly lane.
"""
import json

import numpy as np
import pytest

from repro.core import demand as D, netsim as NS, topology as T
from repro.core import workload as W
from repro.core.pipeline import PipelineConfig, route_pod
from repro.core.traffic import (PhasedTraffic, TenantSpec, TrafficPattern,
                                compose_tenants)

MOE_ARCH = "deepseek-moe-16b"
DENSE_ARCH = "gemma-7b"


# ---------------------------------------------------------------------------
# demand derivation: analytic mix + dry-run JSONs
# ---------------------------------------------------------------------------


def test_collective_mix_moe_vs_dense():
    from repro.configs.registry import get_config, get_shape
    shape = get_shape("train_4k")
    moe = W.collective_mix(get_config(MOE_ARCH).model, shape)
    dense = W.collective_mix(get_config(DENSE_ARCH).model, shape)
    assert moe["all-to-all"] > 0          # MoE dispatch+combine
    assert dense["all-to-all"] == 0.0     # no expert routing
    for mix in (moe, dense):
        assert mix["all-reduce"] > 0      # train: DP gradient sync
        assert mix["all-gather"] > 0 and mix["reduce-scatter"] > 0


def test_collective_mix_decode_drops_gradient_sync():
    from repro.configs.base import ShapeConfig
    from repro.configs.registry import get_config
    decode = ShapeConfig("decode_1", seq_len=4096, global_batch=64,
                         kind="decode")
    mix = W.collective_mix(get_config(DENSE_ARCH).model, decode)
    assert mix["all-reduce"] == 0.0
    # token-proportional terms collapse to one token per sequence
    assert 0 < mix["all-gather"] < 4096 * 64 * 1e6


def test_workload_demand_differentiates_archs():
    """MoE -> same-cube (all-to-all) heavy; dense -> ring heavy."""
    wd_moe = W.workload_demand((4, 4, 8), MOE_ARCH)
    wd_dense = W.workload_demand((4, 4, 8), DENSE_ARCH)
    assert wd_moe.w_same_cube > wd_moe.w_ring
    assert wd_dense.w_ring > wd_dense.w_same_cube
    assert wd_dense.w_same_cube == 0.0


@pytest.mark.parametrize("arch,heavy", [(MOE_ARCH, "all-to-all"),
                                        (DENSE_ARCH, "all-reduce")])
def test_from_dryrun_json_roundtrip(tmp_path, arch, heavy):
    """A measured dry-run JSON feeds the same mapping as the analytic
    mix: whichever collective dominates the wire bytes dominates the
    demand weights."""
    wires = {"all-to-all": 0.0, "all-reduce": 0.0,
             "all-gather": 1e9, "reduce-scatter": 1e9}
    wires[heavy] = 64e9
    (tmp_path / f"{arch}__train_4k__single_pod_16x16.json").write_text(
        json.dumps({"collectives": {
            k: {"wire_bytes": v} for k, v in wires.items()}}))
    wd = D.from_dryrun((4, 4, 8), arch, "train_4k",
                       dryrun_dir=str(tmp_path))
    if heavy == "all-to-all":
        assert wd.w_same_cube > wd.w_ring > 0
    else:
        assert wd.w_ring > wd.w_same_cube
    # workload_demand prefers the measured JSON over the analytic mix
    wd2 = W.workload_demand((4, 4, 8), arch, dryrun_dir=str(tmp_path))
    assert (wd2.w_same_cube, wd2.w_ring) == (wd.w_same_cube, wd.w_ring)


def test_from_dryrun_missing_file_falls_back_uniform(tmp_path):
    wd = D.from_dryrun((4, 4, 8), "no-such-arch", "train_4k",
                       dryrun_dir=str(tmp_path))
    assert wd.w_same_cube == 0.0 and wd.w_ring == 0.0
    assert wd.w_uniform == 1.0


def test_workload_weight_symmetry():
    """w(a, b) == w(b, a): both same-cube membership and the +-1 cube
    ring test are symmetric, so the synthesis LP's symmetric orbit
    reductions stay valid."""
    for arch in (MOE_ARCH, DENSE_ARCH):
        m = W.workload_demand((4, 4, 8), arch).matrix()
        np.testing.assert_allclose(m, m.T)


def test_zero_uniform_demand_still_routes():
    """Unrouted-pair handling: with w_uniform=0 most pairs carry zero
    demand; the alias compilation renormalises the live rows and the
    weighted MCF stays finite and positive."""
    pod = T.Pod((4, 4, 8))
    wd = D.WorkloadDemand(pod, w_same_cube=4.0, w_ring=0.0, w_uniform=0.0)
    tp = TrafficPattern.from_demand(wd)
    probs = tp.compiled().row_probs()
    live = probs.sum(axis=1) > 0
    assert live.all()                      # every source has a target
    np.testing.assert_allclose(probs[live].sum(axis=1), 1.0, atol=1e-6)
    # zero-weight (cross-cube) pairs draw zero probability
    assert probs[0, -1] == 0.0
    lam = D.weighted_mcf(T.pt((4, 4, 8)), wd)
    assert np.isfinite(lam) and lam > 0


def test_demand_pair_weight_quantization():
    """The routing multiplicities are capped integers >= 1 with the
    smallest positive demand level mapped to 1, and trace replay
    durations follow per-node volume, not raw weight levels."""
    wd = W.workload_demand((4, 4, 8), DENSE_ARCH)     # ring-heavy
    pw = W.demand_pair_weight(wd, cap=64)
    assert pw.shape == (128, 128)
    assert pw.min() == 1.0                 # zero-demand pairs still route
    assert pw.max() <= 64.0
    np.testing.assert_allclose(pw, np.rint(pw))       # integers
    m = wd.matrix()
    assert pw[m == m[m > 0].min()].min() == 1.0
    # uniform floor touches ~127 partners vs one ring partner: the
    # background phase must get the larger share of the replay period
    tr = W.replay_trace(wd)
    by_name = dict(zip([p.name for p in tr.patterns], tr.cycles))
    assert by_name["background"] > by_name["ring"]


# ---------------------------------------------------------------------------
# multi-tenant composition through the sim kernels
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_tables():
    topo = T.pt((4, 4, 4))
    return topo, route_pod(topo, PipelineConfig(
        K=4, local_search_rounds=1, engine="sharded")).tables


def _two_tenants(n, overlap):
    rng = np.random.default_rng(0)
    a_nodes = np.arange(0, n // 2)
    b0 = n // 2 - (8 if overlap else 0)
    b_nodes = np.arange(b0, min(n, b0 + n // 2))
    mk = lambda m: rng.random((m, m))
    return [TenantSpec("jobA", a_nodes, mk(len(a_nodes)), 1.0),
            TenantSpec("jobB", b_nodes, mk(len(b_nodes)), 0.5)]


@pytest.mark.parametrize("overlap", [False, True])
def test_two_tenant_exact_conservation(small_tables, overlap):
    topo, tab = small_tables
    tp = compose_tenants(topo.n, _two_tenants(topo.n, overlap))
    assert tp.tenants is not None and tp.tenants.n_tenants == 2
    out = {}
    for kernel in ("csr", "dense"):
        tr = NS.sweep(tab, [0.05, 0.2], traffic=tp, cycles=800,
                      warmup=200, kernel=kernel)
        for r in tr:
            tens = r["tenants"]
            assert set(tens) == {"jobA", "jobB"}
            tot_inj = tot_con = tot_fly = 0
            for t in tens.values():
                # the acceptance bar: exact per-tenant conservation
                assert t["injected"] == t["consumed"] + t["in_flight"]
                tot_inj += t["injected"]
                tot_con += t["consumed"]
                tot_fly += t["in_flight"]
            assert tot_inj == r["injected_total"]
            assert tot_con == r["consumed_total"]
            assert tot_fly == r["in_flight"]
        out[kernel] = tr
    assert out["csr"] == out["dense"]     # tenant counters bit-identical


def test_workload_tenant_slices_demand(small_tables):
    topo, tab = small_tables
    nodes_a = list(range(0, 32))
    nodes_b = list(range(32, 64))
    ta = W.workload_tenant("moe", topo.pod.dims, nodes_a, MOE_ARCH)
    tb = W.workload_tenant("dense", topo.pod.dims, nodes_b, DENSE_ARCH,
                           rate_share=0.5)
    assert ta.matrix.shape == (32, 32)
    tp = compose_tenants(topo.n, [ta, tb])
    r = NS.sweep(tab, [0.1], traffic=tp, cycles=600, warmup=200)[0]
    for t in r["tenants"].values():
        assert t["injected"] == t["consumed"] + t["in_flight"]
        assert t["injected"] > 0


# ---------------------------------------------------------------------------
# trace replay (phased demand)
# ---------------------------------------------------------------------------


def test_single_phase_bit_identical_to_stationary(small_tables):
    topo, tab = small_tables
    tp = TrafficPattern.hotspot(topo.n, frac=0.4)
    ph = PhasedTraffic("one", (tp,), (128,))
    for kernel in ("csr", "dense"):
        a = NS.sweep(tab, [0.05, 0.3], traffic=tp, cycles=700,
                     warmup=300, kernel=kernel)
        b = NS.sweep(tab, [0.05, 0.3], traffic=ph, cycles=700,
                     warmup=300, kernel=kernel)
        assert a == b


def test_multi_phase_csr_dense_parity(small_tables):
    topo, tab = small_tables
    wd = D.WorkloadDemand(topo.pod, w_same_cube=3.0, w_ring=1.0,
                          w_uniform=0.25)
    ph = W.replay_trace(wd, period=96)
    assert isinstance(ph, PhasedTraffic) and len(ph.patterns) == 3
    assert ph.period >= 96
    a = NS.sweep(tab, [0.1, 0.4], traffic=ph, cycles=900, warmup=300,
                 kernel="csr")
    b = NS.sweep(tab, [0.1, 0.4], traffic=ph, cycles=900, warmup=300,
                 kernel="dense")
    assert a == b
    for r in a:
        assert r["injected_total"] == r["consumed_total"] + r["in_flight"]


def test_from_trace_accumulates_pairs():
    tp = TrafficPattern.from_trace(8, [(0, 1, 2), (0, 1, 3), (2, 5, 1)])
    assert tp.matrix[0, 1] == 5.0 and tp.matrix[2, 5] == 1.0
    assert tp.matrix.sum() == 6.0


# ---------------------------------------------------------------------------
# synthesis hookup (nightly smoke: the full workload->fabric loop)
# ---------------------------------------------------------------------------


@pytest.mark.huge
def test_workload_specialized_synthesis_smoke():
    """synthesize_for_workload end to end on one cube: the specialized
    fabric must score at least as well on its own workload's weighted
    MCF as the demand-blind torus."""
    res, wd = W.synthesize_for_workload((4, 4, 4), MOE_ARCH,
                                        interval=48)
    topo = res.topology
    assert topo.n == 64
    ev = W.evaluate_workload(
        topo, wd, cfg=PipelineConfig(K=4, local_search_rounds=1),
        sat_kwargs=dict(step=0.05, cycles=800, warmup=300))
    assert ev["weighted_mcf"] > 0 and ev["trace_saturation"] > 0
    pt = W.evaluate_workload(
        T.pt((4, 4, 4)), wd,
        cfg=PipelineConfig(K=4, local_search_rounds=1),
        sat_kwargs=dict(step=0.05, cycles=800, warmup=300))
    assert ev["weighted_mcf"] >= 0.9 * pt["weighted_mcf"]
