"""Data / optimizer / checkpoint / LP substrate tests (incl. hypothesis)."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.checkpoint.manager import CheckpointManager
from repro.core.lp import COOMatrix, solve_highs, solve_pdhg
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.optim import adamw
from repro.train.loop import compress_grads, dequantize_int8, quantize_int8


def test_data_determinism_and_sharding():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=8)
    ds = SyntheticLM(cfg)
    b1 = ds.batch(5)
    b2 = ds.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = ds.batch(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    full1 = np.concatenate([b1["tokens"][:, :1], b1["labels"]], axis=1)
    np.testing.assert_array_equal(full1[:, 1:], b1["labels"])
    s0 = ds.batch(5, shard=0, n_shards=2)
    s1 = ds.batch(5, shard=1, n_shards=2)
    assert s0["tokens"].shape[0] == 4
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_adamw_decreases_quadratic():
    cfg = adamw.OptConfig(lr=0.1, warmup_steps=0, total_steps=100,
                          weight_decay=0.0)
    params = {"w": jnp.ones((4,)) * 3.0}
    state = adamw.init(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw.update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_checkpoint_roundtrip_and_retention():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        state = {"a": jnp.arange(6).reshape(2, 3),
                 "b": [jnp.ones(4), jnp.zeros(2)]}
        for s in (10, 20, 30):
            mgr.save(s, state, blocking=True)
        assert mgr.all_steps() == [20, 30]
        like = jax.tree.map(lambda a: jnp.zeros_like(a), state)
        rest = mgr.restore(30, like)
        np.testing.assert_array_equal(np.asarray(rest["a"]),
                                      np.asarray(state["a"]))


def test_checkpoint_async_then_wait():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, {"x": jnp.ones(8)})
        mgr.wait()
        assert mgr.latest_step() == 1


@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=1,
                max_size=64))
@settings(max_examples=50, deadline=None)
def test_int8_quantization_error_bound(xs):
    g = jnp.asarray(np.array(xs, np.float32))
    q, s = quantize_int8(g)
    back = dequantize_int8(q, s)
    # error bounded by half a quantisation step
    assert float(jnp.abs(back - g).max()) <= float(s) * 0.5 + 1e-6


def test_pdhg_matches_highs_small_random():
    rng = np.random.default_rng(0)
    for trial in range(3):
        m, n = 30, 20
        A_d = rng.normal(size=(m, n))
        rows, cols = np.nonzero(np.abs(A_d) > 0.7)
        vals = A_d[rows, cols]
        A = COOMatrix.from_triplets(rows, cols, vals, (m, n))
        c = rng.normal(size=n)
        x_feas = rng.uniform(0, 1, n)
        b = A.to_scipy() @ x_feas + rng.uniform(0.1, 1.0, m)
        lo, hi = np.zeros(n), np.ones(n)
        r1 = solve_highs(c, A, b, lo, hi)
        r2 = solve_pdhg(c, A, b, lo, hi, max_iters=20000, tol=1e-6)
        assert abs(r1.obj - r2.obj) < 1e-3 * (1 + abs(r1.obj)), trial


def test_grad_compression_preserves_training_signal():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(
        size=(64,)).astype(np.float32))}
    gc = compress_grads(g)
    cos = float(jnp.dot(g["w"], gc["w"]) /
                (jnp.linalg.norm(g["w"]) * jnp.linalg.norm(gc["w"])))
    assert cos > 0.999
