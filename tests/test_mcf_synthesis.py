"""MCF evaluator + TONS synthesis formulation correctness."""
import numpy as np
import pytest

from repro.core import topology as T
from repro.core.lp import COOMatrix, solve_highs
from repro.core.mcf import PairCanon, build_metric_lp, mcf_uniform


def test_pt_appendix_c_values():
    """Exact reproduction of the paper's Appendix C PT rows."""
    for spec, mcf, diam, hops in [((4, 4, 8), 0.0078125, 8, 4.032),
                                  ((4, 8, 8), 0.00390625, 10, 5.020)]:
        topo = T.pt(spec)
        perms = T.torus_translations(topo.pod)
        lam, res = mcf_uniform(topo.edges(), topo.n, perms=perms,
                               prefer="highs")
        assert res.status == "optimal"
        assert abs(lam - mcf) < 1e-6
        d, h = T.diameter_avg_hops(topo)
        assert d == diam
        assert abs(h - hops) < 0.01


def test_pdtt_appendix_c_value():
    topo = T.pdtt((4, 4, 8))
    perms = T.torus_translations(topo.pod, twisted=True)
    lam, res = mcf_uniform(topo.edges(), topo.n, perms=perms,
                           prefer="highs")
    assert abs(lam - 0.01364) < 2e-5


def test_radix_is_six():
    for make in (T.pt, T.pdtt, lambda s: T.random_topology(s, seed=3)):
        topo = make((4, 4, 8))
        deg = np.zeros(topo.n, int)
        for u, v in topo.edges():
            deg[u] += 1
            deg[v] += 1
        assert (deg == 6).all(), make


@pytest.mark.slow
def test_symmetry_reduction_preserves_mcf():
    """Cube-translation-reduced LP == unreduced LP on a small pod."""
    topo = T.pt((4, 4, 8))
    perms = T.cube_translations(topo.pod)
    lam_sym, _ = mcf_uniform(topo.edges(), topo.n, perms=perms,
                             prefer="highs")
    assert abs(lam_sym - 0.0078125) < 1e-6


def test_one_leg_equals_full_triangles():
    """Appendix A: one-leg restricted metric LP has the same optimum as
    the full triangle set (random small graphs)."""
    rng = np.random.default_rng(0)
    for trial in range(3):
        n = 8
        # random connected graph
        edges = set()
        perm = rng.permutation(n)
        for i in range(1, n):
            edges.add(tuple(sorted((int(perm[i - 1]), int(perm[i])))))
        while len(edges) < 14:
            u, v = rng.integers(0, n, 2)
            if u != v:
                edges.add(tuple(sorted((int(u), int(v)))))
        edges = np.array(sorted(edges))

        lam_ol, _ = mcf_uniform(edges, n, perms=None, prefer="highs")

        # full-triangle variant: build manually
        pairs = [(i, j) for i in range(n) for j in range(i + 1, n)]
        pidx = {p: i for i, p in enumerate(pairs)}

        def vid(a, b):
            return pidx[(min(a, b), max(a, b))]

        rows, cols, vals, b = [], [], [], []
        for p in pairs:
            rows.append(0)
            cols.append(pidx[p])
            vals.append(-1.0)
        b.append(-1.0)
        r = 1
        for i in range(n):
            for j in range(n):
                for k in range(n):
                    if len({i, j, k}) < 3:
                        continue
                    rows += [r, r, r]
                    cols += [vid(i, j), vid(i, k), vid(k, j)]
                    vals += [1.0, -1.0, -1.0]
                    b.append(0.0)
                    r += 1
        A = COOMatrix.from_triplets(rows, cols, vals, (r, len(pairs)))
        c = np.zeros(len(pairs))
        for u, v in edges:
            c[vid(int(u), int(v))] += 1.0
        res = solve_highs(c, A, np.array(b), np.zeros(len(pairs)),
                          np.ones(len(pairs)))
        assert abs(res.obj - lam_ol) < 1e-6, trial


def test_paircanon_consistency():
    """key(a,b) must be invariant under applying any group element."""
    pod = T.Pod((4, 4, 8))
    perms = T.cube_translations(pod)
    pc = PairCanon(perms, pod.n)
    rng = np.random.default_rng(1)
    a = rng.integers(0, pod.n, 50)
    b = rng.integers(0, pod.n, 50)
    k0 = pc.key(a, b)
    for g in range(len(perms)):
        kg = pc.key(perms[g][a], perms[g][b])
        assert (k0 == kg).all()
    # undirected: symmetric
    assert (pc.key(b, a) == k0).all()


@pytest.mark.slow
def test_duality_fixed_pt_topology():
    """TONS dual LP with m fixed to the PT matching == exact MCF(PT)."""
    from repro.core import synthesis as SY
    pod = T.Pod((4, 4, 8))
    lp = SY.build_synthesis_lp(pod, symmetric=True)
    pt_edges = set((u, v) for u, v, _ in T.pt_optical(pod))
    lo, hi = lp.lo.copy(), lp.hi.copy()
    for oi, members in enumerate(lp.orbit_members):
        is_pt = all((u, v) in pt_edges for (u, v, _) in members)
        lo[lp.m_slice][oi] = hi[lp.m_slice][oi] = 1.0 if is_pt else 0.0
    res = solve_highs(lp.c, lp.A, lp.b, lo, hi, method="highs-ipm")
    assert abs(-res.obj - 0.0078125) < 1e-4


def test_directed_synthesis_matches_genkautz_small():
    from repro.core import smallgraphs as SG
    n, r = 10, 4
    gk = SG.gen_kautz(n, r)
    lam_gk = SG.directed_mcf(gk, n)
    edges, _ = SG.synthesize_directed(n, r, interval=1)
    lam_t = SG.directed_mcf(edges, n)
    # paper Fig. 1: synthesis ties or beats reference constructions
    assert lam_t >= lam_gk - 1e-6


def test_valid_pairs_respect_ocs_groups():
    pod = T.Pod((4, 4, 8))
    groups = T.ocs_groups(pod)
    port_color = {}
    for color, plist in groups.items():
        for p in plist:
            port_color[(p.chip, p.axis)] = color
    for u, v, c in T.valid_optical_pairs(pod):
        au = [a for a in range(3)
              if (u, a) in port_color and port_color[(u, a)] == c]
        av = [a for a in range(3)
              if (v, a) in port_color and port_color[(v, a)] == c]
        assert au and av, "edge endpoints must own ports of its color"
