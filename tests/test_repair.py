"""Equivalence suite for the incremental fault-repair pipeline
(`repro.core.repair`): the repaired state must be reachability- and
deadlock-equivalent to a full recompute on the faulted fabric, repaired
paths must avoid every dead channel, untouched flows must stay
byte-identical, and repair quality (post-repair l_max) must stay within
1.10x of the full-recompute oracle. Also covers the delta-admission
exactness (readmitted set stays acyclic), repair-after-repair chains,
and the full-recompute fallback on genuine disconnection."""
import numpy as np
import pytest
import scipy.sparse as sp
from scipy.sparse.csgraph import connected_components

from repro.core import fault as F, routing as R, topology as T, \
    vcalloc as V
from repro.core.repair import (ServingState, _pruned_at, _readmit,
                               full_recompute, repair_fault,
                               restore_channels)

L_MAX_BOUND = 1.10


@pytest.fixture(scope="module")
def served():
    topo = T.pdtt((4, 4, 4))
    state = ServingState.build(topo, n_vc=4, K=8, seed=0, robust=True)
    return topo, state


def _dead_mask(state, dead):
    m = np.zeros(state.at.channels.n, bool)
    m[np.asarray(dead, np.int64)] = True
    return m


# ---------------------------------------------------------------------------
# single-OCS repair: the headline contract
# ---------------------------------------------------------------------------


def test_single_ocs_repair_full_contract(served):
    topo, st = served
    color = F.colors_in_use(topo)[0]
    dead = F.dead_channels_for_color(st.at, color)
    rr = repair_fault(st, dead, verify="full")
    assert rr.flows_rerouted > 0
    assert rr.unreachable == 0
    assert rr.deadlock_free
    assert not rr.fallback
    new = rr.state.table
    # every repaired path avoids every dead channel
    assert not _dead_mask(st, dead)[new.chan].any()
    # still one flow per (s, d) pair at full reachability
    assert new.n_routed() == topo.n * (topo.n - 1)
    # the carried load / VC-count vectors match the table exactly
    np.testing.assert_array_equal(rr.state.loads[:-1],
                                  new.loads().astype(np.int64))
    np.testing.assert_array_equal(rr.state.vc_counts,
                                  new.vc_hop_counts())
    # full deadlock-freedom check over the repaired state graph
    assert V.verify_deadlock_free(rr.state.at, new)
    # quality: within the bound of the full-recompute oracle
    routed, _, _ = full_recompute(st, dead)
    assert routed.unreachable == 0
    assert rr.l_max <= routed.l_max * L_MAX_BOUND, (rr.l_max, routed.l_max)
    # the input state was not mutated
    assert len(st.dead) == 0
    np.testing.assert_array_equal(st.loads[:-1],
                                  st.table.loads().astype(np.int64))


def test_untouched_flows_bit_identical(served):
    topo, st = served
    color = F.colors_in_use(topo)[1]
    dead = F.dead_channels_for_color(st.at, color)
    rr = repair_fault(st, dead)
    old, new = st.table, rr.state.table
    F_ = old.n_flows
    foh = np.repeat(np.arange(F_), old.flow_len)
    pool = np.unique(foh[_dead_mask(st, dead)[old.chan]])
    untouched = np.setdiff1d(np.arange(F_), pool)
    assert len(pool) == rr.flows_rerouted
    P1, V1, L1 = old.gather_paths(untouched)
    P2, V2, L2 = new.gather_paths(untouched)
    W = max(P1.shape[1], P2.shape[1])
    np.testing.assert_array_equal(L1, L2)
    np.testing.assert_array_equal(P1, P2[:, :P1.shape[1]])
    np.testing.assert_array_equal(V1, V2[:, :V1.shape[1]])
    del W


def test_reachability_equivalent_to_fresh_at_on_faulted_topology(served):
    topo, st = served
    color = F.colors_in_use(topo)[0]
    dead = F.dead_channels_for_color(st.at, color)
    rr = repair_fault(st, dead)
    # fresh cold build on the faulted fabric (channel ids differ; node
    # reachability is the invariant)
    faulted = T.Topology(topo.pod,
                         [e for e in topo.optical if e[2] != color])
    fresh = R.allowed_turns(faulted, n_vc=4, robust=True, seed=0)
    srcs = np.arange(topo.n)
    best_fresh = R.node_distances(fresh, srcs)
    best_rep = R.node_distances(rr.state.at, srcs, dead_channels=dead)
    np.testing.assert_array_equal(best_rep >= 0, best_fresh >= 0)
    # robust AT: the faulted fabric stays fully reachable both ways
    assert (best_fresh >= 0).all()


def test_pruned_allowed_set_drops_exactly_dead_turns(served):
    topo, st = served
    color = F.colors_in_use(topo)[2]
    dead = F.dead_channels_for_color(st.at, color)
    dm = _dead_mask(st, dead)
    at2 = _pruned_at(st.at, dm)
    n_vc = st.at.n_vc
    e_old = st.at._edges
    e_new = at2._edges
    dead_edge = dm[e_old[:, 0] // n_vc] | dm[e_old[:, 1] // n_vc]
    # pruning keeps exactly the surviving edges, in canonical content
    keys_old = set(map(tuple, e_old[~dead_edge].tolist()))
    keys_new = set(map(tuple, e_new.tolist()))
    assert keys_old == keys_new
    # and the lazy allowed view matches the reference representation
    sub = {k for k in st.at.allowed
           if not (dm[k[0][0]] or dm[k[1][0]])}
    assert set(at2.allowed) == sub


def test_readmitted_set_stays_acyclic_and_dead_free(served):
    topo, st = served
    ch = st.at.channels
    rng = np.random.default_rng(0)
    pick = rng.choice(np.nonzero(ch.color < 0)[0], size=40, replace=False)
    dead = np.unique(np.concatenate([pick, ch.rev[pick]]))
    dm = _dead_mask(st, dead)
    at2 = _pruned_at(st.at, dm)
    n = _readmit(at2)
    assert n > 0, "heavy electrical pruning should leave room to readmit"
    e = at2._edges
    n_vc = st.at.n_vc
    assert not (dm[e[:, 0] // n_vc] | dm[e[:, 1] // n_vc]).any()
    S = ch.n * n_vc
    m = sp.csr_matrix((np.ones(len(e), np.int8), (e[:, 0], e[:, 1])),
                      shape=(S, S))
    _, labels = connected_components(m, directed=True, connection="strong")
    assert np.bincount(labels).max() == 1, \
        "readmitted allowed set must stay a DAG"


# ---------------------------------------------------------------------------
# repair after repair, fallback, no-op
# ---------------------------------------------------------------------------


def test_multi_fault_sequence_repair_after_repair(served):
    topo, st = served
    cur = st
    killed: list = []
    for color in F.colors_in_use(topo)[:3]:
        dead = F.dead_channels_for_color(cur.at, color)
        rr = repair_fault(cur, dead, verify="full")
        killed.extend(np.asarray(dead).tolist())
        assert rr.unreachable == 0
        assert rr.deadlock_free
        cur = rr.state
        np.testing.assert_array_equal(cur.dead, np.unique(killed))
        dm = np.zeros(cur.at.channels.n, bool)
        dm[cur.dead] = True
        assert not dm[cur.table.chan].any()
        assert V.verify_deadlock_free(cur.at, cur.table)
        np.testing.assert_array_equal(cur.loads[:-1],
                                      cur.table.loads().astype(np.int64))
        np.testing.assert_array_equal(cur.vc_counts,
                                      cur.table.vc_hop_counts())


def test_fallback_full_recompute_on_disconnection(served):
    # legacy opt-in: on_disconnect="recompute" falls back to a cold
    # rebuild over the reachable pairs (renumbering flows)
    topo, st = served
    ch = st.at.channels
    dead = np.nonzero((ch.src == 0) | (ch.dst == 0))[0].astype(np.int64)
    rr = repair_fault(st, dead, verify="full", on_disconnect="recompute")
    assert rr.fallback
    # node 0 is gone: exactly its flows are unreachable
    assert rr.unreachable == 2 * (topo.n - 1)
    assert rr.deadlock_free
    assert not _dead_mask(st, dead)[rr.state.table.chan].any()


def test_degraded_mode_default_on_disconnection(served):
    # the default now serves degraded: no cold recompute, flow ids keep
    # their slots (lost pairs become zero-length entries), and a
    # restore of the killed channels recovers every pair exactly
    topo, st = served
    ch = st.at.channels
    dead = np.nonzero((ch.src == 0) | (ch.dst == 0))[0].astype(np.int64)
    rr = repair_fault(st, dead, verify="full")
    assert not rr.fallback
    assert rr.unreachable == 2 * (topo.n - 1)
    assert rr.lost == 2 * (topo.n - 1)
    assert rr.deadlock_free
    new = rr.state
    assert new.table.n_flows == st.table.n_flows      # slots survive
    np.testing.assert_array_equal(
        np.sort(new.lost), np.nonzero(new.table.flow_len == 0)[0])
    # lost pairs are exactly node 0's flows
    assert ((new.table.flow_src[new.lost] == 0)
            | (new.table.dst[new.lost] == 0)).all()
    assert new.served_fraction == pytest.approx(
        1.0 - rr.lost / st.table.n_flows)
    np.testing.assert_array_equal(new.loads[:-1],
                                  new.table.loads().astype(np.int64))
    assert not _dead_mask(st, dead)[new.table.chan].any()
    # heal: restoring the channels recovers full reachability
    heal = restore_channels(new, dead, verify="full")
    assert heal.restored == len(dead)
    assert len(heal.state.lost) == 0
    assert heal.state.table.n_routed() == topo.n * (topo.n - 1)
    assert heal.l_max <= st.l_max * L_MAX_BOUND


def test_noop_repair_on_empty_fault(served):
    topo, st = served
    rr = repair_fault(st, np.zeros(0, np.int64))
    assert rr.flows_rerouted == 0
    assert rr.unreachable == 0
    assert rr.deadlock_free
    np.testing.assert_array_equal(rr.state.table.chan, st.table.chan)
    np.testing.assert_array_equal(rr.state.loads, st.loads)


# ---------------------------------------------------------------------------
# fault.py integration
# ---------------------------------------------------------------------------


def test_dead_channels_for_color_is_sorted_array_and_cached(served):
    topo, st = served
    ch = st.at.channels
    for color in F.colors_in_use(topo)[:4]:
        dead = F.dead_channels_for_color(st.at, color)
        assert isinstance(dead, np.ndarray) and dead.dtype == np.int64
        assert (np.diff(dead) > 0).all()
        np.testing.assert_array_equal(
            dead, np.nonzero(ch.color == color)[0])
    assert "_color_csr" in ch.__dict__


def test_fault_sweep_repair_mode(served):
    topo, st = served
    sweep = F.fault_sweep(topo, st.at, repair_from=st)
    assert len(sweep) == len(F.colors_in_use(topo))
    for entry in sweep:
        assert entry.repair is not None
        assert entry.connected
        assert entry.repair.unreachable == 0
        assert entry.repair.deadlock_free
        dead = F.dead_channels_for_color(st.at, entry.color)
        assert not _dead_mask(st, dead)[entry.routed.table.chan].any()
        assert entry.routed.l_max == entry.repair.l_max


# ---------------------------------------------------------------------------
# fault-input hardening
# ---------------------------------------------------------------------------


def test_repair_rejects_unknown_channel_ids(served):
    topo, st = served
    n_ch = st.at.channels.n
    with pytest.raises(ValueError, match="unknown channel ids"):
        repair_fault(st, [n_ch + 7])
    with pytest.raises(ValueError, match="unknown channel ids"):
        repair_fault(st, [-1])
    with pytest.raises(ValueError, match="unknown channel ids"):
        full_recompute(st, [0, n_ch])


def test_repair_deduplicates_fault_input(served):
    topo, st = served
    color = F.colors_in_use(topo)[0]
    dead = F.dead_channels_for_color(st.at, color)
    dup = np.concatenate([dead, dead[::-1], dead[:3]])
    a = repair_fault(st, dead)
    b = repair_fault(st, dup)
    assert a.flows_rerouted == b.flows_rerouted
    assert a.l_max == b.l_max
    np.testing.assert_array_equal(a.state.table.chan, b.state.table.chan)
    np.testing.assert_array_equal(a.state.dead, b.state.dead)


def test_repair_already_dead_channels_are_noop(served):
    topo, st = served
    color = F.colors_in_use(topo)[0]
    dead = F.dead_channels_for_color(st.at, color)
    first = repair_fault(st, dead)
    assert first.stats["already_dead"] == 0
    # repeating the identical fault against the repaired state must not
    # move a single flow -- the channels are already routed around
    again = repair_fault(first.state, dead)
    assert again.stats["already_dead"] == len(dead)
    assert again.flows_rerouted == 0
    assert again.unreachable == 0
    assert again.deadlock_free
    np.testing.assert_array_equal(again.state.table.chan,
                                  first.state.table.chan)
    np.testing.assert_array_equal(again.state.dead, first.state.dead)


def test_repair_mixed_new_and_already_dead(served):
    topo, st = served
    colors = F.colors_in_use(topo)
    d0 = F.dead_channels_for_color(st.at, colors[0])
    d1 = F.dead_channels_for_color(st.at, colors[1])
    first = repair_fault(st, d0)
    both = repair_fault(first.state, np.concatenate([d0, d1]))
    assert both.stats["already_dead"] == len(d0)
    np.testing.assert_array_equal(both.state.dead, np.union1d(d0, d1))
    assert not _dead_mask(st, np.union1d(d0, d1))[
        both.state.table.chan].any()


# ---------------------------------------------------------------------------
# 12^3 smoke (opt-in)
# ---------------------------------------------------------------------------


@pytest.mark.huge
@pytest.mark.slow          # the fast lane's -m "not slow" overrides the
def test_12cube_single_ocs_repair_smoke():          # "not huge" addopts
    """12^3 time-to-recover smoke (``pytest -m huge``): one OCS dies
    under a live 1728-chip serving state; the incremental repair must
    restore full reachability deadlock-free, avoid the dead channels,
    and stay within the quality bound of a full recompute."""
    topo = T.pdtt((12, 12, 12))
    st = ServingState.build(topo, n_vc=2, K=4, seed=0, robust=True)
    color = F.colors_in_use(topo)[0]
    dead = F.dead_channels_for_color(st.at, color)
    rr = repair_fault(st, dead, verify="full")
    assert rr.unreachable == 0
    assert rr.deadlock_free
    assert not rr.fallback
    assert not _dead_mask(st, dead)[rr.state.table.chan].any()
    routed, _, _ = full_recompute(st, dead)
    assert rr.l_max <= routed.l_max * L_MAX_BOUND
