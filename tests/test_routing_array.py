"""Equivalence suite: array state-CSR routing engine vs the reference
per-source python enumerator (kept as ``engine="reference"``), plus the
vectorised satellites (out-CSR, APL counting, VC allocation)."""
from collections import defaultdict, deque

import numpy as np
import pytest

from repro.core import fault as F, netsim as NS, routing as R, \
    topology as T, vcalloc as V


@pytest.fixture(scope="module", params=[(4, 4, 4), (4, 4, 8)])
def pod_at(request):
    topo = T.pt(request.param)
    at = R.allowed_turns(topo, n_vc=2, priority="apl")
    return topo, at


def _reference_node_distances(at, source, dead=None):
    """Per-destination best distance from the reference state BFS."""
    ch = at.channels
    dist, _ = R.shortest_path_states(at, source, dead_channels=dead)
    best = {}
    for (c, v), d in dist.items():
        node = int(ch.dst[c])
        if node != source:
            best[node] = min(best.get(node, 1 << 30), d)
    return best


def test_out_csr_matches_scan(pod_at):
    topo, at = pod_at
    ch = at.channels
    for node in range(0, topo.n, 7):
        csr = sorted(int(c) for c in ch.out_of(node))
        scan = sorted(np.nonzero(ch.src == node)[0].tolist())
        assert csr == scan
    # reverse-channel array: rev[c] is the opposite direction of c
    assert (ch.src[ch.rev] == ch.dst).all()
    assert (ch.dst[ch.rev] == ch.src).all()


def test_array_bfs_distances_match_reference_exactly(pod_at):
    topo, at = pod_at
    srcs = np.arange(topo.n)
    best = R.node_distances(at, srcs)
    assert (best[srcs, srcs] == 0).all()
    for s in range(0, topo.n, 5):
        ref = _reference_node_distances(at, s)
        for d in range(topo.n):
            if d == s:
                continue
            assert int(best[s, d]) == ref.get(d, -1), (s, d)


def test_array_bfs_distances_match_reference_under_fault(pod_at):
    topo, at = pod_at
    color = F.colors_in_use(topo)[0]
    dead = F.dead_channels_for_color(at, color)
    srcs = np.arange(0, topo.n, 3)
    best = R.node_distances(at, srcs, dead_channels=dead)
    for i, s in enumerate(srcs.tolist()):
        ref = _reference_node_distances(at, s, dead=dead)
        for d in range(topo.n):
            if d == s:
                continue
            assert int(best[i, d]) == ref.get(d, -1), (s, d)


def test_candidates_are_valid_distinct_shortest(pod_at):
    topo, at = pod_at
    cs = R.enumerate_candidates(at, K=4)
    sg = at.state_graph()
    ch = at.channels
    n_vc = at.n_vc
    assert cs.unreachable == 0
    assert len(cs.flow_src) == topo.n * (topo.n - 1)
    kv = cs.k_valid
    assert (kv[:, 0]).all() and kv.sum(axis=1).min() >= 1
    F_, K, L = cs.chan.shape
    # every valid candidate: connected channel sequence from src to dst
    # whose consecutive (channel, vc) hops are allowed turns
    fi, ki = np.nonzero(kv)
    lens = cs.length[fi]
    chanp = cs.chan[fi, ki]
    vcp = cs.vc[fi, ki].astype(np.int64)
    first = chanp[:, 0]
    last = chanp[np.arange(len(fi)), lens - 1]
    assert (ch.src[first] == cs.flow_src[fi]).all()
    assert (ch.dst[last] == cs.flow_dst[fi]).all()
    pair = np.arange(L - 1)[None, :] < (lens - 1)[:, None]
    a = (chanp[:, :-1].astype(np.int64) * n_vc + vcp[:, :-1])[pair]
    b = (chanp[:, 1:].astype(np.int64) * n_vc + vcp[:, 1:])[pair]
    assert sg.has_edges(a, b).all()
    hop_ok = np.arange(L)[None, :] < lens[:, None]
    assert (ch.dst[chanp[:, :-1][pair]] == ch.src[chanp[:, 1:][pair]]).all()
    assert (chanp[~hop_ok] == cs.n_ch).all(), "padding must be SEN"
    # shortest: lengths equal the reference best distance
    best = R.node_distances(at, np.arange(topo.n))
    assert (cs.length == best[cs.flow_src, cs.flow_dst]).all()
    # distinct within each flow (state-sequence comparison)
    states = cs.chan.astype(np.int64) * n_vc + cs.vc
    for f in range(0, F_, 97):
        seen = set()
        for k in range(K):
            if not kv[f, k]:
                continue
            key = tuple(states[f, k, :cs.length[f]].tolist())
            assert key not in seen
            seen.add(key)


def test_select_paths_quality_and_stats_vs_reference(pod_at):
    topo, at = pod_at
    ref = R.select_paths(at, K=4, local_search_rounds=2,
                         engine="reference")
    arr = R.select_paths(at, K=4, local_search_rounds=2, engine="array")
    assert arr.unreachable == 0 and ref.unreachable == 0
    assert arr.table.n_routed() == topo.n * (topo.n - 1)
    # same shortest lengths => identical average hops
    assert abs(arr.avg_hops - ref.avg_hops) < 1e-12
    # min-max quality: within 5% of the reference (usually better)
    assert arr.l_max <= ref.l_max * 1.05, (arr.l_max, ref.l_max)
    # loads accounting consistent with the emitted table
    np.testing.assert_array_equal(arr.loads, arr.table.loads())


def test_select_paths_emits_valid_vcs(pod_at):
    """The array engine writes each winning candidate's BFS state-path
    VCs into the table; they must already be deadlock-free, and
    ``at_tables(balance=None)`` may consume them without re-allocation."""
    topo, at = pod_at
    arr = R.select_paths(at, K=4, local_search_rounds=1, engine="array")
    assert V.verify_deadlock_free(at, arr.table)
    tab = NS.at_tables(topo, at, arr, balance=None)
    assert V.verify_deadlock_free(at, tab.table)
    np.testing.assert_array_equal(tab.table.vcs, arr.table.vcs)
    r = NS.run(tab, 0.02, cycles=600, warmup=200)
    assert r["injected_total"] == r["consumed_total"] + r["in_flight"]
    assert r["delivered"] > 0


def test_select_paths_array_under_fault(pod_at):
    topo, at = pod_at
    color = F.colors_in_use(topo)[0]
    dead = F.dead_channels_for_color(at, color)
    ref = R.select_paths(at, K=4, local_search_rounds=1,
                         dead_channels=dead, engine="reference")
    arr = R.select_paths(at, K=4, local_search_rounds=1,
                         dead_channels=dead, engine="array")
    assert arr.unreachable == ref.unreachable
    assert abs(arr.avg_hops - ref.avg_hops) < 1e-12
    assert arr.l_max <= ref.l_max * 1.05
    # dead channels never appear in routed paths
    deadarr = np.fromiter(dead, np.int64, len(dead))
    assert not np.isin(arr.table.path, deadarr).any()


def test_vectorized_vcalloc_matches_reference_policy(pod_at):
    topo, at = pod_at
    arr = R.select_paths(at, K=4, local_search_rounds=1, engine="array")
    bal = arr.table.copy()
    counts = V.allocate_vcs(at, bal, balance=True)
    assert V.verify_deadlock_free(at, bal)
    assert (counts == bal.vc_hop_counts()).all()
    ratio = counts.max() / max(counts.min(), 1)
    assert ratio < 1.2, f"VC imbalance {counts}"
    unbal = V.allocate_vcs(at, arr.table.copy(), balance=False)
    assert unbal[0] > unbal[1], "naive policy should bias VC0"


def test_prioritize_turns_apl_matches_python_oracle():
    """The batched level-DAG APL counting reproduces the seed's
    per-source triple-loop frequencies (and therefore its ordering)."""
    topo = T.pt((4, 4, 4))
    ch = R.Channels.from_topology(topo)
    turns = R.base_turns(ch)
    # --- seed implementation (python triple loop), verbatim ---
    n = topo.n
    adj = topo.adjacency()
    freq = defaultdict(float)
    for s in range(n):
        dist = np.full(n, -1)
        dist[s] = 0
        q = deque([s])
        parents = defaultdict(list)
        while q:
            u = q.popleft()
            for v in adj[u]:
                if dist[v] < 0:
                    dist[v] = dist[u] + 1
                    q.append(v)
                if dist[v] == dist[u] + 1:
                    parents[v].append(u)
        npaths = np.zeros(n)
        npaths[s] = 1
        for u in np.argsort(dist):
            if dist[u] <= 0:
                continue
            for p in parents[u]:
                npaths[u] += npaths[p]
        for v in range(n):
            for p in parents[v]:
                for gp in parents[p]:
                    cin = ch.index[(gp, p)]
                    cout = ch.index[(p, v)]
                    freq[(cin, cout)] += npaths[gp]
    oracle = sorted(turns, key=lambda t: -freq.get(t, 0.0))
    got = R.prioritize_turns(turns, "apl", topo, ch)
    assert got == oracle


@pytest.mark.slow
def test_8cube_pod_routes_end_to_end():
    """512-chip pod through the full chain: allowed turns -> array BFS ->
    selection -> VC allocation -> simulator tables."""
    topo = T.pt((8, 8, 8))
    at = R.allowed_turns(topo, n_vc=2, priority="apl")
    routed = R.select_paths(at, K=4, local_search_rounds=1)
    assert routed.unreachable == 0
    assert routed.table.n_routed() == topo.n * (topo.n - 1)
    tab = NS.at_tables(topo, at, routed)
    assert V.verify_deadlock_free(at, tab.table)
    assert tab.n == 512 and tab.table.hops.max() <= 40
    # quality sanity: within 2x of the flow-balance lower bound
    assert routed.l_max <= 2 * R.load_lower_bound(topo)
