"""Adaptive escape-VC routing suite.

Covers the adaptive layer end to end: the escape sub-network's safety
properties under every single-OCS fault, bit-identity of the CSR and
dense kernels with adaptivity / mid-sweep faults / bursty injection
enabled, packet conservation when channels die mid-flight, the livelock
watchdog, the escape-reserving VC allocation, and -- under the ``slow``
/ ``huge`` markers -- the headline robustness claim that adaptive
saturation under hotspot traffic is never below static.
"""
import numpy as np
import pytest

from repro.core import fault as F, netsim as NS, routing as R, \
    topology as T, vcalloc as V
from repro.core.traffic import BurstSchedule, TrafficPattern


def _build(podspec):
    topo = T.pt(podspec)
    at = R.allowed_turns(topo, n_vc=4, priority="robust")
    sel = R.select_paths(at, K=4, local_search_rounds=1,
                         engine="sharded")
    tab = NS.at_tables(topo, at, sel, reserve_escape=True)
    return topo, at, tab


@pytest.fixture(scope="module", params=[(4, 4, 4), (4, 4, 8)])
def pod(request):
    return _build(request.param)


def _patterns(topo, at):
    color = F.colors_in_use(topo)[0]
    region = F.fault_region_nodes(at, color)
    return {
        "uniform": None,
        "hotspot": TrafficPattern.hotspot(topo.n, frac=0.4),
        "fault_correlated": TrafficPattern.fault_correlated(
            topo.n, region, frac=0.6, src_boost=2.0),
    }


# ---------------------------------------------------------------------------
# escape sub-network safety properties
# ---------------------------------------------------------------------------


def _assert_tree_turns_acyclic(er, ch):
    # Kahn's algorithm on the channel-dependency graph restricted to the
    # tree-turn set: it must drain completely (no cycle survives).
    n_ch = len(ch.src)
    if not len(er.turns):
        return
    cin, cout = er.turns[:, 0], er.turns[:, 1]
    indeg = np.bincount(cout, minlength=n_ch)
    live = np.ones(len(cin), bool)
    frontier = set(np.nonzero(indeg == 0)[0].tolist())
    while frontier:
        c = frontier.pop()
        out = np.nonzero(live & (cin == c))[0]
        live[out] = False
        for t in out:
            indeg[cout[t]] -= 1
            if indeg[cout[t]] == 0:
                frontier.add(int(cout[t]))
    assert not live.any(), "tree-turn set contains a cycle"


def _assert_walks_terminate(er, ch, alive):
    # Following esc_next hop by hop from every (u, d) pair must reach d
    # in < n hops without ever touching a dead channel.
    n = er.n
    for d in range(n):
        cur = np.arange(n)
        for _ in range(n):
            done = cur == d
            if done.all():
                break
            c = er.esc_next[cur, d]
            assert (c[~done] >= 0).all()
            assert alive[c[~done]].all(), "escape walk crossed dead channel"
            cur = np.where(done, cur, ch.dst[np.clip(c, 0, None)])
        assert (cur == d).all(), f"escape walk failed to reach {d}"


def test_escape_tree_safe_under_every_ocs_fault():
    topo, at, _ = _build((4, 4, 4))
    ch = R.Channels.from_topology(topo)
    # pre-fault network first, then every single-OCS fault
    faults = [np.zeros(0, np.int64)] + \
        [F.dead_channels_for_color(at, c) for c in F.colors_in_use(topo)]
    for dead in faults:
        er = V.escape_routes(topo, dead_channels=dead)
        assert er.connected, "C8-certified fabric lost escape connectivity"
        alive = np.ones(len(ch.src), bool)
        alive[dead] = False
        assert alive[er.tree_channels].all()
        _assert_tree_turns_acyclic(er, ch)
        _assert_walks_terminate(er, ch, alive)
        # diagonal is -1; everything else resolved
        assert (np.diag(er.esc_next) == -1).all()


def test_adaptive_spec_planes(pod):
    topo, at, _ = pod
    color = F.colors_in_use(topo)[0]
    dead = F.dead_channels_for_color(at, color)
    spec = NS.adaptive_spec(topo, dead_channels=dead)
    assert spec.esc.shape == (2, topo.n, topo.n)
    assert spec.minmask.shape == (2, topo.n, topo.n)
    # plane 1 must never route into a dead channel
    assert not np.isin(spec.esc[1], dead).any()
    # pre/post planes genuinely differ once channels die
    assert (spec.esc[0] != spec.esc[1]).any()
    # no-fault spec has identical planes
    spec0 = NS.adaptive_spec(topo)
    np.testing.assert_array_equal(spec0.esc[0], spec0.esc[1])
    np.testing.assert_array_equal(spec0.minmask[0], spec0.minmask[1])


# ---------------------------------------------------------------------------
# kernel bit-identity with the adaptive features enabled
# ---------------------------------------------------------------------------


def test_adaptive_kernels_bit_identical_across_patterns(pod):
    topo, at, tab = pod
    spec = NS.adaptive_spec(topo)
    rates = [0.02, 0.08, 0.2]
    for name, tp in _patterns(topo, at).items():
        tc = NS.sweep(tab, rates, traffic=tp, cycles=1200, warmup=400,
                      kernel="csr", adaptive=spec)
        td = NS.sweep(tab, rates, traffic=tp, cycles=1200, warmup=400,
                      kernel="dense", adaptive=spec)
        assert tc == td, f"adaptive kernel divergence under {name}"
        for r in tc:
            assert r["injected_total"] == (r["consumed_total"]
                                           + r["in_flight"]), name
            assert r["stalled_at"] == -1, name


def test_adaptive_fault_kernels_bit_identical_and_conserving(pod):
    topo, at, tab = pod
    color = F.colors_in_use(topo)[0]
    ev = F.fault_event(at, color, 600)
    spec = NS.adaptive_spec(topo, dead_channels=ev[1])
    tc = NS.sweep(tab, [0.05, 0.15], cycles=1500, warmup=500,
                  kernel="csr", adaptive=spec, fault=ev)
    td = NS.sweep(tab, [0.05, 0.15], cycles=1500, warmup=500,
                  kernel="dense", adaptive=spec, fault=ev)
    assert tc == td
    for r in tc:
        # every packet delivered or accounted for, and traffic kept
        # flowing after the fault (no deadlock, watchdog silent)
        assert r["injected_total"] == (r["consumed_total"]
                                       + r["in_flight"])
        assert r["consumed_total"] > 0
        assert r["stalled_at"] == -1


def test_static_fault_kernels_bit_identical(pod):
    topo, at, tab = pod
    color = F.colors_in_use(topo)[0]
    ev = F.fault_event(at, color, 600)
    tc = NS.sweep(tab, [0.05, 0.15], cycles=1500, warmup=500,
                  kernel="csr", fault=ev)
    td = NS.sweep(tab, [0.05, 0.15], cycles=1500, warmup=500,
                  kernel="dense", fault=ev)
    assert tc == td
    for r in tc:
        assert r["injected_total"] == (r["consumed_total"]
                                       + r["in_flight"])


def test_adaptive_drains_faults_static_cannot():
    topo, at, tab = _build((4, 4, 8))
    color = F.colors_in_use(topo)[0]
    ev = F.fault_event(at, color, 600)
    spec = NS.adaptive_spec(topo, dead_channels=ev[1])
    st = NS.sweep(tab, [0.15], cycles=1500, warmup=500, fault=ev)
    ad = NS.sweep(tab, [0.15], cycles=1500, warmup=500, fault=ev,
                  adaptive=spec)
    # static tables strand the packets whose frozen paths died; the
    # adaptive kernel escape/re-routes them around the fault
    assert ad[0]["in_flight"] < st[0]["in_flight"]
    # with an impatient threshold the escape lane genuinely engages --
    # and the sweep stays conserving and deadlock-free while it does
    imp = NS.sweep(tab, [0.3], cycles=1500, warmup=500, fault=ev,
                   adaptive=spec, patience=1)
    assert imp[0]["escaped"] > 0
    assert imp[0]["stalled_at"] == -1
    assert imp[0]["injected_total"] == (imp[0]["consumed_total"]
                                        + imp[0]["in_flight"])


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------


def test_watchdog_fires_and_aborts_when_fabric_dies():
    topo, at, tab = _build((4, 4, 4))
    all_dead = np.arange(tab.n_ch, dtype=np.int64)
    stats: dict = {}
    out = NS.sweep(tab, [0.2], cycles=4000, warmup=500,
                   fault=(500, all_dead), watchdog=128, stats=stats)
    r = out[0]
    # in-flight packets can never move again: the watchdog must notice
    # and abort the sweep early instead of spinning 4000 cycles
    assert r["in_flight"] > 0
    assert r["stalled_at"] >= 500
    assert stats["cycles_run"] < 4000
    assert r["injected_total"] == r["consumed_total"] + r["in_flight"]


def test_watchdog_silent_on_healthy_sweep():
    topo, at, tab = _build((4, 4, 4))
    stats: dict = {}
    out = NS.sweep(tab, [0.1], cycles=1000, warmup=300,
                   watchdog=64, stats=stats)
    assert out[0]["stalled_at"] == -1
    assert stats["cycles_run"] == 1000


# ---------------------------------------------------------------------------
# bursty injection
# ---------------------------------------------------------------------------


def test_bursty_mean_preserving_and_bit_identical():
    topo, at, tab = _build((4, 4, 4))
    tp = TrafficPattern.uniform(topo.n).with_burst(64, duty=0.25,
                                                   gain=3.0)
    bc = NS.sweep(tab, [0.1], traffic=tp, cycles=2000, warmup=400,
                  kernel="csr")
    bd = NS.sweep(tab, [0.1], traffic=tp, cycles=2000, warmup=400,
                  kernel="dense")
    assert bc == bd
    steady = NS.sweep(tab, [0.1], cycles=2000, warmup=400)
    # mean-preserving modulation: long-run offered load matches steady
    # within sampling noise
    assert abs(bc[0]["offered"] - steady[0]["offered"]) \
        < 0.1 * steady[0]["offered"]
    # but the cycle-level stream genuinely differs
    assert bc[0] != steady[0]


def test_burst_schedule_validation():
    with pytest.raises(ValueError):
        BurstSchedule(64, duty=0.25, gain=5.0).realize(16)
    # staggered phases realize fine and change the stream
    topo, at, tab = _build((4, 4, 4))
    sync = TrafficPattern.uniform(topo.n).with_burst(64)
    stag = TrafficPattern.uniform(topo.n).with_burst(
        64, phase=np.arange(topo.n) % 64)
    a = NS.sweep(tab, [0.2], traffic=sync, cycles=1200, warmup=400)
    b = NS.sweep(tab, [0.2], traffic=stag, cycles=1200, warmup=400)
    assert a != b


# ---------------------------------------------------------------------------
# escape-reserving VC allocation
# ---------------------------------------------------------------------------


def test_reserve_escape_allocation_keeps_vc0_clear():
    topo, at, _ = _build((4, 4, 4))
    sel = R.select_paths(at, K=4, local_search_rounds=1,
                         engine="sharded")
    stats: dict = {}
    tab = NS.at_tables(topo, at, sel, reserve_escape=True, stats=stats)
    table = tab.table
    esc = set(table.escape_flows().tolist())
    vcs = np.asarray(table.vc)
    for f in range(table.n_flows):
        lo, hi = int(table.hop_indptr[f]), int(table.hop_indptr[f + 1])
        if lo == hi:
            continue
        if f in esc:
            assert (vcs[lo:hi] == 0).all()
        else:
            assert (vcs[lo:hi] >= 1).all()
    assert stats.get("escape_fallback_flows", 0) == len(esc)


def test_reserve_escape_requires_headroom():
    topo = T.pt((4, 4, 4))
    at = R.allowed_turns(topo, n_vc=1, priority="apl")
    sel = R.select_paths(at, K=2, engine="sharded")
    with pytest.raises(ValueError):
        NS.at_tables(topo, at, sel, reserve_escape=True)


# ---------------------------------------------------------------------------
# input validation
# ---------------------------------------------------------------------------


def test_sweep_validates_adaptive_and_fault_inputs():
    topo, at, tab = _build((4, 4, 4))
    spec = NS.adaptive_spec(topo)
    with pytest.raises(ValueError):
        NS.sweep(tab, [0.1], fault=(-5, [0]))
    with pytest.raises(ValueError):
        NS.sweep(tab, [0.1], cycles=1000, fault=(2000, [0]))
    with pytest.raises(ValueError):
        NS.sweep(tab, [0.1], fault=(100, [tab.n_ch + 3]))
    with pytest.raises(ValueError):
        NS.sweep(tab, [0.1], adaptive=spec, patience=0)
    with pytest.raises(ValueError):
        NS.sweep(tab, [0.1], watchdog=0)
    with pytest.raises(ValueError):
        F.fault_event(at, 0, -1)
    # spec shape must match the tables it is used with
    topo8, _, tab8 = _build((4, 4, 8))
    with pytest.raises(ValueError):
        NS.sweep(tab8, [0.1], adaptive=spec)


def test_adaptive_requires_two_vcs():
    topo = T.pt((4, 4, 4))
    tab = NS.dor_tables(topo, n_vc=1)
    spec = NS.adaptive_spec(topo)
    with pytest.raises(ValueError):
        NS.sweep(tab, [0.1], adaptive=spec)


# ---------------------------------------------------------------------------
# robustness headline: adaptive saturation never below static
# ---------------------------------------------------------------------------


def _sat_pair(tab, spec, tp, step=0.02):
    s, _ = NS.saturation_point(tab, step=step, traffic=tp, cycles=1500,
                               warmup=500)
    a, _ = NS.saturation_point(tab, step=step, traffic=tp, cycles=1500,
                               warmup=500, adaptive=spec)
    return s, a


@pytest.mark.slow
def test_adaptive_saturation_not_below_static_4x4x8():
    topo, at, tab = _build((4, 4, 8))
    spec = NS.adaptive_spec(topo)
    for name, tp in _patterns(topo, at).items():
        s, a = _sat_pair(tab, spec, tp)
        assert a >= s, f"adaptive regressed static under {name}: {a} < {s}"


@pytest.mark.huge
@pytest.mark.slow
def test_adaptive_saturation_not_below_static_8cubed():
    topo, at, tab = _build((8, 8, 8))
    spec = NS.adaptive_spec(topo)
    # 8 hot endpoints: consumption-limited sat ~= 0.039 at n=512, so
    # the 0.005 grid resolves it (a single hot node would saturate
    # below any usable step)
    tp = TrafficPattern.hotspot(topo.n, list(range(8)), 0.4)
    s, _ = NS.saturation_point(tab, step=0.005, max_rate=0.08,
                               traffic=tp, cycles=1500, warmup=500)
    a, _ = NS.saturation_point(tab, step=0.005, max_rate=0.08,
                               traffic=tp, cycles=1500, warmup=500,
                               adaptive=spec)
    assert a > 0
    assert a >= s, f"adaptive regressed static under hotspot: {a} < {s}"
