"""Chaos campaign engine suite (`repro.core.chaos`): seeded schedules
replay bit-identically, campaigns keep every machine-checked invariant
green at every event (reachability accounting, deadlock freedom,
load/VC consistency, untouched-flow bit-identity, no dead channel
served), disconnections serve degraded without a cold recompute, and
fault->restore round trips recover pre-fault reachability exactly with
post-heal l_max within 1.10x of the cold build. The randomized
fault/restore property test runs under Hypothesis when available and
falls back to fixed seeds otherwise."""
import functools

import numpy as np
import pytest

from repro.core import chaos as X, fault as F, topology as T
from repro.core.repair import ServingState, repair_fault, restore_channels

try:
    from hypothesis import given, settings, strategies as hst
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

L_MAX_BOUND = 1.10


@pytest.fixture(scope="module")
def served():
    topo = T.pdtt((4, 4, 4))
    return topo, ServingState.build(topo, n_vc=4, K=8, seed=0,
                                    robust=True)


@functools.lru_cache(maxsize=1)
def _prop_state():
    # smaller build for the many-example property test (pure state --
    # repairs never mutate it, so one build serves every example)
    topo = T.pdtt((4, 4, 4))
    return ServingState.build(topo, n_vc=2, K=4, seed=0, robust=True)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


def test_schedule_deterministic_and_well_formed(served):
    topo, st = served
    a = X.generate_schedule(st.at, n_arrivals=14, seed=11)
    b = X.generate_schedule(st.at, n_arrivals=14, seed=11)
    assert a.n_events == b.n_events
    for ea, eb in zip(a.events, b.events):
        assert (ea.t, ea.kind, ea.colors) == (eb.t, eb.kind, eb.colors)
        np.testing.assert_array_equal(ea.channels, eb.channels)
    # a different seed samples a different timeline
    c = X.generate_schedule(st.at, n_arrivals=14, seed=12)
    assert [e.t for e in c.events] != [e.t for e in a.events]
    # well-formed: faults only kill live channels, restores only revive
    # dead ones, and events arrive in time order
    ts = [e.t for e in a.events]
    assert ts == sorted(ts)
    dead = np.zeros(0, np.int64)
    for e in a.events:
        if e.kind == "restore":
            assert len(np.setdiff1d(e.channels, dead)) == 0
            dead = np.setdiff1d(dead, e.channels)
        else:
            dead = np.union1d(dead, e.channels)
    assert len(dead) == 0, "final_heal must close the timeline"


def test_schedule_coverage_guarantees(served):
    topo, st = served
    sched = X.generate_schedule(st.at, n_arrivals=12, seed=5)
    kinds = sched.kinds()
    assert kinds.get("restore", 0) >= 1          # final heal at least
    # the forced isolate is a links event killing a full incident set
    ch = st.at.channels
    isolating = False
    for e in sched.events:
        if e.kind != "links":
            continue
        for node in np.unique(np.concatenate(
                [ch.src[e.channels], ch.dst[e.channels]])):
            inc = np.nonzero((ch.src == node) | (ch.dst == node))[0]
            if len(np.setdiff1d(inc, e.channels)) == 0:
                isolating = True
    assert isolating, "ensure_coverage must force a node isolation"


# ---------------------------------------------------------------------------
# degraded mode + restoration round trips
# ---------------------------------------------------------------------------


def test_fault_restore_roundtrip_exact(served):
    topo, st = served
    color = F.colors_in_use(topo)[0]
    dead = F.dead_channels_for_color(st.at, color)
    rr = repair_fault(st, dead, verify="full")
    heal = restore_channels(rr.state, dead, verify="full")
    assert heal.restored == len(dead)
    assert len(heal.state.dead) == 0
    assert len(heal.state.lost) == 0
    # pre-fault reachability recovered exactly, quality within bound of
    # the cold build (the full-recompute oracle on the healed fabric)
    assert heal.state.table.n_routed() == topo.n * (topo.n - 1)
    assert heal.l_max <= st.l_max * L_MAX_BOUND, (heal.l_max, st.l_max)
    inv = X.check_invariants(rr.state, heal)
    assert all(inv.values()), inv


def test_partial_restore_keeps_remaining_fault(served):
    topo, st = served
    colors = F.colors_in_use(topo)[:2]
    d0 = F.dead_channels_for_color(st.at, colors[0])
    d1 = F.dead_channels_for_color(st.at, colors[1])
    both = repair_fault(repair_fault(st, d0).state, d1)
    heal = restore_channels(both.state, d0, verify="full")
    np.testing.assert_array_equal(heal.state.dead, np.sort(d1))
    # the healed table must not touch the still-dead channels
    m = np.zeros(st.at.channels.n, bool)
    m[d1] = True
    assert not m[heal.state.table.chan].any()
    inv = X.check_invariants(both.state, heal)
    assert all(inv.values()), inv


def test_restore_rejects_unknown_and_ignores_live(served):
    topo, st = served
    with pytest.raises(ValueError, match="unknown channel ids"):
        restore_channels(st, [st.at.channels.n + 3])
    rr = restore_channels(st, [0, 1])   # nothing dead: no-op
    assert rr.restored == 0
    assert rr.flows_rerouted == 0
    np.testing.assert_array_equal(rr.state.table.chan, st.table.chan)


def test_degraded_probe_compacts_lost_pairs(served):
    topo, st = served
    ch = st.at.channels
    dead = np.nonzero((ch.src == 0) | (ch.dst == 0))[0].astype(np.int64)
    rr = repair_fault(st, dead)
    assert rr.lost == 2 * (topo.n - 1) and not rr.fallback
    probe = X.probe_throughput(rr.state, rate=0.05, cycles=600,
                               warmup=200)
    assert probe["served_flows"] == topo.n * (topo.n - 1) - rr.lost
    assert probe["delivered"] > 0.0
    assert probe["cycles_run"] > 0


# ---------------------------------------------------------------------------
# campaigns
# ---------------------------------------------------------------------------


def test_campaign_full_contract_small(served):
    topo, st = served
    sched = X.generate_schedule(st.at, n_arrivals=12, seed=5)
    res = X.run_campaign(st, sched, coalesce=1.0)
    assert res.ok, [r.invariants for r in res.records if not r.ok]
    assert not any(r.fallback for r in res.records)
    # degraded-mode event served without a cold recompute
    assert any(r.lost_pairs > 0 for r in res.records)
    assert any(r.kind == "restore" for r in res.records)
    # final heal recovers everything
    final = res.records[-1]
    assert final.served_fraction == 1.0
    assert len(res.state.lost) == 0
    assert res.state.table.n_routed() == topo.n * (topo.n - 1)
    assert res.state.l_max <= res.baseline_l_max * L_MAX_BOUND


def test_campaign_coalesces_storms(served):
    topo, st = served
    sched = X.generate_schedule(st.at, n_arrivals=12, seed=5)
    res = X.run_campaign(st, sched, coalesce=1.0)
    storms = [r for r in res.records if r.kind == "storm"]
    assert storms and max(r.coalesced for r in storms) > 1
    # total arrivals are conserved across grouping
    assert sum(r.coalesced for r in res.records) == sched.n_events


def test_campaign_replays_bit_identically(served):
    topo, st = served
    sched = X.generate_schedule(st.at, n_arrivals=10, seed=9)
    a = X.run_campaign(st, sched, coalesce=1.0)
    b = X.run_campaign(
        st, X.generate_schedule(st.at, n_arrivals=10, seed=9),
        coalesce=1.0)
    assert a.fingerprint() == b.fingerprint()
    assert a.ok and b.ok
    # and the timeline views agree field by field (MTTR is measured
    # wall-clock, the one legitimately non-deterministic column)
    ta, tb = a.timeline(), b.timeline()
    ta.pop("mttr_s"), tb.pop("mttr_s")
    assert ta == tb


# ---------------------------------------------------------------------------
# randomized fault/restore property: invariants hold at every step
# ---------------------------------------------------------------------------


def _random_ops_preserve_invariants(seed: int, n_ops: int) -> None:
    st = _prop_state()
    ch = st.at.channels
    rng = np.random.default_rng(seed)
    cur = st
    for _ in range(n_ops):
        if len(cur.dead) and rng.random() < 0.4:
            k = int(rng.integers(1, len(cur.dead) + 1))
            chans = np.sort(rng.choice(cur.dead, size=k, replace=False))
            rr = restore_channels(cur, chans)
        else:
            if rng.random() < 0.5:
                node = int(rng.integers(ch.n_nodes))
                chans = np.nonzero((ch.src == node)
                                   | (ch.dst == node))[0]
            else:
                c = int(rng.choice(np.unique(ch.color[ch.color >= 0])))
                chans = np.nonzero(ch.color == c)[0]
            chans = np.setdiff1d(chans.astype(np.int64), cur.dead)
            if not len(chans):
                continue
            rr = repair_fault(cur, chans)
        assert not rr.fallback
        inv = X.check_invariants(cur, rr)
        assert all(inv.values()), (seed, inv)
        cur = rr.state
    # closing heal always recovers the cold build's reachability
    if len(cur.dead):
        rr = restore_channels(cur, cur.dead)
        inv = X.check_invariants(cur, rr)
        assert all(inv.values()), (seed, inv)
        cur = rr.state
    assert len(cur.lost) == 0
    assert cur.table.n_routed() == st.table.n_flows


if HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None)
    @given(seed=hst.integers(0, 2**31 - 1), n_ops=hst.integers(2, 4))
    def test_random_fault_restore_sequences_keep_invariants(seed, n_ops):
        _random_ops_preserve_invariants(seed, n_ops)
else:
    @pytest.mark.parametrize("seed,n_ops",
                             [(0, 3), (1, 4), (7, 2), (13, 4)])
    def test_random_fault_restore_sequences_keep_invariants(seed, n_ops):
        _random_ops_preserve_invariants(seed, n_ops)


# ---------------------------------------------------------------------------
# 8^3 acceptance campaign (opt-in)
# ---------------------------------------------------------------------------


@pytest.mark.huge
@pytest.mark.slow          # the fast lane's -m "not slow" overrides the
def test_8cube_chaos_campaign_acceptance():         # "not huge" addopts
    """The PR's acceptance campaign (``pytest -m huge``): a seeded
    >= 20-event 8^3 timeline with at least one coalesced multi-OCS
    storm, one disconnecting fault served degraded (no cold recompute)
    and one restoration; every invariant green at every event; the
    final heal recovers pre-fault reachability with l_max within 1.10x
    of the cold build; and the campaign replays bit-identically."""
    topo = T.pdtt((8, 8, 8))
    st = ServingState.build(topo, n_vc=2, K=4, seed=0, robust=True)
    sched = X.generate_schedule(st.at, n_arrivals=20, seed=7)
    assert sched.n_events >= 20
    res = X.run_campaign(st, sched, coalesce=1.0)
    assert res.ok, [r.invariants for r in res.records if not r.ok]
    assert any(r.kind == "storm" and r.coalesced > 1 for r in res.records)
    assert any(r.lost_pairs > 0 and not r.fallback for r in res.records)
    assert any(r.kind == "restore" for r in res.records)
    assert not any(r.fallback for r in res.records)
    assert len(res.state.lost) == 0
    assert res.state.table.n_routed() == topo.n * (topo.n - 1)
    assert res.state.l_max <= res.baseline_l_max * L_MAX_BOUND
    replay = X.run_campaign(
        st, X.generate_schedule(st.at, n_arrivals=20, seed=7),
        coalesce=1.0)
    assert replay.fingerprint() == res.fingerprint()
