"""End-to-end behaviour tests for the paper's system."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.configs.registry import cells, get_config, list_archs
from repro.core import collectives as C, netsim as NS, routing as R, \
    topology as T
from repro.data.synthetic import DataConfig
from repro.optim.adamw import OptConfig
from repro.train.loop import TrainConfig, Trainer


def test_cells_cover_assignment():
    all_cells = cells(include_skipped=True)
    assert len(all_cells) == 40
    runnable = [c for c in all_cells if c[2]]
    assert len(runnable) == 32
    # long_500k only for sub-quadratic archs
    for a, s, ok in all_cells:
        if s == "long_500k":
            assert ok == (a in ("mamba2-2.7b", "jamba-v0.1-52b"))


@pytest.mark.slow
def test_training_loss_decreases_and_resumes():
    cfg = get_config("qwen2.5-3b").smoke_model()
    with tempfile.TemporaryDirectory() as d:
        tc = TrainConfig(steps=10, ckpt_every=5, ckpt_dir=d, log_every=100)
        tr = Trainer(cfg, DataConfig(vocab=cfg.vocab, seq_len=32,
                                     global_batch=4),
                     OptConfig(lr=1e-3, warmup_steps=2, total_steps=10), tc)
        out = tr.run()
        assert out["losses"][-1] < out["losses"][0]
        # resume continues from the saved step
        tc2 = TrainConfig(steps=14, ckpt_every=5, ckpt_dir=d, log_every=100)
        tr2 = Trainer(cfg, DataConfig(vocab=cfg.vocab, seq_len=32,
                                      global_batch=4),
                      OptConfig(lr=1e-3, warmup_steps=2, total_steps=14),
                      tc2)
        assert tr2.start_step == 10
        out2 = tr2.run()
        assert out2["final_step"] == 14


def test_grad_compression_trains():
    cfg = get_config("qwen2.5-3b").smoke_model()
    with tempfile.TemporaryDirectory() as d:
        tc = TrainConfig(steps=6, ckpt_every=100, ckpt_dir=d,
                         log_every=100, grad_compression="int8")
        tr = Trainer(cfg, DataConfig(vocab=cfg.vocab, seq_len=32,
                                     global_batch=4),
                     OptConfig(lr=1e-3, warmup_steps=2, total_steps=6), tc)
        out = tr.run()
        assert out["losses"][-1] < out["losses"][0]


@pytest.mark.slow
def test_microbatched_grad_accumulation_matches_full():
    from repro.models import model as M
    from repro.optim import adamw
    from repro.train.loop import make_step
    cfg = get_config("qwen2.5-3b").smoke_model()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(params)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)),
                                   jnp.int32)}
    oc = OptConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    s1 = make_step(cfg, oc, TrainConfig(microbatches=1))
    s2 = make_step(cfg, oc, TrainConfig(microbatches=2))
    _, _, a = s1(params, opt, batch)
    _, _, b = s2(params, opt, batch)
    assert abs(float(a["loss"]) - float(b["loss"])) < 0.02


def test_serving_batched_requests():
    from repro.launch.serve import Request, Server
    from repro.models import model as M
    cfg = get_config("qwen2.5-3b").smoke_model()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    server = Server(cfg, params, n_slots=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab, 8), 4) for i in range(3)]
    out = server.run(reqs)
    assert out["served"] == 3
    assert all(len(v) >= 4 for v in out["results"].values())


def test_collective_schedules_sane():
    topo = T.pt((4, 4, 8))
    at = R.allowed_turns(topo, n_vc=2, priority="random")
    routed = R.select_paths(at, K=2, local_search_rounds=0)
    rep = C.collective_report(topo, routed, mcf_lambda=0.0078125)
    for kind, r in rep.items():
        assert 0 < r["utilization"] <= 1.0 + 1e-9, kind
    # all-gather/all-reduce near-ideal on tori (paper Fig. 6)
    assert rep["all-gather"]["utilization"] > 0.5
    # a2a cannot beat its MCF limit
    assert rep["all-to-all"]["epochs"] >= 1 / 0.0078125 * 0.95


def test_roofline_terms_formulas():
    from repro.launch.hlo_analysis import model_flops, roofline_terms
    t = roofline_terms(1e12, 1e11, 1e9, 256)
    assert t["t_compute"] == pytest.approx(1e12 / 197e12)
    assert t["t_memory"] == pytest.approx(1e11 / 819e9)
    assert t["t_collective"] == pytest.approx(1e9 / (50e9 * 6))
    assert t["dominant"] == "t_memory"
    assert model_flops(1e9, 1e6, "train") == pytest.approx(6e15)


def test_hlo_collective_parser():
    from repro.launch.hlo_analysis import collective_stats
    txt = (
        "%all-reduce = f32[32,256]{1,0} all-reduce(%dot), channel_id=1, "
        "replica_groups=[8,16]<=[8,16]T(1,0), use_global_device_ids=true\n"
        "%ag = bf16[64,64]{1,0} all-gather(%p), channel_id=2, "
        "replica_groups={{0,1,2,3}}, dimensions={0}\n"
        "ROOT %fusion = f32[2]{0} fusion(%all-reduce), kind=kLoop\n")
    s = collective_stats(txt)
    assert s["all-reduce"]["count"] == 1
    assert s["all-reduce"]["operand_bytes"] == 32 * 256 * 4
    g = 16
    assert s["all-reduce"]["wire_bytes"] == pytest.approx(
        2 * 32 * 256 * 4 * (g - 1) / g)
    assert s["all-gather"]["count"] == 1
    assert s["all-gather"]["operand_bytes"] == pytest.approx(
        64 * 64 * 2 / 4)


def test_fault_certificate_math():
    from repro.core.fault import fault_tolerance_certificate
    topo = T.pt((4, 4, 8))
    cert = fault_tolerance_certificate(topo, 0.0078125, f=1)
    assert cert["satisfies_c8"]
    assert cert["t_max"] == min(int(32 * 128 * 0.0078125), 48)
